"""Quickstart: build a DAG, run it on WUKONG, compare every engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import operator

import numpy as np

from repro.core import (
    ENGINES,
    EngineConfig,
    GraphBuilder,
    PlatformConfig,
    WukongEngine,
)


def main() -> None:
    # --- 1. author a workflow (the paper's Figure 6 DAG) ---------------
    g = GraphBuilder()
    t1 = g.add(lambda: np.arange(4.0), name="T1")
    t2 = g.add(lambda: np.ones(4), name="T2")
    t3 = g.add(lambda x: x * 2, t2, name="T3")
    t5 = g.add(np.cumsum, t3, name="T5")
    t4 = g.add(operator.add, t1, t3, name="T4")
    g.add(lambda a, b: float(a.sum() + b.sum()), t4, t5, name="T6")
    dag = g.build()
    print(f"DAG: {len(dag)} tasks, leaves={dag.leaves}, roots={dag.roots}")

    # --- 2. run it decentralized (WUKONG) -------------------------------
    report = WukongEngine().compute(dag)
    print(f"WUKONG result: {report.results}  "
          f"(executors={report.executors_invoked}, "
          f"kv={report.kv_stats['puts']} puts/{report.kv_stats['gets']} gets)")

    # --- 3. same DAG on every design iteration --------------------------
    for name, Engine in ENGINES.items():
        rep = Engine().compute(dag)
        print(f"  {name:18s} -> {rep.results['T6']:.1f}  "
              f"simulated-cost {rep.charged_ms:7.1f} ms")

    # --- 4. through the DAG compiler (fusion/clustering/coalescing) -----
    opt = WukongEngine().compute(g.build(optimize=True))
    print(f"optimized: {opt.results}  "
          f"(executors={opt.executors_invoked}, "
          f"kv puts={opt.kv_stats['puts']}, passes={[s.name for s in opt.optimizer]})")

    # --- 5. on the stateful platform model: what did the job COST? ------
    billed = WukongEngine(EngineConfig(
        platform=PlatformConfig(memory_mb=1792, keep_alive_s=600.0)
    )).compute(dag)
    ps = billed.platform_stats
    print(f"platform: billed ${ps['billed_usd']:.9f} "
          f"({ps['billed_requests']} requests, "
          f"{ps['billed_gb_s']:.4f} GB-s; "
          f"cold={ps['cold_starts']}, warm={ps['warm_reuses']}, "
          f"peak concurrency={ps['peak_concurrency']})")

    # --- 6. multi-tenant traffic on ONE shared platform -----------------
    from repro.core import JobOrchestrator, OrchestratorConfig, WorkloadConfig

    traffic = JobOrchestrator(OrchestratorConfig(
        workload=WorkloadConfig(n_jobs=16, arrival_rate_per_s=4.0,
                                app_mix=(("tree_reduction", 1.0),)),
        max_concurrent_jobs=8,
    )).run()
    print(f"orchestrator: {traffic.completed}/{traffic.jobs} jobs, "
          f"p50={traffic.p50_s:.3f}s p99={traffic.p99_s:.3f}s, "
          f"warm share {traffic.warm_share * 100:.0f}%, "
          f"account bill ${traffic.billed_usd_total:.9f} across "
          f"{len(traffic.per_tenant)} tenants")


if __name__ == "__main__":
    main()
