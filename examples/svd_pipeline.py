"""Blocked linear algebra on the serverless DAG engine (paper §V).

Runs the paper's SVD2 workload (rank-5 randomized SVD, Halko et al.) as
a WUKONG DAG with jitted JAX task payloads, plus the ideal-storage
ablation from §V-C, and prints the per-task latency breakdown (Fig. 13).

    PYTHONPATH=src python examples/svd_pipeline.py [--n 1024]
"""
import argparse

import numpy as np

from repro.apps import randomized_svd_dag
from repro.apps.svd import randomized_svd_expected
from repro.core import CostModel, EngineConfig, WukongEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--blocks", type=int, default=8)
    args = ap.parse_args()

    eng = WukongEngine(EngineConfig(cost=CostModel(time_scale=0.05)))

    for ideal in (False, True):
        dag = randomized_svd_dag(args.n, 5, 5, args.blocks,
                                 ideal_storage=ideal)
        rep = eng.compute(dag)
        s = np.asarray(rep.results["svd2-S"])
        want = randomized_svd_expected(args.n, 5, 5, args.blocks)
        err = np.max(np.abs(s - want) / want)
        kind = "ideal-storage" if ideal else "normal      "
        print(f"[{kind}] wall {rep.wall_s:6.2f}s  "
              f"kv_bytes={rep.kv_stats['bytes_written']:>12,}  "
              f"sv rel-err {err:.2e}")

    execd = [m for m in rep.metrics if m.get("event") == "executed"]
    read = np.array([m["read_ms"] for m in execd])
    comp = np.array([m["compute_ms"] for m in execd])
    print(f"\nFig.13-style breakdown over {len(execd)} tasks:")
    for name, vals in [("kv-read", read), ("compute", comp)]:
        print(f"  {name:8s} p50={np.percentile(vals, 50):7.2f}ms "
              f"p99={np.percentile(vals, 99):8.2f}ms "
              f"max={vals.max():8.2f}ms")


if __name__ == "__main__":
    main()
