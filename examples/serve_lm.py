"""End-to-end serving driver: batched autoregressive decode with the
KV/SSM cache machinery, requests scheduled through the WUKONG engine.

Each request batch is a DAG: prefill (token-by-token cache warmup on the
decode path) -> N decode steps -> detokenize stub. The engine gives us
retry-on-failure per request and concurrency across request batches.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral_8x7b \
        --requests 4 --prompt-len 16 --gen-len 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import EngineConfig, FaultConfig, GraphBuilder, WukongEngine
from repro.models import model as M
from repro.runtime.serve import build_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2,
                    help="sequences per request batch")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    serve_step = jax.jit(build_serve_step(cfg))
    max_len = args.prompt_len + args.gen_len

    def handle_request(rid: int):
        """One batched request: greedy decode after prompt ingestion."""
        key = jax.random.PRNGKey(100 + rid)
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)
        cache = M.init_cache(cfg, args.batch, max_len)
        tok = prompt[:, 0]
        t0 = time.time()
        generated = []
        for pos in range(max_len - 1):
            logits, cache = serve_step(
                params, cache, {"token": tok, "pos": jnp.int32(pos)})
            if pos + 1 < args.prompt_len:
                tok = prompt[:, pos + 1]            # prefill phase
            else:
                tok = jnp.argmax(logits, axis=-1)   # greedy decode
                generated.append(np.asarray(tok))
        dt = time.time() - t0
        gen = np.stack(generated, axis=1)
        return {
            "rid": rid,
            "tokens": gen,
            "decode_tps": args.batch * gen.shape[1] / dt,
            "latency_s": dt,
        }

    # Requests as a WUKONG DAG: fan-out of independent request handlers
    # into a summary fan-in (engine supplies retry + concurrency).
    g = GraphBuilder()
    reqs = [g.add(lambda r=r: handle_request(r), name=f"request-{r}")
            for r in range(args.requests)]
    g.add(lambda *rs: {
        "n": len(rs),
        "mean_tps": float(np.mean([r["decode_tps"] for r in rs])),
        "p99_latency_s": float(np.percentile(
            [r["latency_s"] for r in rs], 99)),
    }, *reqs, name="summary")

    eng = WukongEngine(EngineConfig(
        faults=FaultConfig(task_failure_prob=0.05, max_retries=2, seed=3),
        job_timeout_s=3600.0))
    t0 = time.time()
    rep = eng.compute(g.build())
    summary = rep.results["summary"]
    print(f"arch={cfg.name} requests={args.requests} "
          f"batch={args.batch} gen={args.gen_len}")
    print(f"served in {time.time() - t0:.1f}s  "
          f"mean decode throughput {summary['mean_tps']:.1f} tok/s  "
          f"p99 latency {summary['p99_latency_s']:.2f}s")
    r0 = rep.results["request-0"]
    print("sample continuation (req 0, seq 0):",
          r0["tokens"][0][:12].tolist())


if __name__ == "__main__":
    main()
