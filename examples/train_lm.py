"""End-to-end training driver: LM train steps orchestrated as a WUKONG
workflow with fault-injected retries and periodic async checkpoints.

The inner step is jitted JAX (loss -> grads -> AdamW); the *cluster
workflow* (data shard -> step -> metrics, checkpoint fan-outs) runs on
the paper's decentralized DAG engine, which supplies Lambda-style retry
and straggler handling (DESIGN.md §2).

Defaults are laptop-sized. For the assignment's "~100M model for a few
hundred steps" run:
    PYTHONPATH=src python examples/train_lm.py --arch smollm_360m \
        --layers 8 --steps 200 --batch 8 --seq 256
(smollm_360m at 8 layers ≈ 100M params with its 49k vocab.)
"""
import argparse
import dataclasses
import os
import time

import jax

from repro.configs import get_config, reduced
from repro.core import EngineConfig, FaultConfig
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import checkpoint as ckpt
from repro.runtime.orchestrator import (
    build_training_workflow,
    run_training_workflow,
)
from repro.runtime.train import build_train_step, synthetic_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-width", action="store_true",
                    help="keep the arch's real width (default: reduced)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-prob", type=float, default=0.02,
                    help="injected Lambda failure probability")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_width:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, n_layers=args.layers
                              * cfg.pattern_period)
    n_params = sum(x.size for x in jax.tree.leaves(
        M.abstract_params(cfg)))
    print(f"arch={cfg.name} layers={cfg.n_layers} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    jstep = jax.jit(build_train_step(cfg, AdamWConfig(lr=args.lr)))

    os.makedirs(args.ckpt_dir, exist_ok=True)
    ckpt_path = os.path.join(args.ckpt_dir, f"{cfg.name}.npz")

    def init_fn():
        # elastic resume: pick up the latest checkpoint if one exists
        if os.path.exists(ckpt_path):
            like = jax.eval_shape(lambda: {"params": params, "opt": opt})
            state, step0 = ckpt.restore(ckpt_path, like)
            print(f"resumed from checkpoint @ step {step0}")
            return (state["params"], state["opt"])
        return (params, opt)

    losses = []

    def step_fn(state, i):
        p, o = state
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=i)
        p, o, m = jstep(p, o, batch)
        loss = float(m["loss"])
        losses.append((i, loss))
        return (p, o), {"loss": loss}

    def checkpoint_fn(state, i):
        p, o = state
        ckpt.save(ckpt_path, {"params": p, "opt": o}, step=i, async_=True)
        return f"ckpt@{i}"

    dag, final_key, metric_keys = build_training_workflow(
        n_steps=args.steps, step_fn=step_fn, init_fn=init_fn,
        checkpoint_fn=checkpoint_fn, checkpoint_every=args.ckpt_every)

    t0 = time.time()
    res = run_training_workflow(
        dag, final_key, metric_keys,
        EngineConfig(faults=FaultConfig(task_failure_prob=args.fail_prob,
                                        max_retries=2, seed=1),
                     job_timeout_s=24 * 3600.0))
    dt = time.time() - t0

    losses.sort()
    shown = {i: l for i, l in losses}
    first, last = losses[0][1], losses[-1][1]
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    for i in sorted(shown)[:: max(1, args.steps // 10)]:
        print(f"  step {i:4d}  loss {shown[i]:.4f}")
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    print(f"checkpoint: {ckpt_path} (step {ckpt.latest_step(ckpt_path)})")


if __name__ == "__main__":
    main()
