"""Fig. 8: blocked GEMM — WUKONG vs serverful, growing problem size.

Paper claims: WUKONG >2x faster than Dask (EC2) and >5x faster than Dask
(Laptop) at 10k x 10k; the largest sizes OOM the serverful setups while
WUKONG scales out elastically (we mark the laptop DNF by worker-memory
model rather than crashing the container).

Beyond-paper series: ``wukong_striped`` vs ``wukong_unstriped`` isolate
the PR 2 data plane (striped large objects + batched KV round trips) in
the emulated data-intensive regime — §V-B identifies intermediate-data
movement as the dominant overhead for GEMM, and the Wukong follow-up's
chunked storage is the fix this pair ablates. Both run the identical
optimized engine and cost regime; only the two data-plane factors differ.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import gemm_dag


def run(sizes=((512, 128), (1024, 128), (2048, 128))) -> list[dict]:
    rows = []
    for n, bs in sizes:
        for label, eng in [
            ("wukong", common.wukong()),
            ("wukong_striped", common.wukong_dataplane()),
            ("wukong_unstriped", common.wukong_dataplane_off()),
            ("dask_ec2", common.serverful_ec2()),
            ("dask_laptop", common.serverful_laptop()),
        ]:
            dag = gemm_dag(n, bs, ms_per_flop=common.ms_per_flop())
            r = common.timed(eng, dag)
            r["label"] = f"{label}@n={n}"
            r["derived"] = f"blocks={(n // bs) ** 2}"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig08")


if __name__ == "__main__":
    main()
