"""Fig. 7: TR end-to-end — WUKONG vs design iterations vs serverful Dask.

Paper claims: WUKONG beats every centralized iteration; at 0ms delay the
communication-bound TR still favors Dask (EC2); with 250-500ms task
delays WUKONG overtakes Dask (EC2) (~2.5x at 500ms).

Beyond-paper series: ``wukong+opt`` is the same engine behind the DAG
compiler (clustering's delayed fan-in I/O halves KV ``set`` traffic on TR
and coalescing halves initial invocations), the optimized-vs-unoptimized
comparison the Wukong follow-up paper motivates.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import tree_reduction_dag


def run(n: int = 512, delays_ms=(0.0, 250.0, 500.0)) -> list[dict]:
    rows = []
    engines = [
        ("wukong", common.wukong()),
        ("wukong+opt", common.wukong_optimized()),
        ("strawman", common.strawman()),
        ("pubsub", common.pubsub()),
        ("parallel_invoker", common.parallel_invoker()),
        ("dask_ec2", common.serverful_ec2()),
        ("dask_laptop", common.serverful_laptop()),
    ]
    for delay in delays_ms:
        for label, eng in engines:
            dag = tree_reduction_dag(n, compute_ms=delay,
                                     payload_bytes=1 << 20)
            r = common.timed(eng, dag)
            r["label"] = f"{label}@{delay:g}ms"
            r["derived"] = f"delay={delay:g}ms"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig07")


if __name__ == "__main__":
    main()
