"""Fig. 10: rank-5 randomized SVD of an n x n matrix (+ ideal storage).

Paper claims: Dask (EC2) wins small sizes; WUKONG wins the largest
(3.1x at 100k x 100k); with an ideally-fast intermediate store WUKONG
executes in a fraction of the time (95.5% less than Dask EC2 at the
largest size) — bounding how much of WUKONG's time is KV-store traffic.

Beyond-paper series: ``wukong_striped`` vs ``wukong_unstriped`` — the
PR 2 data-plane ablation (striping + batched round trips) in the
emulated data-intensive regime; it sits between ``wukong`` and
``wukong_ideal``, showing how much of the ideal-storage gap the real
data-plane optimizations close. See fig08_gemm.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import randomized_svd_dag


def run(sizes=(512, 1024, 2048, 4096), n_blocks: int = 8) -> list[dict]:
    rows = []
    for n in sizes:
        for label, eng, kw in [
            ("wukong", common.wukong(), {}),
            ("wukong_striped", common.wukong_dataplane(), {}),
            ("wukong_unstriped", common.wukong_dataplane_off(), {}),
            ("wukong_ideal", common.wukong(), {"ideal_storage": True}),
            ("dask_ec2", common.serverful_ec2(), {}),
            ("dask_laptop", common.serverful_laptop(), {}),
        ]:
            dag = randomized_svd_dag(n, 5, 5, n_blocks,
                         ms_per_flop=common.ms_per_flop(),
                         **kw)
            r = common.timed(eng, dag)
            r["label"] = f"{label}@n={n}"
            r["derived"] = f"kv_bytes={r['kv_bytes']}"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig10")


if __name__ == "__main__":
    main()
