"""Benchmark entry point: one module per paper figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a
``BENCH_results.json`` snapshot (engine -> wall_s / charged_ms /
kv_stats per figure) at the repo root so the perf trajectory is tracked
across PRs.

Benchmarks run on the deterministic virtual clock by default
(``SIM_SCALE == 0``): ``wall_s`` is the simulated makespan,
bit-identical across runs. Setting ``REPRO_SIM_SCALE > 0`` re-enables
the seed real-time mode (simulated latencies really sleep) for
cross-checks. Problem-size knobs: ``--quick`` (smaller sizes) and
``--smoke`` (toy sizes; a CI regression gate that executes every
figure's engines end-to-end in seconds, plus a data-plane gate, a
virtual-clock gate asserting determinism and the >=10x wall-time
speedup over the seed SIM_SCALE=0.1 real-time path, and the fig16
scale gate asserting the event-driven substrate's >=5x speedup over
the thread-per-actor cross-check mode and the 10^5-task wall budget).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

RESULTS_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_results.json"
)


def _json_row(row: dict) -> dict:
    """The per-PR trajectory record for one engine/config series."""
    out = {
        "wall_s": row["wall_s"],
        "charged_ms": row.get("charged_ms"),
        "kv_stats": row.get("kv_stats"),
        "tasks": row.get("tasks"),
        "executors": row.get("executors"),
        # Provider-model counters (cold/warm starts, throttles, billed
        # USD in pool mode; invoker cold starts in every mode).
        "platform_stats": row.get("platform_stats"),
    }
    if row.get("cache_stats"):
        # Locality trajectory (fig18): per-tier hits/misses/evictions,
        # tier-0 hit rate, and bytes served locally instead of from the
        # shared KV store.
        out["cache_stats"] = row["cache_stats"]
        out["hit_rate"] = row.get("hit_rate")
        out["bytes_local"] = row.get("bytes_local")
    return out


def _time_schedule_generation() -> dict:
    """Host-side hot path trajectory: O(V+E) sweep vs the paper's
    per-leaf DFS on a 512-leaf tree reduction (printed + recorded in
    BENCH_results.json so regressions are visible across PRs)."""
    import gc
    import time as _t

    from repro.apps import tree_reduction_dag
    from repro.core.optimize import compile_dag
    from repro.core.schedule import (
        generate_static_schedules,
        generate_static_schedules_dfs,
    )

    dag = compile_dag(tree_reduction_dag(1024))  # 512 leaves

    # Interleave the two implementations so drifting background load
    # lands on both equally (serial best-of-N loops skew the ratio
    # whenever the machine quiets down between them).
    dfs_ts, sweep_ts = [], []
    gc.disable()
    try:
        for _ in range(20):
            t0 = _t.perf_counter()
            generate_static_schedules_dfs(dag)
            dfs_ts.append(_t.perf_counter() - t0)
            t0 = _t.perf_counter()
            generate_static_schedules(dag)
            sweep_ts.append(_t.perf_counter() - t0)
    finally:
        gc.enable()
    dfs_ms = min(dfs_ts) * 1e3
    sweep_ms = min(sweep_ts) * 1e3
    out = {"leaves": 512, "dfs_ms": dfs_ms, "sweep_ms": sweep_ms,
           "speedup": dfs_ms / sweep_ms}
    print(f"# schedule-gen (512-leaf TR): per-leaf DFS {dfs_ms:.2f}ms, "
          f"O(V+E) sweep {sweep_ms:.2f}ms, {out['speedup']:.1f}x faster",
          file=sys.stderr)
    return out


def _virtual_mode_trajectory(smoke: bool) -> dict:
    """The PR 3 acceptance record: fig07's 512-leaf tree reduction under
    the virtual clock — two seeded runs must produce identical results /
    charged_ms / simulated makespan, and the virtual run must beat the
    seed ``SIM_SCALE=0.1`` real-time path by >= 10x wall time. Recorded
    in BENCH_results.json; asserted under ``--smoke``."""
    import time as _t

    from repro.apps import tree_reduction_dag
    from repro.core import CostModel, EngineConfig, WukongEngine

    # 512 leaves, 12 s tasks along a 10-level critical path, 1 MB edge
    # payloads (the fig07 shape): ~120 s of simulated time. The virtual
    # run's wall time is flat in task duration (same event count), the
    # real-time run's scales with it — exactly the decoupling the
    # virtual clock exists to provide.
    dag = tree_reduction_dag(1024, compute_ms=12000.0,
                             payload_bytes=1 << 20)

    def run_once(time_scale: float):
        eng = WukongEngine(EngineConfig(cost=CostModel(
            time_scale=time_scale)))
        t0 = _t.perf_counter()
        rep = eng.compute(dag)
        elapsed = _t.perf_counter() - t0
        (_, root), = rep.results.items()
        return {"elapsed_s": elapsed, "sim_wall_s": rep.wall_s,
                "charged_ms": rep.charged_ms, "root": float(root[0])}

    v1 = run_once(0.0)
    v2 = run_once(0.0)
    rt = run_once(0.1)  # the seed real-time path (SIM_SCALE=0.1)
    deterministic = (v1["charged_ms"] == v2["charged_ms"]
                     and v1["sim_wall_s"] == v2["sim_wall_s"]
                     and v1["root"] == v2["root"])
    speedup = rt["elapsed_s"] / min(v1["elapsed_s"], v2["elapsed_s"])
    out = {
        "workload": "fig07 512-leaf TR, 12000ms tasks, 1MB payloads",
        "virtual_wall_s": min(v1["elapsed_s"], v2["elapsed_s"]),
        "virtual_sim_makespan_s": v1["sim_wall_s"],
        "virtual_charged_ms": v1["charged_ms"],
        "realtime_wall_s": rt["elapsed_s"],
        "speedup_vs_realtime": speedup,
        "deterministic": deterministic,
    }
    print(f"# virtual clock (512-leaf TR): sim makespan "
          f"{v1['sim_wall_s']:.1f}s in {out['virtual_wall_s']:.2f}s wall; "
          f"seed real-time path {rt['elapsed_s']:.2f}s wall -> "
          f"{speedup:.1f}x; deterministic={deterministic}",
          file=sys.stderr)
    if smoke:
        if not deterministic:
            raise SystemExit(
                "virtual-clock regression: two identical runs diverged "
                f"({v1} vs {v2})")
        if speedup < 10.0:
            raise SystemExit(
                f"virtual-clock regression: only {speedup:.1f}x over the "
                "seed real-time path (>= 10x required)")
    return out


def _check_platform_gate(rows_by_fig: dict, smoke_kwargs: dict) -> None:
    """CI regression gate for the stateful platform model:

    - *determinism*: re-running the fig14 warm/cold smoke workload must
      reproduce the recorded run bit-identically — ``platform_stats``
      (including billed USD), charged ms, and simulated makespan;
    - *warm pool pays*: container reuse must strictly lower the charged
      simulated latency relative to the all-cold (keep_alive=0) pool.
    """
    from benchmarks import common, fig14_platform

    if common.SIM_SCALE > 0:
        # Bit-identity is a virtual-clock property; under the real-time
        # cross-check mode wall_s is real elapsed time and thread timing
        # perturbs the throttle/pool counters.
        print("# platform gate skipped (real-time mode)", file=sys.stderr)
        return
    rows = {r["label"]: r for r in rows_by_fig.get("fig14", [])}
    warm, cold = rows.get("warm_pool"), rows.get("cold_pool")
    if warm is None or cold is None:
        return
    warm2, cold2 = fig14_platform.warm_cold_pair(
        n=smoke_kwargs["n"], compute_ms=smoke_kwargs["compute_ms"],
        lanes=smoke_kwargs["pool_lanes"])
    for first, second in ((warm, warm2), (cold, cold2)):
        for field in ("platform_stats", "charged_ms", "wall_s"):
            if first[field] != second[field]:
                raise SystemExit(
                    f"platform regression: {first['label']} not "
                    f"deterministic across runs — {field} "
                    f"{first[field]!r} != {second[field]!r}")
    if not warm["charged_ms"] < cold["charged_ms"]:
        raise SystemExit(
            f"platform regression: warm pool charged "
            f"{warm['charged_ms']:.1f}ms, not strictly below the "
            f"all-cold pool's {cold['charged_ms']:.1f}ms")
    ps = warm["platform_stats"]
    if not ps["warm_reuses"] > 0:
        raise SystemExit("platform regression: warm pool saw no reuse")
    saved = (1 - warm["charged_ms"] / cold["charged_ms"]) * 100
    print(f"# platform gate OK: deterministic billed "
          f"${ps['billed_usd']:.6f}; warm pool charged "
          f"{warm['charged_ms']:.1f}ms vs cold {cold['charged_ms']:.1f}ms "
          f"({saved:.1f}% saved, {ps['warm_reuses']} reuses)",
          file=sys.stderr)


def _check_multitenant_gate(rows_by_fig: dict, smoke_kwargs: dict) -> None:
    """CI regression gate for the multi-tenant orchestrator (fig15):

    - *scale*: the smoke workload must run >= 32 jobs from >= 4 tenants
      on one shared platform;
    - *determinism*: re-running the shared/isolated smoke pair must
      reproduce the recorded rows bit-identically — latency percentiles
      AND per-tenant billed USD;
    - *pooling pays*: the shared warm pool's p50 job latency must be
      strictly below the isolated-per-job baseline's.
    """
    from benchmarks import common, fig15_multitenant

    if common.SIM_SCALE > 0:
        print("# multitenant gate skipped (real-time mode)", file=sys.stderr)
        return
    rows = {r["label"]: r for r in rows_by_fig.get("fig15", [])}
    rate = smoke_kwargs["rates"][0]
    n_tenants = 4
    shared = rows.get(f"shared_pool_r{rate:g}_t{n_tenants}")
    isolated = rows.get(f"isolated_per_job_r{rate:g}_t{n_tenants}")
    if shared is None or isolated is None:
        return
    ps = shared["platform_stats"]
    if ps["jobs"] < 32 or len(ps["per_tenant"]) < 4:
        raise SystemExit(
            f"multitenant regression: smoke ran only {ps['jobs']} jobs "
            f"from {len(ps['per_tenant'])} tenants (>=32 from >=4 required)")
    if ps["failed"]:
        raise SystemExit(
            f"multitenant regression: {ps['failed']} smoke jobs failed")
    shared2, isolated2 = fig15_multitenant.shared_isolated_pair(
        n_jobs=smoke_kwargs["n_jobs"], rate=rate, n_tenants=n_tenants,
        max_concurrent_jobs=smoke_kwargs["max_concurrent_jobs"])
    for first, second in ((shared, shared2), (isolated, isolated2)):
        for field in ("wall_s", "p50_s", "p95_s", "p99_s",
                      "per_tenant_billed", "platform_stats"):
            if first[field] != second[field]:
                raise SystemExit(
                    f"multitenant regression: {first['label']} not "
                    f"deterministic across runs — {field} "
                    f"{first[field]!r} != {second[field]!r}")
    if not shared["p50_s"] < isolated["p50_s"]:
        raise SystemExit(
            f"multitenant regression: shared pool p50 {shared['p50_s']:.3f}s "
            f"not strictly below isolated-per-job {isolated['p50_s']:.3f}s")
    print(f"# multitenant gate OK: {ps['jobs']} jobs/"
          f"{len(ps['per_tenant'])} tenants deterministic; shared p50 "
          f"{shared['p50_s']:.3f}s vs isolated {isolated['p50_s']:.3f}s "
          f"(warm share {ps['warm_share'] * 100:.0f}% vs "
          f"{isolated['platform_stats']['warm_share'] * 100:.0f}%)",
          file=sys.stderr)


def _check_dataplane_gate(rows_by_fig: dict) -> None:
    """CI regression gate: on the smoke workload the optimized data
    plane (striping + batched round trips) must not be charged more
    simulated ms than the PR 1 data plane it replaced."""
    rows = rows_by_fig.get("fig08", [])
    striped = [r["charged_ms"] for r in rows
               if r["label"].startswith("wukong_striped@")]
    unstriped = [r["charged_ms"] for r in rows
                 if r["label"].startswith("wukong_unstriped@")]
    if not striped or not unstriped:
        return
    s, u = min(striped), min(unstriped)
    if s > u:
        raise SystemExit(
            f"data-plane regression: optimized Wukong charged {s:.1f}ms > "
            f"unoptimized {u:.1f}ms on the fig08 smoke workload"
        )
    saved = (1 - s / u) * 100
    print(f"# data-plane gate OK: charged {s:.1f}ms vs {u:.1f}ms "
          f"({saved:.1f}% saved)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, near-zero simulated latency; "
                         "engine-regression gate for CI")
    ap.add_argument("--only", default=None, help="comma list, e.g. fig07")
    args = ap.parse_args()

    from benchmarks import (
        fig04_design_iterations,
        fig07_tree_reduction,
        fig08_gemm,
        fig09_svd_tall,
        fig10_svd_square,
        fig11_svc,
        fig12_factor_analysis,
        fig13_task_cdf,
        fig14_platform,
        fig15_multitenant,
        fig16_scaling,
        fig17_recovery,
        fig18_locality,
        fig19_streaming,
    )
    from benchmarks import common

    # One row per figure: (run fn, smoke kwargs, quick kwargs, full kwargs).
    # Adding a figure here covers all three modes, including CI's
    # bench-smoke gate.
    figs = {
        "fig04": (fig04_design_iterations.run,
                  dict(n=32, delays_ms=(0.0,)),
                  dict(n=128, delays_ms=(0.0, 50.0)),
                  dict(n=512, delays_ms=(0.0, 50.0, 100.0))),
        "fig07": (fig07_tree_reduction.run,
                  dict(n=32, delays_ms=(0.0,)),
                  dict(n=128, delays_ms=(0.0, 250.0)),
                  dict(n=512, delays_ms=(0.0, 250.0, 500.0))),
        "fig08": (fig08_gemm.run,
                  dict(sizes=((256, 128),)),
                  dict(sizes=((512, 128),)),
                  dict(sizes=((512, 128), (1024, 128), (2048, 128)))),
        "fig09": (fig09_svd_tall.run,
                  dict(row_sizes=(1024,)),
                  dict(row_sizes=(4096,)),
                  dict(row_sizes=(4096, 8192, 16384))),
        "fig10": (fig10_svd_square.run,
                  dict(sizes=(256,)),
                  dict(sizes=(512,)),
                  dict(sizes=(512, 1024, 2048, 4096))),
        "fig11": (fig11_svc.run,
                  dict(sample_sizes=(2048,)),
                  dict(sample_sizes=(8192,)),
                  dict(sample_sizes=(8192, 32768, 131072))),
        "fig12": (fig12_factor_analysis.run,
                  dict(n=32), dict(n=128), dict(n=512)),
        "fig13": (fig13_task_cdf.run,
                  dict(n=256), dict(n=1024), dict(n=2048)),
        "fig14": (fig14_platform.run,
                  dict(n=32, compute_ms=5.0, memory_sweep=(896, 1792),
                       pool_cap=4, pool_lanes=4, fanout_n=64,
                       fanout_burst=8, fanout_cap=16),
                  dict(n=128, compute_ms=100.0,
                       memory_sweep=(1024, 1792, 3584), pool_cap=16,
                       pool_lanes=8, fanout_n=512, fanout_burst=64,
                       fanout_cap=128),
                  dict()),
        "fig15": (fig15_multitenant.run,
                  dict(n_jobs=32, rates=(4.0,), tenant_counts=(4,),
                       max_concurrent_jobs=32),
                  dict(n_jobs=64, rates=(2.0, 8.0), tenant_counts=(2, 4),
                       max_concurrent_jobs=32),
                  dict()),
        # The substrate scaling curve (PR 6). Smoke = the CI gate tiers
        # (>= 5x substrate speedup at 4096 leaves, 10^5 engine tasks
        # < 30 s); full adds the 10^6-task event-only tier.
        "fig16": (fig16_scaling.run,
                  dict(),
                  dict(),
                  dict(micro_leaves=(1024, 4096, 16384),
                       engine_tiers=((8192, True), (131072, False),
                                     (1 << 20, False)))),
        # Crash-recovery cost curves (durable control plane). The smoke
        # sweep crashes the dispatcher at all three protocol points on
        # BOTH simulation substrates and gates on journal billing parity.
        "fig17": (fig17_recovery.run,
                  dict(n_jobs=12, rate=8.0, crash_ats=(2,),
                       substrates=("event", "thread"),
                       max_concurrent_jobs=4),
                  dict(n_jobs=32, rate=8.0, crash_ats=(1, 4),
                       substrates=("event", "thread"),
                       max_concurrent_jobs=8),
                  dict(n_jobs=64, crash_ats=(1, 4, 16))),
        # Locality series (multi-tier container cache vs cacheless) on
        # the two data-intensive shapes. Smoke = the CI locality gate
        # (cache strictly cheaper, tier-0 hits > 0, bit-identical
        # across runs and substrates); full adds a capacity sweep.
        "fig18": (fig18_locality.run,
                  dict(gemm_sizes=((512, 128),), tree_n=256),
                  dict(gemm_sizes=((512, 128),), tree_n=512),
                  dict(gemm_sizes=((512, 128), (1024, 128)), tree_n=1024,
                       capacities=(1 << 20, 4 << 20, 16 << 20))),
        # Steady-state streaming via the trigger bus (event-fired jobs,
        # windowed aggregation, dynamic-DAG parity, mid-stream crash).
        # Smoke = the CI streaming gate: >= 64 window jobs, all four
        # trigger sources live, bit-identical metrics across runs and
        # substrates, exactly-once fires across a dispatcher crash.
        "fig19": (fig19_streaming.run,
                  dict(n_events=400, crash_ats=(12,),
                       substrates=("event", "thread")),
                  dict(n_events=400, crash_ats=(12, 40),
                       substrates=("event", "thread")),
                  dict(n_events=1200, crash_ats=(12, 40, 120),
                       substrates=("event", "thread"), parity_n=64)),
    }
    mode = 0 if args.smoke else (1 if args.quick else 2)
    only = set(args.only.split(",")) if args.only else None
    rows_by_fig: dict[str, list[dict]] = {}
    print("name,us_per_call,derived")
    for name, (fn, *kwargs_by_mode) in figs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        rows = fn(**kwargs_by_mode[mode])
        rows_by_fig[name] = rows
        common.emit(rows, name)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)

    snapshot = {
        "mode": ("smoke" if args.smoke else "quick" if args.quick else "full"),
        "sim_scale": common.SIM_SCALE,
        "clock": "virtual" if common.SIM_SCALE == 0 else "realtime",
        "schedule_generation": _time_schedule_generation(),
        "figures": {
            name: {r["label"]: _json_row(r) for r in rows}
            for name, rows in rows_by_fig.items()
        },
    }
    if "fig16" in rows_by_fig:
        # tasks vs host wall seconds, both substrates where feasible —
        # the PR 6 acceptance record (fig16's wall_s is HOST seconds,
        # unlike the simulated wall_s of every other figure).
        snapshot["scaling_curve"] = fig16_scaling.scaling_curve(
            rows_by_fig["fig16"])
    if only is None:
        # The trajectory's real-time leg costs ~12 s of genuine sleeping;
        # skip it when a dev is iterating on a single figure via --only.
        snapshot["virtual_mode"] = _virtual_mode_trajectory(smoke=args.smoke)
    path = os.path.normpath(RESULTS_JSON)
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)

    if args.smoke:
        _check_dataplane_gate(rows_by_fig)
        _check_platform_gate(rows_by_fig, figs["fig14"][1])
        _check_multitenant_gate(rows_by_fig, figs["fig15"][1])
        if "fig16" in rows_by_fig:
            fig16_scaling.check_gates(rows_by_fig["fig16"])
        if "fig17" in rows_by_fig:
            fig17_recovery.check_gates(rows_by_fig["fig17"])
        if "fig18" in rows_by_fig:
            fig18_locality.check_gates(rows_by_fig["fig18"],
                                       **figs["fig18"][1])
        if "fig19" in rows_by_fig:
            fig19_streaming.check_gates(rows_by_fig["fig19"])


if __name__ == "__main__":
    main()
