"""Benchmark entry point: one module per paper figure.

Prints ``name,us_per_call,derived`` CSV rows. Scale-down knobs:
``REPRO_SIM_SCALE`` (simulated-latency multiplier) and ``--quick``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI)")
    ap.add_argument("--only", default=None, help="comma list, e.g. fig07")
    args = ap.parse_args()

    from benchmarks import (
        fig04_design_iterations,
        fig07_tree_reduction,
        fig08_gemm,
        fig09_svd_tall,
        fig10_svd_square,
        fig11_svc,
        fig12_factor_analysis,
        fig13_task_cdf,
    )
    from benchmarks import common

    figs = {
        "fig04": lambda: fig04_design_iterations.run(
            n=128 if args.quick else 512,
            delays_ms=(0.0, 50.0) if args.quick else (0.0, 50.0, 100.0)),
        "fig07": lambda: fig07_tree_reduction.run(
            n=128 if args.quick else 512,
            delays_ms=(0.0, 250.0) if args.quick else (0.0, 250.0, 500.0)),
        "fig08": lambda: fig08_gemm.run(
            sizes=((512, 128),) if args.quick
            else ((512, 128), (1024, 128), (2048, 128))),
        "fig09": lambda: fig09_svd_tall.run(
            row_sizes=(4096,) if args.quick else (4096, 8192, 16384)),
        "fig10": lambda: fig10_svd_square.run(
            sizes=(512,) if args.quick else (512, 1024, 2048, 4096)),
        "fig11": lambda: fig11_svc.run(
            sample_sizes=(8192,) if args.quick else (8192, 32768, 131072)),
        "fig12": lambda: fig12_factor_analysis.run(
            n=128 if args.quick else 512),
        "fig13": lambda: fig13_task_cdf.run(n=1024 if args.quick else 2048),
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, fn in figs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        rows = fn()
        common.emit(rows, name)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
