"""Benchmark entry point: one module per paper figure.

Prints ``name,us_per_call,derived`` CSV rows. Scale-down knobs:
``REPRO_SIM_SCALE`` (simulated-latency multiplier), ``--quick`` (smaller
problem sizes), and ``--smoke`` (toy sizes + near-zero simulated latency;
a CI regression gate that executes every figure's engines end-to-end in
seconds, checking they complete rather than how fast they run).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, near-zero simulated latency; "
                         "engine-regression gate for CI")
    ap.add_argument("--only", default=None, help="comma list, e.g. fig07")
    args = ap.parse_args()

    if args.smoke:
        # Must be set before benchmarks.common is imported (it reads the
        # env at import time).
        os.environ.setdefault("REPRO_SIM_SCALE", "0.001")

    from benchmarks import (
        fig04_design_iterations,
        fig07_tree_reduction,
        fig08_gemm,
        fig09_svd_tall,
        fig10_svd_square,
        fig11_svc,
        fig12_factor_analysis,
        fig13_task_cdf,
    )
    from benchmarks import common

    # One row per figure: (run fn, smoke kwargs, quick kwargs, full kwargs).
    # Adding a figure here covers all three modes, including CI's
    # bench-smoke gate.
    figs = {
        "fig04": (fig04_design_iterations.run,
                  dict(n=32, delays_ms=(0.0,)),
                  dict(n=128, delays_ms=(0.0, 50.0)),
                  dict(n=512, delays_ms=(0.0, 50.0, 100.0))),
        "fig07": (fig07_tree_reduction.run,
                  dict(n=32, delays_ms=(0.0,)),
                  dict(n=128, delays_ms=(0.0, 250.0)),
                  dict(n=512, delays_ms=(0.0, 250.0, 500.0))),
        "fig08": (fig08_gemm.run,
                  dict(sizes=((256, 128),)),
                  dict(sizes=((512, 128),)),
                  dict(sizes=((512, 128), (1024, 128), (2048, 128)))),
        "fig09": (fig09_svd_tall.run,
                  dict(row_sizes=(1024,)),
                  dict(row_sizes=(4096,)),
                  dict(row_sizes=(4096, 8192, 16384))),
        "fig10": (fig10_svd_square.run,
                  dict(sizes=(256,)),
                  dict(sizes=(512,)),
                  dict(sizes=(512, 1024, 2048, 4096))),
        "fig11": (fig11_svc.run,
                  dict(sample_sizes=(2048,)),
                  dict(sample_sizes=(8192,)),
                  dict(sample_sizes=(8192, 32768, 131072))),
        "fig12": (fig12_factor_analysis.run,
                  dict(n=32), dict(n=128), dict(n=512)),
        "fig13": (fig13_task_cdf.run,
                  dict(n=256), dict(n=1024), dict(n=2048)),
    }
    mode = 0 if args.smoke else (1 if args.quick else 2)
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, (fn, *kwargs_by_mode) in figs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        rows = fn(**kwargs_by_mode[mode])
        common.emit(rows, name)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
