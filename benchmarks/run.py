"""Benchmark entry point: one module per paper figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a
``BENCH_results.json`` snapshot (engine -> wall_s / charged_ms /
kv_stats per figure) at the repo root so the perf trajectory is tracked
across PRs. Scale-down knobs: ``REPRO_SIM_SCALE`` (simulated-latency
multiplier), ``--quick`` (smaller problem sizes), and ``--smoke`` (toy
sizes + near-zero simulated latency; a CI regression gate that executes
every figure's engines end-to-end in seconds, checking they complete
rather than how fast they run — plus a data-plane gate asserting the
optimized WUKONG config is not charged more than the unoptimized one).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

RESULTS_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_results.json"
)


def _json_row(row: dict) -> dict:
    """The per-PR trajectory record for one engine/config series."""
    return {
        "wall_s": row["wall_s"],
        "charged_ms": row.get("charged_ms"),
        "kv_stats": row.get("kv_stats"),
        "tasks": row.get("tasks"),
        "executors": row.get("executors"),
    }


def _time_schedule_generation() -> dict:
    """Host-side hot path trajectory: O(V+E) sweep vs the paper's
    per-leaf DFS on a 512-leaf tree reduction (printed + recorded in
    BENCH_results.json so regressions are visible across PRs)."""
    import gc
    import time as _t

    from repro.apps import tree_reduction_dag
    from repro.core.optimize import compile_dag
    from repro.core.schedule import (
        generate_static_schedules,
        generate_static_schedules_dfs,
    )

    dag = compile_dag(tree_reduction_dag(1024))  # 512 leaves

    # Interleave the two implementations so drifting background load
    # lands on both equally (serial best-of-N loops skew the ratio
    # whenever the machine quiets down between them).
    dfs_ts, sweep_ts = [], []
    gc.disable()
    try:
        for _ in range(20):
            t0 = _t.perf_counter()
            generate_static_schedules_dfs(dag)
            dfs_ts.append(_t.perf_counter() - t0)
            t0 = _t.perf_counter()
            generate_static_schedules(dag)
            sweep_ts.append(_t.perf_counter() - t0)
    finally:
        gc.enable()
    dfs_ms = min(dfs_ts) * 1e3
    sweep_ms = min(sweep_ts) * 1e3
    out = {"leaves": 512, "dfs_ms": dfs_ms, "sweep_ms": sweep_ms,
           "speedup": dfs_ms / sweep_ms}
    print(f"# schedule-gen (512-leaf TR): per-leaf DFS {dfs_ms:.2f}ms, "
          f"O(V+E) sweep {sweep_ms:.2f}ms, {out['speedup']:.1f}x faster",
          file=sys.stderr)
    return out


def _check_dataplane_gate(rows_by_fig: dict) -> None:
    """CI regression gate: on the smoke workload the optimized data
    plane (striping + batched round trips) must not be charged more
    simulated ms than the PR 1 data plane it replaced."""
    rows = rows_by_fig.get("fig08", [])
    striped = [r["charged_ms"] for r in rows
               if r["label"].startswith("wukong_striped@")]
    unstriped = [r["charged_ms"] for r in rows
                 if r["label"].startswith("wukong_unstriped@")]
    if not striped or not unstriped:
        return
    s, u = min(striped), min(unstriped)
    if s > u:
        raise SystemExit(
            f"data-plane regression: optimized Wukong charged {s:.1f}ms > "
            f"unoptimized {u:.1f}ms on the fig08 smoke workload"
        )
    saved = (1 - s / u) * 100
    print(f"# data-plane gate OK: charged {s:.1f}ms vs {u:.1f}ms "
          f"({saved:.1f}% saved)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem sizes (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, near-zero simulated latency; "
                         "engine-regression gate for CI")
    ap.add_argument("--only", default=None, help="comma list, e.g. fig07")
    args = ap.parse_args()

    if args.smoke:
        # Must be set before benchmarks.common is imported (it reads the
        # env at import time).
        os.environ.setdefault("REPRO_SIM_SCALE", "0.001")

    from benchmarks import (
        fig04_design_iterations,
        fig07_tree_reduction,
        fig08_gemm,
        fig09_svd_tall,
        fig10_svd_square,
        fig11_svc,
        fig12_factor_analysis,
        fig13_task_cdf,
    )
    from benchmarks import common

    # One row per figure: (run fn, smoke kwargs, quick kwargs, full kwargs).
    # Adding a figure here covers all three modes, including CI's
    # bench-smoke gate.
    figs = {
        "fig04": (fig04_design_iterations.run,
                  dict(n=32, delays_ms=(0.0,)),
                  dict(n=128, delays_ms=(0.0, 50.0)),
                  dict(n=512, delays_ms=(0.0, 50.0, 100.0))),
        "fig07": (fig07_tree_reduction.run,
                  dict(n=32, delays_ms=(0.0,)),
                  dict(n=128, delays_ms=(0.0, 250.0)),
                  dict(n=512, delays_ms=(0.0, 250.0, 500.0))),
        "fig08": (fig08_gemm.run,
                  dict(sizes=((256, 128),)),
                  dict(sizes=((512, 128),)),
                  dict(sizes=((512, 128), (1024, 128), (2048, 128)))),
        "fig09": (fig09_svd_tall.run,
                  dict(row_sizes=(1024,)),
                  dict(row_sizes=(4096,)),
                  dict(row_sizes=(4096, 8192, 16384))),
        "fig10": (fig10_svd_square.run,
                  dict(sizes=(256,)),
                  dict(sizes=(512,)),
                  dict(sizes=(512, 1024, 2048, 4096))),
        "fig11": (fig11_svc.run,
                  dict(sample_sizes=(2048,)),
                  dict(sample_sizes=(8192,)),
                  dict(sample_sizes=(8192, 32768, 131072))),
        "fig12": (fig12_factor_analysis.run,
                  dict(n=32), dict(n=128), dict(n=512)),
        "fig13": (fig13_task_cdf.run,
                  dict(n=256), dict(n=1024), dict(n=2048)),
    }
    mode = 0 if args.smoke else (1 if args.quick else 2)
    only = set(args.only.split(",")) if args.only else None
    rows_by_fig: dict[str, list[dict]] = {}
    print("name,us_per_call,derived")
    for name, (fn, *kwargs_by_mode) in figs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        rows = fn(**kwargs_by_mode[mode])
        rows_by_fig[name] = rows
        common.emit(rows, name)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)

    snapshot = {
        "mode": ("smoke" if args.smoke else "quick" if args.quick else "full"),
        "sim_scale": common.SIM_SCALE,
        "schedule_generation": _time_schedule_generation(),
        "figures": {
            name: {r["label"]: _json_row(r) for r in rows}
            for name, rows in rows_by_fig.items()
        },
    }
    path = os.path.normpath(RESULTS_JSON)
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)

    if args.smoke:
        _check_dataplane_gate(rows_by_fig)


if __name__ == "__main__":
    main()
