"""Fig. 4: design-iteration comparison on Tree Reduction.

Paper claims: (a) parallel-invoker executes TR ~24% faster than strawman/
pub-sub at 0ms delay (invocation-bound, 512 leaf tasks); (b) pub/sub pulls
ahead of strawman as task duration grows (fewer TCP round-trips).

Beyond-paper series: ``parallel_invoker+opt`` runs the best centralized
iteration behind the DAG compiler (repro.core.optimize) — an
optimized-vs-unoptimized pairing; TR has no fusible chains, so this also
bounds the compiler's overhead on a pass-neutral graph.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import tree_reduction_dag


def run(n: int = 512, delays_ms=(0.0, 50.0, 100.0)) -> list[dict]:
    rows = []
    engines = [
        ("strawman", common.strawman()),
        ("pubsub", common.pubsub()),
        ("parallel_invoker", common.parallel_invoker()),
        ("parallel_invoker+opt", common.parallel_invoker_optimized()),
    ]
    for delay in delays_ms:
        for label, eng in engines:
            dag = tree_reduction_dag(n, compute_ms=delay,
                                     payload_bytes=1 << 20)
            r = common.timed(eng, dag)
            r["label"] = f"{label}@{delay:g}ms"
            r["derived"] = f"delay={delay:g}ms"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig04")


if __name__ == "__main__":
    main()
