"""Fig. 13: per-task latency breakdown CDF for SVD2.

Paper claims: most tasks see negligible KV time but a long tail of
multi-second reads/writes of large intermediates dominates job time.
We print read/compute/write percentiles from the executor metrics.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.apps import randomized_svd_dag


def run(n: int = 2048, n_blocks: int = 8) -> list[dict]:
    eng = common.wukong()
    dag = randomized_svd_dag(n, 5, 5, n_blocks)
    r = common.timed(eng, dag)
    recs = [m for m in r["metrics"] if m.get("event") == "executed"]
    rows = []
    for field in ("read_ms", "compute_ms", "write_ms"):
        vals = np.array([m.get(field, 0.0) for m in recs])
        for p in (50, 90, 99, 100):
            rows.append({
                "label": f"{field}_p{p}",
                "wall_s": float(np.percentile(vals, p)) / 1e3,
                "derived": f"n_tasks={len(recs)}",
            })
    return rows


def main() -> None:
    common.emit(run(), "fig13")


if __name__ == "__main__":
    main()
