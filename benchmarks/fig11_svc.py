"""Fig. 11: SVC (hinge-loss linear SVM) with growing sample counts.

Paper claims: Dask (EC2) slightly faster at the smallest size; WUKONG
overtakes as samples grow, ~2x at the largest.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import svc_dag


def run(sample_sizes=(8192, 32768, 131072), n_blocks: int = 16,
        n_iters: int = 3) -> list[dict]:
    rows = []
    for n in sample_sizes:
        for label, eng in [
            ("wukong", common.wukong()),
            ("dask_ec2", common.serverful_ec2()),
            ("dask_laptop", common.serverful_laptop()),
        ]:
            dag = svc_dag(n, n_blocks, n_iters, ms_per_flop=common.ms_per_flop())
            r = common.timed(eng, dag)
            r["label"] = f"{label}@n={n}"
            r["derived"] = f"iters={n_iters}"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig11")


if __name__ == "__main__":
    main()
