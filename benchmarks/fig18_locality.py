"""Fig. 18 (beyond-paper): locality-enhanced executors — the multi-tier
container cache (memory → disk-spill → shared KV) vs the cacheless data
plane.

The paper attributes Wukong's headline speedup on real DAG jobs to
*locality enhancement* (§IV-C, §V-B): executors keep intermediates close
and schedule their own children instead of round-tripping every
cross-executor edge through remote storage. This figure measures that
claim's storage half on the emulated data-intensive regime (the fig08
5 MB/s KV lanes): the same DAG runs cacheless, with a memory-only
container cache, and with memory+disk tiers — identical results, but
tier-0/1 hits turn remote transfers into local (free / disk-bandwidth)
reads, so charged simulated ms drops.

Shapes:

- *GEMM* (fig08): every A/B input block feeds ``b`` multiply tasks, so
  read-through caching + hint-steered warm placement serve the shared
  blocks locally after their first fetch.
- *tree reduction* (fig07, 1 MB payloads): no shared inputs — the wins
  come purely from warm containers that carry a dead walk's deposited
  outputs to the later invocation that needs them. Run WITHOUT the
  coalescing passes: coalescing already resolves sibling fan-ins inside
  one executor's memory, which is the same locality captured a layer
  earlier (the cached/cacheless pair isolates the cache, not the
  optimizer).

Full mode adds a tier-0 capacity sweep on GEMM (how small can the
container memory get before spills eat the win).

``check_gates`` is the CI locality gate (run.py --smoke): cached charged
ms strictly below cacheless on BOTH shapes, tier-0 hit rate > 0, and
every arm bit-identical across re-runs and across the event/thread
substrates.
"""
from __future__ import annotations

import sys
from typing import Any

from benchmarks import common
from repro.apps import gemm_dag, tree_reduction_dag
from repro.core import ALL_PASSES, NO_PASSES, CacheConfig

ARMS = (
    ("cacheless", None),
    ("cached_mem", CacheConfig(disk_bytes=0)),
    ("cached_mem_disk", CacheConfig()),
)


def _shapes(gemm_sizes, tree_n) -> "list[tuple]":
    shapes: "list[tuple]" = []
    for n, bs in gemm_sizes:
        shapes.append((f"gemm@n={n}", lambda n=n, bs=bs: gemm_dag(n, bs),
                       ALL_PASSES, 8, f"blocks={(n // bs) ** 2}"))
    shapes.append((
        f"tree@n={tree_n}",
        lambda: tree_reduction_dag(tree_n, payload_bytes=1 << 20,
                                   compute_ms=5.0),
        NO_PASSES, 4, f"leaves={tree_n // 2},payload=1MB"))
    return shapes


def _row(label: str, rep: Any, derived: str) -> dict:
    cs = rep.cache_stats
    lookups = cs.get("mem_hits", 0) + cs.get("disk_hits", 0) \
        + cs.get("misses", 0)
    return {
        "label": label,
        "wall_s": rep.wall_s,
        "charged_ms": rep.charged_ms,
        "tasks": rep.tasks,
        "executors": rep.executors_invoked,
        "kv_stats": rep.kv_stats,
        "platform_stats": rep.platform_stats,
        "cache_stats": cs,
        "hit_rate": (cs.get("mem_hits", 0) / lookups) if lookups else 0.0,
        "bytes_local": cs.get("bytes_local", 0),
        "derived": derived,
    }


def run(gemm_sizes=((512, 128),), tree_n=256, capacities=(),
        substrate: "str | None" = None) -> "list[dict]":
    rows = []
    for shape, dag_fn, opt, invokers, derived in _shapes(gemm_sizes,
                                                         tree_n):
        dag = dag_fn()
        for arm, cache in ARMS:
            eng = common.wukong_locality(cache=cache, optimize=opt,
                                         invokers=invokers,
                                         substrate=substrate)
            rows.append(_row(f"{arm}/{shape}", eng.compute(dag), derived))
    # Capacity sweep (full mode): how small can tier 0 get on GEMM
    # before eviction/spill traffic eats the locality win.
    for cap in capacities:
        dag = gemm_dag(*gemm_sizes[0])
        eng = common.wukong_locality(
            cache=CacheConfig(memory_bytes=cap), optimize=ALL_PASSES,
            substrate=substrate)
        rows.append(_row(f"cached_cap{cap >> 20}MB/gemm@n={gemm_sizes[0][0]}",
                         eng.compute(dag), f"memory_bytes={cap}"))
    return rows


def check_gates(rows: "list[dict]", gemm_sizes=((512, 128),),
                tree_n=256) -> None:
    """CI locality gate (run.py --smoke):

    - *cache pays*: each cached arm's charged simulated ms is strictly
      below the cacheless baseline on BOTH data-intensive shapes;
    - *tier 0 works*: the cached arms' tier-0 hit rate is > 0;
    - *determinism*: re-running the smoke sweep — and running it on the
      thread substrate — reproduces every arm bit-identically
      (charged ms, wall s, cache_stats, KV counters).
    """
    if common.SIM_SCALE > 0:
        print("# locality gate skipped (real-time mode)", file=sys.stderr)
        return
    recorded = {r["label"]: r for r in rows}
    for substrate in ("event", "thread"):
        again = run(gemm_sizes=gemm_sizes, tree_n=tree_n,
                    substrate=substrate)
        for row in again:
            first = recorded.get(row["label"])
            if first is None:
                continue
            for field in ("charged_ms", "wall_s", "cache_stats",
                          "kv_stats"):
                if first[field] != row[field]:
                    raise SystemExit(
                        f"locality regression: {row['label']} not "
                        f"bit-identical on the {substrate} substrate — "
                        f"{field} {first[field]!r} != {row[field]!r}")
    shapes = {label.split("/", 1)[1] for label in recorded}
    for shape in sorted(shapes):
        base = recorded.get(f"cacheless/{shape}")
        if base is None:
            continue
        for arm in ("cached_mem", "cached_mem_disk"):
            cached = recorded.get(f"{arm}/{shape}")
            if cached is None:
                continue
            if not cached["charged_ms"] < base["charged_ms"]:
                raise SystemExit(
                    f"locality regression: {arm}/{shape} charged "
                    f"{cached['charged_ms']:.1f}ms, not strictly below "
                    f"the cacheless {base['charged_ms']:.1f}ms")
            if not cached["cache_stats"]["mem_hits"] > 0:
                raise SystemExit(
                    f"locality regression: {arm}/{shape} saw no tier-0 "
                    f"hits")
        cached = recorded[f"cached_mem_disk/{shape}"]
        saved = (1 - cached["charged_ms"] / base["charged_ms"]) * 100
        cs = cached["cache_stats"]
        print(f"# locality gate OK [{shape}]: charged "
              f"{cached['charged_ms']:.1f}ms vs cacheless "
              f"{base['charged_ms']:.1f}ms ({saved:.1f}% saved, "
              f"hit rate {cached['hit_rate'] * 100:.0f}%, "
              f"{cs['bytes_local'] >> 10} KiB served locally)",
              file=sys.stderr)


def main() -> None:
    common.emit(run(), "fig18")


if __name__ == "__main__":
    main()
