"""Fig. 9: SVD of tall-and-skinny matrices (TSQR), growing row counts.

Paper claims: both WUKONG and Dask (EC2) dwarf the laptop; Dask (EC2)
wins small sizes, WUKONG overtakes as rows grow (parallelism outweighs
communication).
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import tsqr_svd_dag


def run(row_sizes=(4096, 8192, 16384), cols: int = 64,
        n_blocks: int = 16) -> list[dict]:
    rows = []
    for nrows in row_sizes:
        for label, eng in [
            ("wukong", common.wukong()),
            ("dask_ec2", common.serverful_ec2()),
            ("dask_laptop", common.serverful_laptop()),
        ]:
            dag = tsqr_svd_dag(nrows, cols, n_blocks, sleep_per_flop=common.sleep_per_flop())
            r = common.timed(eng, dag)
            r["label"] = f"{label}@rows={nrows}"
            r["derived"] = f"cols={cols}"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig09")


if __name__ == "__main__":
    main()
