"""Fig. 9: SVD of tall-and-skinny matrices (TSQR), growing row counts.

Paper claims: both WUKONG and Dask (EC2) dwarf the laptop; Dask (EC2)
wins small sizes, WUKONG overtakes as rows grow (parallelism outweighs
communication).

Beyond-paper series: ``wukong_striped`` vs ``wukong_unstriped`` — the
PR 2 data-plane ablation (striping + batched round trips) in the
emulated data-intensive regime; see fig08_gemm.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import tsqr_svd_dag


def run(row_sizes=(4096, 8192, 16384), cols: int = 64,
        n_blocks: int = 16) -> list[dict]:
    rows = []
    for nrows in row_sizes:
        for label, eng in [
            ("wukong", common.wukong()),
            ("wukong_striped", common.wukong_dataplane()),
            ("wukong_unstriped", common.wukong_dataplane_off()),
            ("dask_ec2", common.serverful_ec2()),
            ("dask_laptop", common.serverful_laptop()),
        ]:
            dag = tsqr_svd_dag(nrows, cols, n_blocks, ms_per_flop=common.ms_per_flop())
            r = common.timed(eng, dag)
            r["label"] = f"{label}@rows={nrows}"
            r["derived"] = f"cols={cols}"
            rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig09")


if __name__ == "__main__":
    main()
