"""§Perf hillclimbing: hypothesis -> change -> re-lower -> validate.

Three cells (chosen from the baseline roofline table):
  1. llama3_405b/train_4k     — largest memory term; representative big
                                 dense training job.
  2. xlstm_350m/prefill_32k   — the most collective-bound cell.
  3. mixtral_8x7b/train_4k    — MoE + SWA serving-oriented arch (the
                                 family the paper's serverless serving
                                 story targets); best baseline fraction,
                                 so the closest to roofline-pushable.

Each iteration is a Variant re-compiled through the SAME dry-run +
depth-probe machinery (launch/dryrun.py), so before/after numbers come
from compiled artifacts, not estimates. The flash-attention credit is
*measured*: we compile a windowed variant and extrapolate the
S²-dependent byte term that the (interpret-validated) Pallas flash
kernel keeps in VMEM on the TPU target.

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [cell...]
"""
from __future__ import annotations

import sys

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def terms(m: dict, corr_flops: float = 0.0) -> dict:
    t = {
        "compute": (m["flops"] + corr_flops) / PEAK,
        "memory": m["bytes_accessed"] / HBM,
        "collective": m["collective_bytes"]["total"] / ICI,
    }
    t["dominant"] = max(("compute", "memory", "collective"), key=t.get)
    t["bound_s"] = t[t["dominant"]]
    return t


def show(tag: str, t: dict, model_flops: float) -> None:
    frac = (model_flops / PEAK) / t["bound_s"] * 100 if t["bound_s"] else 0
    print(f"  {tag:34s} comp={t['compute']:9.3f}s mem={t['memory']:9.3f}s "
          f"coll={t['collective']:9.3f}s dom={t['dominant']:10s} "
          f"roofline={frac:5.1f}%")


def run(arch: str, shape: str, variant_str: str):
    from repro.launch.dryrun import parse_variant, run_cell
    v = parse_variant(variant_str)
    rec = run_cell(arch, shape, multi_pod=False, variant=v, verbose=False,
                   probe=True)
    assert rec["ok"], rec.get("error")
    return rec


def cell_llama_train() -> None:
    """llama3_405b / train_4k — memory-dominated by S² score arrays."""
    from benchmarks.roofline import inner_scan_correction, \
        model_flops_per_chip
    from repro.configs import get_config
    cfg = get_config("llama3_405b")
    mf = model_flops_per_chip(cfg, "train_4k")
    print("\n=== llama3_405b / train_4k ===")
    base = run("llama3_405b", "train_4k", "baseline")
    d0 = base["probe"]["derived"]
    t0 = terms(d0)
    show("baseline (paper-faithful)", t0, mf)

    # H1: the memory term is dominated by materialized (B,S,S,H) score
    # tensors; napkin: 126L x 3passes x 256·4096²·128 x 4B /256chips
    # ≈ 1.0e15 B ≈ 60% of the 1.76e15 measured. The flash kernel keeps
    # them in VMEM. Measure the S²-term by compiling window=512.
    win = run("llama3_405b", "train_4k", "window=512")
    dw = win["probe"]["derived"]
    S, W = 4096, 512
    s2_bytes = (d0["bytes_accessed"] - dw["bytes_accessed"]) / (1 - W / S)
    t1 = dict(d0)
    t1 = {**d0, "bytes_accessed": d0["bytes_accessed"] - s2_bytes}
    tt1 = terms(t1)
    print(f"  measured S² byte term: {s2_bytes:.3e} B/chip "
          f"({100 * s2_bytes / d0['bytes_accessed']:.0f}% of memory term)")
    show("it1: +flash kernel (VMEM scores)", tt1, mf)

    # H2: MODEL/HLO = 0.36 -> full remat recomputes the whole block.
    # Selective remat (remat=0 here: save activations) trades bytes for
    # flops; napkin: flops x ~0.7.
    nr = run("llama3_405b", "train_4k", "remat=0")
    d2 = nr["probe"]["derived"]
    d2f = {**d2, "bytes_accessed": d2["bytes_accessed"] - s2_bytes}
    tt2 = terms(d2f)
    show("it2: it1 + no-remat", tt2, mf)

    # H3: microbatching reduces live activation footprint; probe at the
    # HLO level keeps bytes ~flat (scan counted once) so we report the
    # variant only as a compile-validation, not a win.
    mb = run("llama3_405b", "train_4k", "n_microbatches=4")
    print(f"  it3: microbatch=4 compiles ok "
          f"(lower/compile {mb['lower_s']}/{mb['compile_s']}s) — "
          f"memory_analysis temp {mb['memory_analysis'].get('temp_size_in_bytes', 0):.2e}B "
          f"vs baseline {base['memory_analysis'].get('temp_size_in_bytes', 0):.2e}B")


def cell_xlstm_prefill() -> None:
    """xlstm_350m / prefill_32k — the most collective-bound cell."""
    from benchmarks.roofline import inner_scan_correction, \
        model_flops_per_chip
    from repro.configs import get_config
    cfg = get_config("xlstm_350m")
    mf = model_flops_per_chip(cfg, "prefill_32k")
    corr = inner_scan_correction("xlstm_350m", "prefill_32k", cfg)
    print("\n=== xlstm_350m / prefill_32k ===")
    base = run("xlstm_350m", "prefill_32k", "baseline")
    t0 = terms(base["probe"]["derived"], corr)
    show("baseline (paper-faithful)", t0, mf)

    # H1: the dominant collective is the all-gather of full-vocab logits
    # (32 x 32768 x 50304 bf16 ≈ 0.4GB/chip after gather). Keep logits
    # vocab-sharded. Napkin: removes nearly all output-side collectives.
    it1 = run("xlstm_350m", "prefill_32k", "shard_logits=1")
    t1 = terms(it1["probe"]["derived"], corr)
    show("it1: vocab-sharded logits", t1, mf)

    # H2: 4-head mLSTM cannot shard over 16-way model axis -> TP only
    # slivers the projections and replication-gathers activations.
    # Replicate weights (350M fits trivially) and give the model axis to
    # batch: pure DP. Napkin: all remaining TP collectives vanish.
    it2 = run("xlstm_350m", "prefill_32k",
              "shard_logits=1,tensor_parallel=0")
    t2 = terms(it2["probe"]["derived"], corr)
    show("it2: it1 + no-TP (replicated weights)", t2, mf)


def cell_mixtral_train() -> None:
    """mixtral_8x7b / train_4k — MoE dispatch + score materialization."""
    from benchmarks.roofline import model_flops_per_chip
    from repro.configs import get_config
    cfg = get_config("mixtral_8x7b")
    mf = model_flops_per_chip(cfg, "train_4k")
    print("\n=== mixtral_8x7b / train_4k ===")
    base = run("mixtral_8x7b", "train_4k", "baseline")
    d0 = base["probe"]["derived"]
    t0 = terms(d0)
    show("baseline (paper-faithful)", t0, mf)

    # H1: flash credit (SWA window 4096 == S at train_4k, so scores are
    # effectively full). Measure S² term via window=512 probe.
    win = run("mixtral_8x7b", "train_4k", "window=512")
    dw = win["probe"]["derived"]
    s2 = (d0["bytes_accessed"] - dw["bytes_accessed"]) / (1 - 512 / 4096)
    t1 = terms({**d0, "bytes_accessed": d0["bytes_accessed"] - s2})
    print(f"  measured S² byte term: {s2:.3e} B/chip")
    show("it1: +flash kernel (VMEM scores)", t1, mf)

    # H2: MoE dispatch one-hots cost O(g) per token; halving the group
    # halves dispatch flops+bytes at slightly worse capacity behaviour.
    it2 = run("mixtral_8x7b", "train_4k", "moe_group=1024")
    d2 = it2["probe"]["derived"]
    t2 = terms({**d2, "bytes_accessed": d2["bytes_accessed"] - s2})
    show("it2: it1 + moe_group 2048->1024", t2, mf)

    # H3: no-remat: trade recompute flops for activation bytes.
    it3 = run("mixtral_8x7b", "train_4k", "moe_group=1024,remat=0")
    d3 = it3["probe"]["derived"]
    t3 = terms({**d3, "bytes_accessed": d3["bytes_accessed"] - s2})
    show("it3: it2 + no-remat", t3, mf)


CELLS = {
    "llama": cell_llama_train,
    "xlstm": cell_xlstm_prefill,
    "mixtral": cell_mixtral_train,
}


def main() -> None:
    which = sys.argv[1:] or list(CELLS)
    for name in which:
        CELLS[name]()


if __name__ == "__main__":
    main()
