"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch × shape) cell on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_per_chip / 197e12      [s]
    memory term     = HLO_bytes_per_chip / 819e9       [s]
    collective term = collective_bytes_per_chip / 50e9 [s]

Sources: the depth-probe derived metrics in benchmarks/results/dryrun/
(*16x16__<variant>.json). The probe reconstructs exact per-device totals
from unrolled 1- and 2-superblock compiles (XLA cost analysis counts a
``while`` body once — launch/dryrun.py:depth_probe). HLO flops/bytes are
PER CHIP because the compiled module is the per-device SPMD program.

Analytic inner-scan correction: Mamba's chunk scan, mLSTM's chunk scan
and sLSTM's time scan remain rolled inside the probe compiles, so their
bodies are also counted once. ``inner_scan_correction`` adds the
(trip_count - 1) missing bodies from closed-form FLOP counts of the scan
body (documented per family below); it only affects xlstm and jamba
train/prefill cells and is reported separately so the raw HLO numbers
stay visible.

MODEL_FLOPS = 6·N_active·D (train; fwd+bwd) or 2·N_active·D (inference),
per chip. The ratio MODEL_FLOPS / HLO_FLOPs shows how much of compiled
compute is "useful" (catches remat/dispatch/recompute waste).
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = 256

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token x batch
    "long_500k": 1,
}
TRAIN_SHAPES = {"train_4k"}


def inner_scan_correction(arch: str, shape: str, cfg) -> float:
    """Global missing FLOPs from rolled inner sequence scans (see module
    docstring); returns PER-CHIP flops to add."""
    if shape.startswith("decode") or shape.startswith("long"):
        return 0.0  # decode paths are single-step: no inner scan
    seq = 4096 if shape == "train_4k" else 32768
    batch = 256 if shape == "train_4k" else 32
    bwd_mult = 3.0 if shape in TRAIN_SHAPES else 1.0
    total = 0.0
    d = cfg.d_model
    for entry in cfg.block_pattern:
        mixer = cfg.mixer_of(entry)
        n_layers_of = cfg.n_repeats
        if mixer == "mamba":
            chunk = 256
            nchunks = max(1, seq // chunk)
            di, N = cfg.d_inner, cfg.ssm_state_dim
            import math
            body = (math.log2(chunk) + 2) * 2 * batch * chunk * di * N
            total += (nchunks - 1) * body * n_layers_of
        elif mixer == "mlstm":
            chunk = 256
            nchunks = max(1, seq // chunk)
            dk = int(cfg.mlstm_proj_factor * d)
            hd = dk // cfg.n_heads
            body = (4 * batch * chunk * chunk * dk
                    + 6 * batch * chunk * hd * dk)
            total += (nchunks - 1) * body * n_layers_of
        elif mixer == "slstm":
            body = 8 * batch * d * (d // cfg.n_heads) + 24 * batch * d
            total += (seq - 1) * body * n_layers_of
    return total * bwd_mult / CHIPS


def model_flops_per_chip(cfg, shape: str) -> float:
    n_active = cfg.param_counts()["active"]
    tokens = SHAPE_TOKENS[shape]
    mult = 6.0 if shape in TRAIN_SHAPES else 2.0
    return mult * n_active * tokens / CHIPS


def modeled_hbm_bytes_per_chip(cfg, shape: str, *, remat: bool = True,
                               flash: bool = False) -> float:
    """Modeled TPU HBM traffic per chip per step.

    Why this exists: XLA's cost-analysis "bytes accessed" counts every
    HLO op's operands as if they hit memory — on the CPU backend this is
    a pre-fusion UPPER BOUND (llama3 train would need 2145s of HBM time,
    which is physically absurd). The roofline table reports both the raw
    bound and this model:

      params:  bf16 read (fwd) + read (bwd) + fp32 grad w+r + AdamW
               m/v r+w + param write  ≈ 30 bytes/param, sharded
      acts:    per-layer boundary saves (remat) or ~6 intermediates
               (no-remat), bf16 write+read
      scores:  attention logits/probs fp32, ~8 passes train / 2 passes
               inference — ZERO when ``flash`` (the Pallas kernel keeps
               them in VMEM); sliding windows cap the k-extent
      decode:  params read + full KV cache read + pointwise state
    """
    N = cfg.param_counts()["total"]
    d, H, L = cfg.d_model, cfg.n_heads, cfg.n_layers
    seq_of = {"train_4k": 4096, "prefill_32k": 32768,
              "decode_32k": 32768, "long_500k": 524288}
    bsz_of = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
              "long_500k": 1}
    S, B = seq_of[shape], bsz_of[shape]
    tokens = B * S
    n_attn = sum(1 for e in cfg.block_pattern
                 if cfg.mixer_of(e) == "attn") * cfg.n_repeats

    if shape in TRAIN_SHAPES or shape == "prefill_32k":
        train = shape in TRAIN_SHAPES
        params = (30.0 if train else 2.0) * N
        act_passes = (4.0 if remat else 24.0) if train else 2.0
        acts = act_passes * L * tokens * d * 2
        kv_extent = min(S, cfg.sliding_window or S)
        score_passes = 0.0 if flash else (8.0 if train else 2.0)
        scores = score_passes * B * S * kv_extent * H * 4 * n_attn
        return (params + acts + scores) / CHIPS
    # decode: params + cache traffic dominate
    params = 2.0 * N
    kv_extent = min(S, cfg.sliding_window or S)
    cache = n_attn * B * kv_extent * cfg.n_kv_heads * cfg.hd * 2 * 2
    state = 0.0
    for e in cfg.block_pattern:
        m = cfg.mixer_of(e)
        if m == "mamba":
            state += cfg.n_repeats * B * cfg.d_inner * cfg.ssm_state_dim * 4
        elif m == "mlstm":
            dk = int(cfg.mlstm_proj_factor * d)
            state += cfg.n_repeats * B * dk * (dk // cfg.n_heads) * 4
    return (params + 2 * cache + 2 * state) / CHIPS


def load_cells(variant: str = "baseline") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(
            os.path.join(RESULTS, f"*__16x16__{variant}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok") and "probe" in rec:
            cells.append(rec)
    return cells


def analyse(rec: dict) -> dict:
    from repro.configs import get_config

    arch, shape = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    d = rec["probe"]["derived"]
    corr = inner_scan_correction(arch, shape, cfg)
    flops = d["flops"] + corr
    t_comp = flops / PEAK
    t_mem_raw = d["bytes_accessed"] / HBM           # unfused upper bound
    t_mem = modeled_hbm_bytes_per_chip(cfg, shape) / HBM
    t_coll = d["collective_bytes"]["total"] / ICI
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_chip(cfg, shape)
    bound = max(terms.values())
    return {
        "arch": arch, "shape": shape, "variant": rec.get("variant", "?"),
        "t_compute": t_comp, "t_memory": t_mem, "t_memory_raw": t_mem_raw,
        "t_collective": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (mf / PEAK) / bound if bound else 0.0,
        "corr_flops": corr,
    }


SUGGESTIONS = {
    "compute": "reduce recompute (remat policy) / raise useful-FLOP ratio",
    "memory": "fuse elementwise chains; bigger per-chip tiles; bf16 "
              "activations end-to-end",
    "collective": "reshard to cut all-gathers (FSDP off / 2D sharding), "
                  "overlap collectives with compute",
}


def main() -> None:
    variant = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    cells = load_cells(variant)
    if not cells:
        print("no probe results found; run "
              "`python -m repro.launch.dryrun --all --probe` first")
        return
    rows = [analyse(r) for r in cells]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp(s)':>10s} {'t_mem(s)':>10s} "
           f"{'t_memRAW':>9s} {'t_coll(s)':>10s} {'dominant':>10s} "
           f"{'MODEL/HLO':>9s} {'roofline%':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['t_compute']:10.4f} "
              f"{r['t_memory']:10.4f} {r['t_memory_raw']:9.2f} "
              f"{r['t_collective']:10.4f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:9.3f} "
              f"{100 * r['roofline_fraction']:8.1f}%")
    print()
    for r in rows:
        print(f"{r['arch']}/{r['shape']}: {r['dominant']}-bound -> "
              f"{SUGGESTIONS[r['dominant']]}")
    out = os.path.join(os.path.dirname(RESULTS), f"roofline_{variant}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"\nsaved {out}")


if __name__ == "__main__":
    main()
