"""Fig. 14 (beyond-paper): the stateful platform's cost/latency axes.

Three experiments on the stateful FaaS platform model (repro.platform):

1. **Cost-vs-latency Pareto** — sweep the Lambda memory size (CPU share
   is proportional to memory, so small containers are slow but cheap
   per GB-second... until longer billed durations eat the saving) and
   the keep-alive window (longer keep-alive converts cold starts into
   warm reuses at zero billing cost — keep-alive is charged to the
   *provider*, which is ServerMix's whole economic argument).
2. **Throttled mega-fan-out** — a 1024-leaf tree reduction against an
   account concurrency cap with a burst ramp: invocations beyond the
   limit get 429s and charged exponential backoff, reshaping the
   fan-out into waves (Lambada's observation that provider rate limits
   bound usable width).
3. **Fixed-cluster comparison** — the same workload on the serverful
   baseline, billed VM-hours for the makespan whether workers are busy
   or idle: pay-per-allocation vs the platform's pay-per-use.

Every number is deterministic under the virtual clock: two consecutive
runs produce bit-identical ``platform_stats`` including billed USD
(asserted by ``run.py --smoke``'s platform gate).
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import tree_reduction_dag
from repro.core import ServerfulConfig, ServerfulEngine
from repro.platform import PlatformConfig


def _pstat_row(label: str, r: dict, derived: str = "") -> dict:
    ps = r["platform_stats"]
    bits = [derived] if derived else []
    bits.append(f"billed=${ps.get('billed_usd', 0.0):.6f}")
    if ps.get("mode") == "pool":
        bits.append(f"cold={ps['cold_starts']}/warm={ps['warm_reuses']}"
                    f"/throttled={ps['throttle_events']}"
                    f"/peak={ps['peak_concurrency']}")
    r["label"] = label
    r["derived"] = " ".join(bits)
    return r


def warm_cold_pair(n: int, compute_ms: float, lanes: int,
                   keep_alive_s: float = 600.0) -> "tuple[dict, dict]":
    """The warm-pool-vs-all-cold-pool comparison the smoke gate asserts
    on. A small invoker-lane count staggers the leaf invocations (each
    lane charges ~50 ms serially per invoke), so early containers are
    already released when later invocations arrive — reuse without any
    throttling in the picture. The ONLY difference between the two runs
    is the keep-alive window: 0 reclaims every container immediately,
    making every invocation a cold start, so the cold run charges
    exactly the warm run plus the extra ``cold_start_ms`` draws."""
    dag = tree_reduction_dag(n, compute_ms=compute_ms)
    rows = []
    for label, keep in (("warm_pool", keep_alive_s), ("cold_pool", 0.0)):
        eng = common.wukong_platform(
            platform=PlatformConfig(keep_alive_s=keep),
            num_initial_invokers=lanes, num_proxy_invokers=lanes)
        r = common.timed(eng, dag)
        rows.append(_pstat_row(label, r, derived=f"keep={keep:g}s"))
    return rows[0], rows[1]


def run(n: int = 512,
        compute_ms: float = 250.0,
        memory_sweep: "tuple[int, ...]" = (512, 1024, 1792, 3584),
        keep_alive_s: float = 600.0,
        pool_cap: int = 64,
        pool_lanes: int = 8,
        fanout_n: int = 2048,
        fanout_burst: int = 128,
        fanout_cap: int = 384) -> list[dict]:
    rows: list[dict] = []
    dag = tree_reduction_dag(n, compute_ms=compute_ms)

    # -- 1. memory sweep: the cost-vs-latency Pareto frontier ---------------
    for mem in memory_sweep:
        eng = common.wukong_platform(platform=PlatformConfig(
            memory_mb=mem, keep_alive_s=keep_alive_s,
            account_concurrency=pool_cap, burst_concurrency=pool_cap))
        r = common.timed(eng, dag)
        rows.append(_pstat_row(f"pareto_mem{mem}", r,
                               derived=f"mem={mem}MB"))

    # -- keep-alive axis: warm pool vs all-cold pool ------------------------
    warm, cold = warm_cold_pair(n, compute_ms, pool_lanes,
                                keep_alive_s=keep_alive_s)
    rows += [warm, cold]

    # -- 2. throttled mega-fan-out ------------------------------------------
    eng = common.wukong_platform(platform=PlatformConfig(
        keep_alive_s=keep_alive_s, account_concurrency=fanout_cap,
        burst_concurrency=fanout_burst, burst_ramp_per_min=500.0))
    r = common.timed(eng, tree_reduction_dag(fanout_n,
                                             compute_ms=compute_ms))
    rows.append(_pstat_row(f"throttled_fanout{fanout_n // 2}", r,
                           derived=f"burst={fanout_burst}"
                                   f"->cap={fanout_cap}"))

    # -- 3. fixed-cluster cost comparison -----------------------------------
    eng = ServerfulEngine(ServerfulConfig(cost=common.cost()))
    r = common.timed(eng, dag)
    rows.append(_pstat_row("serverful_cluster", r,
                           derived="5xVM fixed"))
    return rows


def main() -> None:
    common.emit(run(), "fig14")


if __name__ == "__main__":
    main()
