"""Shared benchmark scaffolding: engine presets + the simulated cost model.

All paper-figure benchmarks run the *real* engines on *real* DAGs with
jitted JAX payloads; only the FaaS substrate costs (invocation latency,
KV transfer, TCP handling, per-task compute duration) are simulated.

By default (``SIM_SCALE == 0``) everything runs on the deterministic
virtual discrete-event clock (repro.core.simclock): simulated seconds
cost zero wall time, results and charged ms are bit-identical across
runs, and ``wall_s`` in every row is the simulated makespan. Setting
``REPRO_SIM_SCALE > 0`` switches to the seed real-time mode (simulated
latencies really sleep, scaled by SIM_SCALE) — only needed for sanity
cross-checks of the virtual substrate. Within one figure all engines
share the same clock mode, so the paper's *relative* claims are the
reproduction targets (absolute AWS seconds are not reproducible in this
container — DESIGN.md §1).
"""
from __future__ import annotations

import os
from typing import Any

from repro.core import (
    ALL_PASSES,
    CostModel,
    EngineConfig,
    OptimizeConfig,
    ParallelInvokerEngine,
    PubSubEngine,
    ServerfulConfig,
    ServerfulEngine,
    StrawmanEngine,
    WukongEngine,
)

SIM_SCALE = float(os.environ.get("REPRO_SIM_SCALE", "0"))
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def cost(scale: float = SIM_SCALE, **kw: Any) -> CostModel:
    return CostModel(time_scale=scale, **kw)


# Effective per-core throughput of the simulated cluster. Task compute
# duration = analytic_flops / GFLOPS_SIM simulated ms, charged on the
# engine clock like every other simulated latency. This is how the
# paper's compute-heavy regime (where Lambda's elastic core count beats
# a 25-core cluster) is emulated on a single-core container.
GFLOPS_SIM = float(os.environ.get("REPRO_GFLOPS_SIM", "0.02")) * 1e9
# default calibrated so a 128^3 block product ~ 210 ms simulated (the
# paper's sub-second task regime) and simulated compute >> the real
# single-core jnp time of the small blocks


def ms_per_flop() -> float:
    """Simulated ms charged per analytic flop (clock-mode agnostic: the
    realtime clock sleeps it scaled, the virtual clock just advances)."""
    return 1e3 / GFLOPS_SIM


def wukong(scale: float = SIM_SCALE, **kw: Any) -> WukongEngine:
    return WukongEngine(EngineConfig(cost=cost(scale), **kw))


def wukong_optimized(scale: float = SIM_SCALE,
                     optimize: OptimizeConfig = ALL_PASSES,
                     **kw: Any) -> WukongEngine:
    """WUKONG with the DAG compiler pipeline (optimized-vs-unoptimized
    series; pass an ``OptimizeConfig`` for single-pass ablations)."""
    return WukongEngine(EngineConfig(cost=cost(scale), optimize=optimize,
                                     **kw))


# -- data-plane factor series (striping + batched round trips) --------------
# The paper's data-intensive workloads move GB-scale blocks; at this
# container's toy block sizes the default 600 MB/s lane makes transfers
# negligible next to invoke_ms, so the striped-vs-unstriped comparison
# emulates the paper's regime by scaling the per-shard lane down. Both
# series share the regime — the ONLY difference between them is the two
# data-plane factors, so the comparison isolates exactly what §V-B-style
# factor analysis requires.
DATAPLANE_KV_MBPS = 5.0          # per-shard lane in the emulated regime
DATAPLANE_STRIPE_BYTES = 8 << 10  # stripe target: a 64 KiB GEMM block -> 8


def wukong_dataplane(scale: float = SIM_SCALE, **kw: Any) -> WukongEngine:
    """Optimized WUKONG with the PR 2 data plane ON: striped large
    objects + batched (mget / counter-registration) round trips."""
    c = cost(scale, kv_bandwidth_mbps=DATAPLANE_KV_MBPS,
             stripe_threshold_bytes=DATAPLANE_STRIPE_BYTES)
    return WukongEngine(EngineConfig(cost=c, optimize=ALL_PASSES,
                                     batch_kv_round_trips=True, **kw))


def wukong_dataplane_off(scale: float = SIM_SCALE, **kw: Any) -> WukongEngine:
    """Optimized WUKONG with the PR 1 data plane: one shard lane per
    object (striping off), one round trip per key (batching off). Same
    emulated regime as ``wukong_dataplane`` — the ablation baseline."""
    c = cost(scale, kv_bandwidth_mbps=DATAPLANE_KV_MBPS,
             stripe_threshold_bytes=0)
    return WukongEngine(EngineConfig(cost=c, optimize=ALL_PASSES,
                                     batch_kv_round_trips=False, **kw))


def wukong_locality(scale: float = SIM_SCALE, cache: "Any | None" = None,
                    optimize: OptimizeConfig = ALL_PASSES,
                    invokers: int = 8, substrate: "str | None" = None,
                    **kw: Any) -> WukongEngine:
    """WUKONG on the stateful platform in the emulated data-intensive
    regime, with an optional container cache (``CacheConfig``) — the
    fig18 locality series. Same KV regime as ``wukong_dataplane``, so
    the cacheless arm is the PR 2 data plane and the cached arms isolate
    exactly the multi-tier cache + locality-aware placement. When
    ``substrate`` is None the CostModel default applies (the event
    engine, or ``REPRO_SIM_SUBSTRATE`` — how the CI matrix steers the
    fig18 job)."""
    from repro.platform import PlatformConfig

    c = cost(scale, kv_bandwidth_mbps=DATAPLANE_KV_MBPS,
             stripe_threshold_bytes=DATAPLANE_STRIPE_BYTES,
             cold_start_ms=250.0,
             **({} if substrate is None else {"substrate": substrate}))
    return WukongEngine(EngineConfig(
        cost=c, optimize=optimize, batch_kv_round_trips=True,
        num_initial_invokers=invokers, num_proxy_invokers=invokers,
        platform=PlatformConfig(keep_alive_s=600.0, cache=cache), **kw))


# -- stateful platform presets (fig14: warm pool / throttling / billing) ----


def wukong_platform(scale: float = SIM_SCALE,
                    platform: "Any | None" = None,
                    **kw: Any) -> WukongEngine:
    """Optimized WUKONG on the stateful platform model (repro.platform):
    warm-container pool + concurrency throttle + billing meter. Pass a
    ``PlatformConfig`` to set the memory / keep-alive / concurrency
    knobs; cost-model overrides ride ``kw['cost']``."""
    from repro.platform import PlatformConfig

    c = kw.pop("cost", None) or cost(scale, cold_start_ms=250.0)
    return WukongEngine(EngineConfig(
        cost=c, optimize=ALL_PASSES,
        platform=platform or PlatformConfig(), **kw))


def parallel_invoker_optimized(scale: float = SIM_SCALE,
                               n: int = 20) -> ParallelInvokerEngine:
    """Centralized best-iteration with the DAG compiler (chain fusion
    shrinks its one-Lambda-per-task graph)."""
    return ParallelInvokerEngine(cost=cost(scale), num_invokers=n,
                                 optimize=ALL_PASSES)


def strawman(scale: float = SIM_SCALE) -> StrawmanEngine:
    return StrawmanEngine(cost=cost(scale))


def pubsub(scale: float = SIM_SCALE) -> PubSubEngine:
    return PubSubEngine(cost=cost(scale))


def parallel_invoker(scale: float = SIM_SCALE,
                     n: int = 20) -> ParallelInvokerEngine:
    return ParallelInvokerEngine(cost=cost(scale), num_invokers=n)


def serverful_ec2(scale: float = SIM_SCALE) -> ServerfulEngine:
    # paper: five t2.2xlarge VMs x five workers
    return ServerfulEngine(ServerfulConfig(
        cost=cost(scale), n_workers=25, worker_bandwidth_mbps=1000.0,
        n_vms=5, vm_price_per_hour_usd=0.3712))


def serverful_laptop(scale: float = SIM_SCALE) -> ServerfulEngine:
    # paper: two-core i5 laptop, four workers — owned hardware, so the
    # fixed-cluster billing model charges no VM-hours
    return ServerfulEngine(ServerfulConfig(
        cost=cost(scale), n_workers=4, worker_bandwidth_mbps=4000.0,
        n_vms=0, vm_price_per_hour_usd=0.0))


def timed(engine, dag, repeats: int = 1,
          warmup: "bool | None" = None) -> dict[str, Any]:
    """Run and report simulated-environment wall seconds (mean over
    repeats) plus engine counters. ``warmup`` runs the DAG once first so
    one-time XLA compilation of the task payloads is not charged to
    whichever engine happens to run first; it defaults to on only in
    real-time mode — under the virtual clock ``wall_s`` is simulated
    makespan, which host-side compilation cannot perturb."""
    if warmup is None:
        warmup = SIM_SCALE > 0
    walls = []
    rep = None
    if warmup:
        engine.compute(dag)
    for _ in range(repeats):
        rep = engine.compute(dag)
        walls.append(rep.wall_s)
    return {
        "wall_s": sum(walls) / len(walls),
        "min_s": min(walls),
        "max_s": max(walls),
        "tasks": rep.tasks,
        "executors": rep.executors_invoked,
        "kv_bytes": rep.kv_stats["bytes_read"] + rep.kv_stats["bytes_written"],
        "kv_stats": rep.kv_stats,
        "charged_ms": rep.charged_ms,
        "metrics": rep.metrics,
        "platform_stats": rep.platform_stats,
    }


def emit(rows: list[dict[str, Any]], name: str) -> None:
    """Print the standard CSV block for run.py."""
    for r in rows:
        us = r["wall_s"] * 1e6
        derived = r.get("derived", "")
        print(f"{name}/{r['label']},{us:.0f},{derived}")
