"""Fig. 19 (beyond-paper): steady-state streaming via the trigger bus.

The trigger subsystem (repro.core.triggers) claims that event-fired
jobs are a first-class, durable, deterministic workload: a Poisson
event stream lands in the KV store, tumbling windows close and fire
tree-reduction jobs through the PR 5 orchestrator, timer / job-
completion / external triggers fire alongside, and the whole run is
bit-identical across repeats and across the event/thread simulation
substrates. Dynamic DAGs (runtime graph expansion) claim charged-cost
parity with their static equivalents, and a dispatcher crash mid-
stream claims exactly-once window fires via the fire journal.

Fig. 19 prices those claims with three arms:

- **streaming**: ``StreamConfig`` arrivals + four trigger rules (one
  per source type) on both substrates, run twice on the event
  substrate; gates on >= 64 window-close jobs, >= 1 fire per source,
  zero failures, and bit-identical steady-state metrics (sustained
  jobs/s, event-to-result p50/p95/p99, backlog, window fire-key set)
  across runs AND across substrates;
- **parity**: ``dynamic_tree_reduction_dag`` vs its pre-expanded
  static equivalent on a ship-free engine — results and charged_ms
  must match bit for bit on both substrates;
- **crash**: the streaming config crashed at the "dispatch" protocol
  point and recovered via ``run_with_recovery`` — the recovered run
  must complete every job with the same window fire-key set as the
  uncrashed baseline and no duplicated trigger job id (the journal
  dedupe is what makes re-delivered events exactly-once).
"""
from __future__ import annotations

import hashlib

import numpy as np

from benchmarks import common
from repro.apps import (
    dynamic_tree_reduction_dag,
    dynamic_tree_reduction_expected,
    static_tree_reduction_equivalent,
)
from repro.core import (
    EngineConfig,
    FaultConfig,
    JobOrchestrator,
    OrchestratorConfig,
    StreamConfig,
    TenantSpec,
    TriggerRule,
    WorkloadConfig,
    WukongEngine,
)

_TENANTS = (TenantSpec("tenant-a"), TenantSpec("tenant-b"))

# Metrics that must be bit-identical across repeated runs and across
# the event/thread substrates (the determinism gate).
_DETERMINISM_KEYS = (
    "wall_s", "jobs", "completed", "failed", "fires", "windows_closed",
    "window_jobs_completed", "sustained_jobs_per_s",
    "event_to_result_p50_s", "event_to_result_p95_s",
    "event_to_result_p99_s", "mean_backlog", "max_backlog",
    "window_fire_digest", "billed_usd_total",
)


def _engine_config(substrate: str, **cost_kw) -> EngineConfig:
    return EngineConfig(cost=common.cost(substrate=substrate, **cost_kw),
                        num_initial_invokers=4, num_proxy_invokers=4,
                        max_concurrency=512)


def _stream(n_events: int) -> StreamConfig:
    return StreamConfig(n_events=n_events, rate_per_s=40.0, seed=3,
                        flush_event="eos")


def _rules(stream: StreamConfig, window_ms: float) -> "tuple[TriggerRule, ...]":
    # One rule per trigger source type; the acceptance gate requires
    # each of the four to fire at least one job.
    return (
        TriggerRule("window", "kv_write",
                    {"app": "tree_reduction", "size": 8,
                     "tenant": "tenant-a"},
                    key_prefix=stream.store_prefix, window_ms=window_ms),
        TriggerRule("tick", "timer",
                    {"app": "tree_reduction", "size": 8,
                     "tenant": "tenant-b"},
                    period_ms=2500.0, max_fires=2),
        TriggerRule("ckpt", "job_completed",
                    {"app": "dynamic_tree", "size": 8,
                     "tenant": "tenant-b"},
                    job_app="tree_reduction", every_n=8),
        TriggerRule("flush", "external",
                    {"app": "tree_reduction", "size": 8,
                     "tenant": "tenant-a"},
                    event="eos", flush_windows=True),
    )


def _orch_config(substrate: str, n_events: int, window_ms: float,
                 crash_at: "int | None" = None) -> OrchestratorConfig:
    stream = _stream(n_events)
    faults = FaultConfig()
    if crash_at is not None:
        faults = FaultConfig(orchestrator_crash_point="dispatch",
                             orchestrator_crash_at=crash_at)
    return OrchestratorConfig(
        engine=_engine_config(substrate),
        workload=WorkloadConfig(n_jobs=2, tenants=_TENANTS, seed=1),
        max_concurrent_jobs=8,
        triggers=_rules(stream, window_ms),
        stream=stream,
        faults=faults,
    )


def _row(label: str, rep, bus, n_events: int,
         derived_extra: str = "") -> dict:
    srep = bus.report(n_events=n_events)
    fired = bus.fired_records()
    window_keys = sorted(r["fire_key"] for r in fired
                         if r["source"] == "kv_write")
    digest = hashlib.sha256(
        "\n".join(window_keys).encode()).hexdigest()[:16]
    job_ids = [r["job_id"] for r in fired]
    row = {
        "label": label,
        "wall_s": rep.makespan_s,
        "jobs": rep.jobs,
        "completed": rep.completed,
        "failed": rep.failed,
        "crashes": rep.crashes,
        "fires": dict(sorted(srep.fires.items())),
        "windows_closed": srep.windows_closed,
        "window_jobs_completed": srep.window_jobs_completed,
        "sustained_jobs_per_s": srep.sustained_jobs_per_s,
        "event_to_result_p50_s": srep.event_to_result_p50_s,
        "event_to_result_p95_s": srep.event_to_result_p95_s,
        "event_to_result_p99_s": srep.event_to_result_p99_s,
        "mean_backlog": srep.mean_backlog,
        "max_backlog": srep.max_backlog,
        "duplicate_fires_suppressed": srep.duplicate_fires_suppressed,
        "window_fire_digest": digest,
        "dup_job_ids": len(job_ids) - len(set(job_ids)),
        "billed_usd_total": rep.billed_usd_total,
    }
    bits = [derived_extra] if derived_extra else []
    bits.append(f"{rep.completed}/{rep.jobs}jobs")
    bits.append(f"w={srep.windows_closed}")
    bits.append(f"rate={srep.sustained_jobs_per_s:.2f}/s")
    bits.append(f"p99={srep.event_to_result_p99_s:.3f}s")
    row["derived"] = " ".join(bits)
    return row


def _parity_rows(substrates: "tuple[str, ...]", n: int) -> "list[dict]":
    """Dynamic-vs-static charged parity on a ship-free engine.

    ``schedule_ship_mbps=inf`` removes the static-schedule shipping
    charge (the dynamic arm's expansion schedules are built after
    dispatch, so shipping is the one structural cost the two arms
    cannot share); everything else — invokes, KV traffic, counter
    registration, compute — must then price identically.
    """
    rows: list[dict] = []
    expected = dynamic_tree_reduction_expected(n)
    for substrate in substrates:
        reports = {}
        for arm, dag_fn in (("dynamic", dynamic_tree_reduction_dag),
                            ("static", static_tree_reduction_equivalent)):
            eng = WukongEngine(
                _engine_config(substrate,
                               schedule_ship_mbps=float("inf")))
            reports[arm] = eng.compute(dag_fn(n, compute_ms=5.0))
        dyn, sta = reports["dynamic"], reports["static"]
        correct = (np.allclose(dyn.results["reduce"], expected)
                   and np.allclose(sta.results["reduce"], expected))
        parity = (dyn.charged_ms == sta.charged_ms
                  and dyn.tasks == sta.tasks
                  and np.array_equal(np.asarray(dyn.results["reduce"]),
                                     np.asarray(sta.results["reduce"])))
        rows.append({
            "label": f"{substrate}_parity_n{n}",
            "wall_s": dyn.wall_s,
            "charged_ms": dyn.charged_ms,
            "static_charged_ms": sta.charged_ms,
            "tasks": dyn.tasks,
            "kv_stats": dyn.kv_stats,
            "parity": parity,
            "correct": correct,
            "derived": (f"dyn={dyn.charged_ms:.3f}ms "
                        f"static={sta.charged_ms:.3f}ms "
                        f"parity={'ok' if parity else 'BROKEN'}"),
        })
    return rows


def run(n_events: int = 400, window_ms: float = 125.0,
        crash_ats: "tuple[int, ...]" = (12,),
        substrates: "tuple[str, ...]" = ("event", "thread"),
        parity_n: int = 16) -> "list[dict]":
    rows: list[dict] = []
    for substrate in substrates:
        repeats = 2 if substrate == substrates[0] else 1
        for rep_i in range(repeats):
            orch = JobOrchestrator(
                _orch_config(substrate, n_events, window_ms))
            rep = orch.run()
            rows.append(_row(f"{substrate}_stream_run{rep_i + 1}", rep,
                             orch.last_substrate.trigger_bus, n_events,
                             derived_extra=f"{n_events}ev@40/s"))
    for crash_at in crash_ats:
        orch = JobOrchestrator(
            _orch_config(substrates[0], n_events, window_ms,
                         crash_at=crash_at))
        rep = orch.run_with_recovery()
        rows.append(_row(f"{substrates[0]}_crash_at{crash_at}", rep,
                         orch.last_substrate.trigger_bus, n_events,
                         derived_extra=f"crash@dispatch#{crash_at}"))
    rows.extend(_parity_rows(substrates, parity_n))
    return rows


def check_gates(rows: "list[dict]") -> None:
    """CI regression gate (run.py --smoke): deterministic steady-state
    streaming, all four trigger sources live, exactly-once fires across
    a mid-stream dispatcher crash, dynamic/static charged parity."""
    import sys

    stream_rows = [r for r in rows if "_stream_run" in r["label"]]
    assert stream_rows, "streaming gate: no streaming rows in fig19"
    for row in stream_rows:
        assert row["completed"] == row["jobs"] and row["failed"] == 0, (
            f"streaming regression: {row['label']} completed "
            f"{row['completed']}/{row['jobs']} ({row['failed']} failed)")
        assert row["windows_closed"] >= 64, (
            f"streaming regression: {row['label']} closed only "
            f"{row['windows_closed']} windows (need >= 64)")
        for source in ("timer", "kv_write", "job_completed", "external"):
            assert row["fires"].get(source, 0) >= 1, (
                f"streaming regression: {row['label']} fired no "
                f"{source} job")
        assert row["dup_job_ids"] == 0, (
            f"streaming regression: {row['label']} allocated duplicate "
            f"trigger job ids")
    base = stream_rows[0]
    for row in stream_rows[1:]:
        for key in _DETERMINISM_KEYS:
            assert row[key] == base[key], (
                f"determinism regression: {row['label']}.{key} = "
                f"{row[key]!r} != {base['label']}.{key} = {base[key]!r}")

    crashed = [r for r in rows if "_crash_at" in r["label"]]
    assert crashed, "crash gate: no crashed runs in fig19 rows"
    for row in crashed:
        assert row["crashes"] > 0, (
            f"crash gate: {row['label']} never actually crashed")
        assert row["completed"] == row["jobs"] and row["failed"] == 0, (
            f"crash regression: {row['label']} completed "
            f"{row['completed']}/{row['jobs']} ({row['failed']} failed)")
        assert row["window_fire_digest"] == base["window_fire_digest"], (
            f"crash regression: {row['label']} window fire-key set "
            f"diverged from the uncrashed baseline (lost or spurious "
            f"window job)")
        assert row["dup_job_ids"] == 0, (
            f"crash regression: {row['label']} duplicated a trigger "
            f"job id across recovery")

    parity = [r for r in rows if "_parity_" in r["label"]]
    assert parity, "parity gate: no parity rows in fig19"
    for row in parity:
        assert row["correct"], (
            f"parity regression: {row['label']} computed a wrong "
            f"reduction result")
        assert row["parity"], (
            f"parity regression: {row['label']} dynamic charged "
            f"{row['charged_ms']} != static {row['static_charged_ms']}")
    assert len({r["charged_ms"] for r in parity}) == 1, (
        "parity regression: dynamic charged_ms differs across substrates")

    print(f"# streaming gate OK: {len(stream_rows)} runs bit-identical "
          f"({base['windows_closed']} windows, "
          f"{base['sustained_jobs_per_s']:.2f} jobs/s sustained), "
          f"{len(crashed)} crashed sweeps exactly-once, "
          f"dynamic/static parity on {len(parity)} substrates",
          file=sys.stderr)


def main() -> None:
    common.emit(run(), "fig19")


if __name__ == "__main__":
    main()
