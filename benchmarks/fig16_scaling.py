"""Fig. 16 (beyond-paper): scaling curve of the two simulation substrates.

The event-driven substrate (PR 6) replaces thread-per-actor execution
with continuations driven from the clock's ready queue, so simulating a
DAG costs generator dispatches instead of OS threads + context
switches. This figure measures that substitution directly, at two
levels:

1. **Substrate-level tree reduction** — pure actors (one generator per
   leaf/node) on clock queues, no engine around them. This isolates the
   actor-switching cost the refactor removes; it is where the honest
   thread-vs-event gap lives (the full engine adds substrate-agnostic
   Python work — kv simulation, executor walks, metrics — that dilutes
   the ratio to ~2x). The CI gate asserts the event substrate is
   >= 5x faster here at the 4096-leaf tier, bit-identical across runs,
   and charges exactly what the thread substrate charges.
2. **Engine-level scaling curve** — the real ``WukongEngine`` on tree
   reductions from 8k to 10^6 tasks. Both substrates run the 8k tier
   (cross-substrate charged_ms equality); beyond that only the event
   substrate is feasible (the thread path would need one OS thread per
   concurrent executor — 64k+ at the 10^5 tier). The CI gate asserts
   the 10^5-task tier completes in under 30 s of host wall time.

Rows report ``wall_s`` as *host* seconds (the quantity under test —
how fast the simulator itself runs), with the simulated makespan in
``sim_s``. Every event-substrate row is run twice and carries a
``deterministic`` bit; all event measurements run before any thread
measurement so thread-run residue (dying OS threads, allocator churn)
cannot pollute the event timings.
"""
from __future__ import annotations

import sys
import time
from typing import Any

from benchmarks import common
from repro.apps import tree_reduction_dag
from repro.apps.tree_reduction import tree_reduction_expected
from repro.core import (
    EngineConfig,
    WukongEngine,
    clock_for_scale,
    drain_worker_cache,
)

GATE_LEAVES = 4096        # micro tier the >= 5x speedup gate runs at
GATE_MIN_SPEEDUP = 5.0
GATE_SCALE_TASKS = 100_000  # engine tier the wall-budget gate runs at
GATE_SCALE_BUDGET_S = 30.0


def _tree_actors(clock, leaves: int, compute_ms: float):
    """Spawn a pure-actor tree reduction on ``clock``: one generator per
    leaf and per internal node, pairwise-reducing through clock queues.
    Returns the root generator for ``clock.run``."""
    qs = []
    for i in range(leaves):
        q = clock.queue()

        def leaf(q=q):
            yield ("charge", compute_ms)
            q.put(1)

        clock.spawn(leaf, name=f"leaf{i}")
        qs.append(q)
    while len(qs) > 1:
        nxt = []
        for i in range(0, len(qs), 2):
            a_q, b_q, out = qs[i], qs[i + 1], clock.queue()

            def node(a_q=a_q, b_q=b_q, out=out):
                a = yield ("get", a_q, None)
                b = yield ("get", b_q, None)
                yield ("charge", compute_ms)
                out.put(a + b)

            clock.spawn(node, name="node")
            nxt.append(out)
        qs = nxt

    def root(q=qs[0]):
        return (yield ("get", q, None))

    return root()


def _micro_once(substrate: str, leaves: int,
                compute_ms: float) -> dict[str, Any]:
    drain_worker_cache()
    clock = clock_for_scale(0.0, substrate)
    t0 = time.perf_counter()
    total = clock.run(_tree_actors(clock, leaves, compute_ms))
    elapsed = time.perf_counter() - t0
    assert total == leaves
    return {"wall_s": elapsed, "sim_ms": clock.now_ms(),
            "charged_ms": clock.charged_ms, "result": total}


def _engine_once(substrate: str, n: int,
                 compute_ms: float) -> dict[str, Any]:
    drain_worker_cache()
    dag = tree_reduction_dag(n, compute_ms=compute_ms)
    cfg = EngineConfig(
        cost=common.cost(0.0, substrate=substrate),
        max_concurrency=max(n, 4096),
        job_timeout_s=1e6,
        # Million-task tiers would hold ~2.5 metric dicts per task;
        # recording is off for the whole curve so tiers are comparable.
        record_metrics=False,
    )
    t0 = time.perf_counter()
    rep = WukongEngine(cfg).compute(dag)
    elapsed = time.perf_counter() - t0
    (_, root), = rep.results.items()
    assert root[0] == tree_reduction_expected(n)
    return {"wall_s": elapsed, "sim_ms": rep.wall_s * 1e3,
            "charged_ms": rep.charged_ms, "kv_stats": rep.kv_stats,
            "tasks": rep.tasks}


def _row(level: str, substrate: str, tasks: int, first: dict,
         second: "dict | None") -> dict[str, Any]:
    """One scaling-curve row. ``wall_s`` is host seconds (best of the
    runs taken); ``deterministic`` compares the simulated quantities of
    two event-substrate runs bit-for-bit."""
    deterministic = None
    if second is not None:
        deterministic = all(first[k] == second[k]
                            for k in ("sim_ms", "charged_ms"))
    wall = (min(first["wall_s"], second["wall_s"]) if second is not None
            else first["wall_s"])
    sim_s = first["sim_ms"] / 1e3
    row = {
        "label": f"{level}_{substrate}@{tasks}",
        "level": level,
        "substrate": substrate,
        "tasks": tasks,
        "wall_s": wall,
        "sim_s": sim_s,
        "charged_ms": first["charged_ms"],
        "kv_stats": first.get("kv_stats"),
        "deterministic": deterministic,
        "derived": (f"tasks={tasks} sim_s={sim_s:.1f} "
                    f"charged={first['charged_ms']:.1f}ms"
                    + ("" if deterministic is None
                       else f" deterministic={deterministic}")),
    }
    return row


def run(micro_leaves: "tuple[int, ...]" = (1024, GATE_LEAVES),
        engine_tiers: "tuple[tuple[int, bool], ...]" = (
            (8192, True), (131072, False)),
        compute_ms: float = 1.0) -> list[dict]:
    """``engine_tiers`` is (dag_n, run_thread_substrate_too); dag_n - 1
    tasks per tier. All event measurements run before any thread
    measurement (see module docstring)."""
    if common.SIM_SCALE > 0:
        # The curve compares zero-scale substrates; under the real-time
        # cross-check mode there is nothing meaningful to measure.
        print("# fig16 skipped (real-time mode)", file=sys.stderr)
        return []
    rows: list[dict] = []

    # -- event substrate first: micro tiers, then the engine curve ---------
    for leaves in micro_leaves:
        first = _micro_once("event", leaves, compute_ms)
        second = _micro_once("event", leaves, compute_ms)
        rows.append(_row("substrate", "event", 2 * leaves - 1,
                         first, second))
    for n, _both in engine_tiers:
        first = _engine_once("event", n, compute_ms)
        # The bit-identity repeat is only affordable at the small tiers;
        # big tiers get determinism coverage from the micro rows and the
        # slow-marked scale test.
        second = (_engine_once("event", n, compute_ms) if n <= 16384
                  else None)
        rows.append(_row("engine", "event", n - 1, first, second))

    # -- thread substrate (the cross-check mode) ----------------------------
    for leaves in micro_leaves:
        rows.append(_row("substrate", "thread", 2 * leaves - 1,
                         _micro_once("thread", leaves, compute_ms), None))
    for n, both in engine_tiers:
        if both:
            rows.append(_row("engine", "thread", n - 1,
                             _engine_once("thread", n, compute_ms), None))
    return rows


def scaling_curve(rows: list[dict]) -> list[dict]:
    """The compact tasks-vs-wall-seconds record for BENCH_results.json."""
    return [{k: r[k] for k in ("level", "substrate", "tasks", "wall_s",
                               "sim_s", "charged_ms", "deterministic")}
            for r in rows]


def check_gates(rows: list[dict]) -> None:
    """The CI scale gates (raise SystemExit on regression):

    - *substrate speedup*: at the 4096-leaf micro tier the event
      substrate must be >= 5x faster in host wall time than the
      thread-per-actor substrate;
    - *bit-identity*: every twice-run event row must reproduce its
      simulated time and charged ms exactly;
    - *substrate equivalence*: wherever both substrates ran a tier,
      their charged_ms (and kv_stats, engine tiers) must be identical;
    - *scale budget*: the >= 10^5-task engine tier must complete in
      under 30 s of host wall time.
    """
    if not rows:
        print("# scale gate skipped (real-time mode)", file=sys.stderr)
        return
    by_label = {r["label"]: r for r in rows}

    gate_tasks = 2 * GATE_LEAVES - 1
    ev = by_label.get(f"substrate_event@{gate_tasks}")
    th = by_label.get(f"substrate_thread@{gate_tasks}")
    if ev is None or th is None:
        raise SystemExit("scale regression: 4096-leaf micro tier missing "
                         "from the fig16 rows")
    speedup = th["wall_s"] / ev["wall_s"]
    if speedup < GATE_MIN_SPEEDUP:
        raise SystemExit(
            f"scale regression: event substrate only {speedup:.1f}x faster "
            f"than thread at {GATE_LEAVES} leaves "
            f"({ev['wall_s']:.3f}s vs {th['wall_s']:.3f}s; "
            f">= {GATE_MIN_SPEEDUP:g}x required)")

    for r in rows:
        if r["deterministic"] is False:
            raise SystemExit(
                f"scale regression: {r['label']} not bit-identical across "
                "two runs")

    for r in rows:
        if r["substrate"] != "thread":
            continue
        ev_r = by_label.get(r["label"].replace("thread", "event"))
        if ev_r is None:
            continue
        if ev_r["charged_ms"] != r["charged_ms"]:
            raise SystemExit(
                f"scale regression: {r['label']} charged "
                f"{r['charged_ms']!r}ms but the event substrate charged "
                f"{ev_r['charged_ms']!r}ms — substrates diverged")
        if (r.get("kv_stats") is not None
                and ev_r.get("kv_stats") != r.get("kv_stats")):
            raise SystemExit(
                f"scale regression: {r['label']} kv_stats diverged "
                "across substrates")

    scale = [r for r in rows if r["level"] == "engine"
             and r["substrate"] == "event"
             and r["tasks"] >= GATE_SCALE_TASKS]
    if not scale:
        raise SystemExit(
            f"scale regression: no >= {GATE_SCALE_TASKS}-task event tier "
            "in the fig16 rows")
    worst = max(scale, key=lambda r: r["wall_s"])
    if worst["wall_s"] >= GATE_SCALE_BUDGET_S:
        raise SystemExit(
            f"scale regression: {worst['tasks']}-task tier took "
            f"{worst['wall_s']:.1f}s host wall "
            f"(< {GATE_SCALE_BUDGET_S:g}s required)")

    print(f"# scale gate OK: substrate {speedup:.1f}x at {GATE_LEAVES} "
          f"leaves; {worst['tasks']} tasks in {worst['wall_s']:.1f}s; "
          "event rows bit-identical; substrates charge identically",
          file=sys.stderr)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI tiers + the scale gates")
    ap.add_argument("--full", action="store_true",
                    help="adds the 10^6-task event tier")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="merge the fig16 rows + scaling_curve into this "
                         "BENCH_results.json (read-modify-write; lets the "
                         "CI bench-scale job publish the curve without "
                         "re-running every figure)")
    args = ap.parse_args()

    if args.full:
        kwargs = dict(micro_leaves=(1024, GATE_LEAVES, 16384),
                      engine_tiers=((8192, True), (131072, False),
                                    (1 << 20, False)))
    else:
        kwargs = dict()  # the smoke/CI tiers are the defaults
    rows = run(**kwargs)
    print("name,us_per_call,derived")
    common.emit(rows, "fig16")
    for r in scaling_curve(rows):
        print(f"# {r}", file=sys.stderr)
    if args.json:
        import json
        import os

        from benchmarks.run import _json_row

        snap = {}
        if os.path.exists(args.json):
            with open(args.json) as f:
                snap = json.load(f)
        snap.setdefault("figures", {})["fig16"] = {
            r["label"]: _json_row(r) for r in rows}
        snap["scaling_curve"] = scaling_curve(rows)
        with open(args.json, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# merged fig16 into {args.json}", file=sys.stderr)
    if args.smoke:
        check_gates(rows)


if __name__ == "__main__":
    main()
