"""Fig. 15 (beyond-paper): multi-tenant traffic on ONE shared platform.

The paper benchmarks one job at a time; its premise (fine-grained tasks
on a shared auto-scaling provider) only pays off under *traffic* — many
jobs from many tenants contending for one account's warm-container pool
and concurrency cap (the ServerMix / Triggerflow regime). Fig. 15 runs
the ``JobOrchestrator`` (repro.core.orchestrator) over a seeded Poisson
workload with a heavy-tailed mix of the paper's four applications and
sweeps:

1. **arrival rate** — shared-account vs isolated-per-job platforms at
   each rate: the shared pool converts later jobs' cold starts into
   warm reuses; isolation is the one-job-at-a-time assumption PRs 1-4
   baked in, priced out.
2. **tenant count** — more tenants on one account means each tenant's
   per-function warm pool sees a thinner slice of the traffic: the
   warm-share (and with it p50) degrades — pooling has economies of
   *scale per function*, not per account.

Every row reports job-latency percentiles (p50/p95/p99 of arrival ->
completion), per-tenant billed USD, warm share, and peak account
concurrency. Deterministic under the virtual clock; ``run.py --smoke``
re-runs the smoke pair and asserts bit-identity (including per-tenant
billed USD) plus shared-p50 strictly below isolated-p50.
"""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.core import (
    EngineConfig,
    JobOrchestrator,
    OrchestratorConfig,
    TenantSpec,
    WorkloadConfig,
)

# Memory ladder cycled over generated tenants: two standard functions,
# one small/slow/cheap-per-GB-s, one large/fast.
_TENANT_MEMORY_LADDER = (1792, 1792, 896, 3584)


def tenants_for(count: int) -> "tuple[TenantSpec, ...]":
    return tuple(
        TenantSpec(f"tenant-{i:02d}",
                   _TENANT_MEMORY_LADDER[i % len(_TENANT_MEMORY_LADDER)])
        for i in range(count)
    )


def _engine_config() -> EngineConfig:
    # Per-job engine preset: small invoker pools (N jobs run at once on
    # one machine) on the shared benchmark cost model.
    return EngineConfig(cost=common.cost(cold_start_ms=250.0),
                        num_initial_invokers=4, num_proxy_invokers=4,
                        max_concurrency=512)


def orchestrate(n_jobs: int, rate: float, n_tenants: int,
                isolated: bool, max_concurrent_jobs: int = 32,
                seed: int = 0):
    cfg = OrchestratorConfig(
        engine=_engine_config(),
        workload=WorkloadConfig(n_jobs=n_jobs, arrival_rate_per_s=rate,
                                tenants=tenants_for(n_tenants), seed=seed),
        max_concurrent_jobs=max_concurrent_jobs,
        isolate_platform=isolated,
    )
    return JobOrchestrator(cfg).run()


def _row(label: str, rep, derived: str = "") -> dict:
    bits = [derived] if derived else []
    bits.append(f"p50={rep.p50_s:.3f}s/p95={rep.p95_s:.3f}s"
                f"/p99={rep.p99_s:.3f}s")
    bits.append(f"warm={rep.warm_share * 100:.0f}%")
    bits.append(f"billed=${rep.billed_usd_total:.6f}")
    summary = dataclasses.asdict(rep)
    summary.pop("job_records")  # per-job detail stays out of the JSON
    return {
        "label": label,
        # wall_s = simulated makespan of the whole traffic trace
        "wall_s": rep.makespan_s,
        "tasks": sum(r.get("tasks", 0) for r in rep.job_records),
        "executors": sum(r.get("executors", 0) for r in rep.job_records),
        "p50_s": rep.p50_s,
        "p95_s": rep.p95_s,
        "p99_s": rep.p99_s,
        "per_tenant_billed": {t: blk["billed_usd"]
                              for t, blk in rep.per_tenant.items()},
        "platform_stats": summary,
        "derived": " ".join(bits),
    }


def shared_isolated_pair(n_jobs: int, rate: float, n_tenants: int,
                         max_concurrent_jobs: int = 32) -> "tuple[dict, dict]":
    """The comparison the smoke gate asserts on: the SAME workload on
    one shared account vs per-job private platforms. The only difference
    is platform sharing, so the latency gap is exactly the value of
    cross-job warm reuse (minus shared-cap contention)."""
    rows = []
    for label, isolated in (("shared_pool", False), ("isolated_per_job", True)):
        rep = orchestrate(n_jobs, rate, n_tenants, isolated,
                          max_concurrent_jobs)
        rows.append(_row(f"{label}_r{rate:g}_t{n_tenants}", rep,
                         derived=f"{n_jobs}jobs"))
    return rows[0], rows[1]


def run(n_jobs: int = 128,
        rates: "tuple[float, ...]" = (2.0, 8.0),
        tenant_counts: "tuple[int, ...]" = (2, 4, 8),
        max_concurrent_jobs: int = 32) -> "list[dict]":
    rows: list[dict] = []

    # -- 1. arrival-rate sweep: shared vs isolated at each rate -------------
    for rate in rates:
        shared, isolated = shared_isolated_pair(
            n_jobs, rate, n_tenants=4,
            max_concurrent_jobs=max_concurrent_jobs)
        rows += [shared, isolated]

    # -- 2. tenant-count sweep on the shared account ------------------------
    for n_tenants in tenant_counts:
        rep = orchestrate(n_jobs, rates[0], n_tenants, isolated=False,
                          max_concurrent_jobs=max_concurrent_jobs)
        rows.append(_row(f"shared_tenants{n_tenants}", rep,
                         derived=f"{n_jobs}jobs@r{rates[0]:g}"))
    return rows


def main() -> None:
    common.emit(run(), "fig15")


if __name__ == "__main__":
    main()
