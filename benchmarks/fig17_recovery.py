"""Fig. 17 (beyond-paper): orchestrator crash recovery cost curves.

The durable control plane (repro.core.statemachine + the orchestrator's
journal-then-act dispatch loop) claims that killing the dispatcher at
any protocol point loses no completed work: a fresh orchestrator replays
the journal, returns journaled-complete jobs verbatim (bit-identical
billing — no double execution), re-admits in-flight jobs with resume
semantics over their durable task outputs, and purges orphaned
namespaces.

Fig. 17 prices that claim. For each crash point ("admit" — after
journaling ADMITTED, before the runner exists; "dispatch" — after the
runner actor is spawned; "complete" — after journaling the terminal
record, before the namespace purge) x crash occurrence x simulation
substrate, a run is crashed once via ``run_with_recovery`` and compared
against the uncrashed baseline on:

- **recovery overhead**: makespan delta vs the baseline (replay +
  re-admission + redone work);
- **re-executed work**: extra task attempts beyond the baseline's
  (work the crash forced the system to redo despite resume);
- **resumed work**: task outputs reused from the durable store instead
  of re-executed;
- **journal parity**: every journaled-complete job's billed USD and
  latency bit-identical to the baseline record — the no-double-billing
  acceptance criterion, asserted by the ``--smoke`` gate on BOTH
  substrates.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import (
    EngineConfig,
    FaultConfig,
    JobOrchestrator,
    OrchestratorConfig,
    TenantSpec,
    WorkloadConfig,
)

CRASH_POINTS = ("admit", "dispatch", "complete")

# Tiered tenants: the recovery sweep doubles as the per-tier SLO
# accounting demo (premium admitted first, batch last, both recovered).
_TENANTS = (
    TenantSpec("prem-00", 3584, tier="premium", priority=2, slo_s=30.0),
    TenantSpec("std-00", 1792, tier="standard", priority=1, slo_s=120.0),
    TenantSpec("std-01", 896, tier="standard", priority=1, slo_s=120.0),
    TenantSpec("batch-00", 1792, tier="batch", priority=0),
)


def _engine_config(substrate: str) -> EngineConfig:
    return EngineConfig(cost=common.cost(substrate=substrate,
                                         cold_start_ms=250.0),
                        num_initial_invokers=4, num_proxy_invokers=4,
                        max_concurrency=512)


def _orch_config(n_jobs: int, rate: float, substrate: str,
                 crash_point: "str | None" = None, crash_at: int = 1,
                 max_concurrent_jobs: int = 8, seed: int = 0,
                 ) -> OrchestratorConfig:
    return OrchestratorConfig(
        engine=_engine_config(substrate),
        workload=WorkloadConfig(n_jobs=n_jobs, arrival_rate_per_s=rate,
                                tenants=_TENANTS, seed=seed),
        max_concurrent_jobs=max_concurrent_jobs,
        faults=FaultConfig(orchestrator_crash_point=crash_point,
                           orchestrator_crash_at=crash_at),
    )


def _total_attempts(rep) -> int:
    return sum(r.get("fault_stats", {}).get("task_attempts", 0)
               for r in rep.job_records)


def _journal_parity(rep, base_by_id: dict) -> "tuple[bool, bool]":
    """(record parity, per-tenant billing-sum parity) of the recovered
    run's journaled-complete jobs vs the uncrashed baseline."""
    from_journal = [r for r in rep.job_records if r.get("from_journal")]
    rec_ok = all(
        r["billed_usd"] == base_by_id[r["job_id"]]["billed_usd"]
        and r["latency_s"] == base_by_id[r["job_id"]]["latency_s"]
        for r in from_journal)
    tenants = {r["tenant"] for r in from_journal}
    sums_ok = all(
        sum(r["billed_usd"] for r in from_journal if r["tenant"] == t)
        == sum(base_by_id[r["job_id"]]["billed_usd"]
               for r in from_journal if r["tenant"] == t)
        for t in tenants)
    return rec_ok, sums_ok


def _row(label: str, rep, base=None, derived: str = "") -> dict:
    row = {
        "label": label,
        "wall_s": rep.makespan_s,
        "jobs": rep.jobs,
        "completed": rep.completed,
        "failed": rep.failed,
        "crashes": rep.crashes,
        "recovered_jobs": rep.recovered_jobs,
        "tasks_resumed": rep.tasks_resumed,
        "task_attempts": _total_attempts(rep),
        "p50_s": rep.p50_s,
        "p99_s": rep.p99_s,
        "billed_usd_total": rep.billed_usd_total,
        "per_tier": rep.per_tier,
    }
    bits = [derived] if derived else []
    if base is not None:
        base_by_id = {r["job_id"]: r for r in base.job_records}
        rec_ok, sums_ok = _journal_parity(rep, base_by_id)
        n_journal = sum(1 for r in rep.job_records if r.get("from_journal"))
        row["from_journal"] = n_journal
        row["journal_parity"] = rec_ok
        row["billing_parity"] = sums_ok
        row["recovery_overhead_s"] = rep.makespan_s - base.makespan_s
        row["reexecuted_attempts"] = (_total_attempts(rep)
                                      - _total_attempts(base))
        bits.append(f"overhead={row['recovery_overhead_s']:.3f}s")
        bits.append(f"redo={row['reexecuted_attempts']}attempts")
        bits.append(f"resumed={rep.tasks_resumed}")
        bits.append(f"parity={'ok' if rec_ok and sums_ok else 'BROKEN'}")
    else:
        bits.append(f"{rep.jobs}jobs")
        bits.append(f"p50={rep.p50_s:.3f}s")
    row["derived"] = " ".join(bits)
    return row


def run(n_jobs: int = 24, rate: float = 8.0,
        crash_ats: "tuple[int, ...]" = (1, 4),
        substrates: "tuple[str, ...]" = ("event", "thread"),
        max_concurrent_jobs: int = 8) -> "list[dict]":
    rows: list[dict] = []
    for substrate in substrates:
        base = JobOrchestrator(
            _orch_config(n_jobs, rate, substrate,
                         max_concurrent_jobs=max_concurrent_jobs)).run()
        rows.append(_row(f"{substrate}_baseline", base,
                         derived=f"{n_jobs}jobs@r{rate:g}"))
        for point in CRASH_POINTS:
            for crash_at in crash_ats:
                cfg = _orch_config(n_jobs, rate, substrate,
                                   crash_point=point, crash_at=crash_at,
                                   max_concurrent_jobs=max_concurrent_jobs)
                rep = JobOrchestrator(cfg).run_with_recovery()
                rows.append(_row(f"{substrate}_{point}_at{crash_at}",
                                 rep, base=base))
    return rows


def check_gates(rows: "list[dict]") -> None:
    """CI regression gate (run.py --smoke): every crashed run on every
    substrate recovered completely with bit-identical journal billing."""
    crashed = [r for r in rows if "crashes" in r and r["crashes"] > 0]
    assert crashed, "recovery gate: no crashed runs in fig17 rows"
    for row in crashed:
        assert row["completed"] == row["jobs"], (
            f"recovery regression: {row['label']} completed "
            f"{row['completed']}/{row['jobs']} jobs after recovery")
        assert row["failed"] == 0, (
            f"recovery regression: {row['label']} failed {row['failed']}")
        assert row["journal_parity"], (
            f"recovery regression: {row['label']} returned journaled "
            f"records differing from the uncrashed baseline")
        assert row["billing_parity"], (
            f"recovery regression: {row['label']} per-tenant billing of "
            f"journaled-complete jobs diverged from the baseline")
    # at least one sweep point must exercise actual resume-over-durable-
    # outputs (otherwise the resume path is silently untested)
    assert any(r["tasks_resumed"] > 0 for r in crashed), (
        "recovery regression: no sweep point resumed durable outputs")
    import sys
    resumed = sum(r["tasks_resumed"] for r in crashed)
    print(f"# recovery gate OK: {len(crashed)} crashed sweeps recovered "
          f"to completion, journal billing bit-identical, "
          f"{resumed} task outputs resumed", file=sys.stderr)


def main() -> None:
    common.emit(run(), "fig17")


if __name__ == "__main__":
    main()
