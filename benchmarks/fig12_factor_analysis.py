"""Fig. 12: factor analysis — contribution of each optimization.

Cumulative versions from strawman to full WUKONG. Paper claims the
decentralization of Task Executors is the single largest factor; then
parallel invokers, the KV-proxy for large fan-outs, pub/sub, and giving
each KV shard its own VM (NIC decontention).

Beyond-paper axis: the *warm Lambda pool* (paper §V-A warms a pool so
invocations skip container cold starts). ``7_cold_pool`` re-runs the
full WUKONG configuration with a cold-start distribution — only
``warm_fraction`` of invocations hit a warm container; the rest pay
``cold_start_ms`` — plus seeded lognormal invoke-latency jitter, the
latency-distribution realism the virtual clock makes deterministic.
The 6→7 gap is the warm pool's contribution.
"""
from __future__ import annotations

from benchmarks import common
from repro.apps import tree_reduction_dag
from repro.core import (
    EngineConfig,
    ParallelInvokerEngine,
    PubSubEngine,
    StrawmanEngine,
    WukongEngine,
)


def run(n: int = 512, delay_ms: float = 20.0,
        payload_bytes: int = 4 << 20,
        cold_warm_fraction: float = 0.5,
        cold_invoke_sigma: float = 0.25) -> list[dict]:
    # wide fan-outs (n/2 leaves) + 4MB edge payloads: exercises the proxy
    # and the per-shard NIC contention the paper's factors 5/6 target
    dagf = lambda: tree_reduction_dag(
        n, compute_ms=delay_ms, payload_bytes=payload_bytes)
    rows = []
    # Factors are cumulative; "own VM per KV shard" arrived LAST in the
    # paper, so every earlier version runs with colocated shards.
    steps = [
        ("1_strawman", StrawmanEngine(
            cost=common.cost(), colocate_kv_shards=True)),
        ("2_pubsub", PubSubEngine(
            cost=common.cost(), colocate_kv_shards=True)),
        ("3_parallel_invoker", ParallelInvokerEngine(
            cost=common.cost(), colocate_kv_shards=True)),
        # decentralized Task Executors (static schedules + local caches):
        ("4_decentralized", WukongEngine(EngineConfig(
            cost=common.cost(), use_proxy=False, colocate_kv_shards=True))),
        ("5_plus_proxy", WukongEngine(EngineConfig(
            cost=common.cost(), use_proxy=True, colocate_kv_shards=True))),
        ("6_sharded_vms", WukongEngine(EngineConfig(
            cost=common.cost(), use_proxy=True, colocate_kv_shards=False))),
        # ...and what full WUKONG would cost WITHOUT the warm pool:
        ("7_cold_pool", WukongEngine(EngineConfig(
            cost=common.cost(warm_fraction=cold_warm_fraction,
                             invoke_sigma=cold_invoke_sigma),
            use_proxy=True, colocate_kv_shards=False))),
    ]
    for label, eng in steps:
        r = common.timed(eng, dagf())
        r["label"] = label
        r["derived"] = f"delay={delay_ms:g}ms"
        rows.append(r)
    return rows


def main() -> None:
    common.emit(run(), "fig12")


if __name__ == "__main__":
    main()
