"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_fallback import given, settings, st

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.linear_attention import mlstm_chunk
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    mlstm_chunk_ref,
)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,bq,bk", [
    (1, 128, 2, 2, 64, 64, 64),      # MHA
    (2, 256, 4, 2, 64, 128, 64),     # GQA 2:1
    (1, 256, 8, 1, 32, 64, 128),     # MQA
    (2, 512, 4, 4, 128, 128, 128),   # bigger head_dim
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (False, None), (True, 128),
])
def test_flash_attention_sweep(dtype, B, S, H, K, hd, bq, bk, causal,
                               window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, S, H, hd), dtype)
    k = rand(ks[1], (B, S, K, hd), dtype)
    v = rand(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,bk", [
    (2, 512, 8, 2, 64, 128),
    (3, 1024, 4, 4, 32, 256),
    (1, 256, 16, 2, 128, 64),
])
def test_decode_attention_sweep(dtype, B, S, H, K, hd, bk):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (B, H, hd), dtype)
    kc = rand(ks[1], (B, S, K, hd), dtype)
    vc = rand(ks[2], (B, S, K, hd), dtype)
    kv_len = jnp.asarray([S, max(1, S // 2), 7][:B], dtype=jnp.int32)
    out = decode_attention(q, kc, vc, kv_len, block_k=bk)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 128, 2, 32, 32),
    (1, 256, 4, 64, 64),
    (2, 256, 1, 16, 128),
])
def test_mlstm_chunk_sweep(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = rand(ks[0], (B, S, H, hd), jnp.float32) * 0.5
    k = rand(ks[1], (B, S, H, hd), jnp.float32) * 0.5
    v = rand(ks[2], (B, S, H, hd), jnp.float32)
    log_f = jax.nn.log_sigmoid(rand(ks[3], (B, S, H), jnp.float32))
    i_g = jax.nn.sigmoid(rand(ks[4], (B, S, H), jnp.float32))
    out = mlstm_chunk(q, k, v, log_f, i_g, chunk=chunk)
    ref = mlstm_chunk_ref(q, k, v, log_f, i_g, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
def test_flash_attention_property(s_blocks, h, g, causal):
    """Property: kernel == oracle for arbitrary block-aligned shapes and
    GQA group sizes."""
    S = 64 * s_blocks
    H, K, hd = h * g, h, 32
    ks = jax.random.split(jax.random.PRNGKey(S + H + causal), 3)
    q = rand(ks[0], (1, S, H, hd), jnp.float32)
    k = rand(ks[1], (1, S, K, hd), jnp.float32)
    v = rand(ks[2], (1, S, K, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_sdpa():
    """The kernel agrees with the model's attention oracle (layers.sdpa)."""
    from repro.models.layers import sdpa
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = rand(ks[0], (2, 128, 4, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_mlstm_kernel_matches_model_layer():
    """Kernel output matches repro.models.ssm.mlstm's inner computation
    (same gating math, zero initial state)."""
    from repro.configs import get_config, reduced
    from repro.models import ssm

    cfg = reduced(get_config("xlstm_350m"))
    p, _ = ssm.init_mlstm(jax.random.PRNGKey(3), cfg)
    x = rand(jax.random.PRNGKey(4), (2, 64, cfg.d_model), jnp.float32)
    y_layer, _ = ssm.mlstm(p, x, cfg)

    dk = int(cfg.mlstm_proj_factor * cfg.d_model)
    H, hd = cfg.n_heads, dk // cfg.n_heads
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, H, hd).astype(jnp.float32) * hd ** -0.5
    k = (x @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(xf @ p["wf"])
    i_g = jnp.exp(jax.nn.log_sigmoid(xf @ p["wi"]))
    y_kernel = mlstm_chunk(q, k, v, log_f, i_g, chunk=64)
    y_kernel = y_kernel.reshape(B, S, dk) @ p["wo"]
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_layer),
                               atol=1e-4, rtol=1e-3)
