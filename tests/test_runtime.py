"""Runtime substrate: sharding rules, checkpointing, orchestrator."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core import EngineConfig, FaultConfig
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import checkpoint as ckpt
from repro.runtime import sharding as sh
from repro.runtime.orchestrator import (
    build_training_workflow,
    run_training_workflow,
)
from repro.runtime.train import build_train_step, synthetic_batch


def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


class TestShardingRules:
    def test_divisibility_guard(self):
        mesh = tiny_mesh()
        rules = {"heads": "model", "embed": None, None: None}
        # dim 4 over a 1-way axis is fine
        spec = sh.resolve_spec(("heads", "embed"), (4, 8), mesh, rules)
        assert spec == P("model", None)

    def test_no_axis_reuse(self):
        mesh = tiny_mesh()
        rules = {"heads": "model", "ff": "model", None: None}
        spec = sh.resolve_spec(("heads", "ff"), (4, 4), mesh, rules)
        assert spec == P("model", None)  # second use dropped

    def test_batch_axes_single_vs_multi(self):
        mesh = tiny_mesh()
        assert sh.batch_axes(mesh) == ("data",)

    def test_tree_shardings_cover_model(self):
        cfg = reduced(get_config("mixtral_8x7b"))
        mesh = tiny_mesh()
        rules = sh.rules_for(mesh, fsdp=True)
        aparams = M.abstract_params(cfg)
        specs = M.model_specs(cfg)
        shardings = sh.tree_shardings(aparams, specs, mesh, rules)
        assert jax.tree.structure(shardings) == jax.tree.structure(
            jax.tree.map(lambda x: 0, aparams))


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cfg = reduced(get_config("smollm_360m"))
        params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        state = {"params": params, "opt": opt}
        path = os.path.join(tmp_path, "ckpt.npz")
        ckpt.save(path, state, step=7)
        assert ckpt.latest_step(path) == 7
        like = jax.eval_shape(lambda: state)
        restored, step = ckpt.restore(path, like)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        cfg = reduced(get_config("smollm_360m"))
        params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
        path = os.path.join(tmp_path, "async.npz")
        t = ckpt.save(path, {"p": params}, step=3, async_=True)
        t.join(timeout=60)
        assert ckpt.latest_step(path) == 3

    def test_restore_resharded(self, tmp_path):
        """Elastic resume: restore onto explicit (trivial) shardings."""
        mesh = tiny_mesh()
        x = {"w": jnp.arange(16.0).reshape(4, 4)}
        path = os.path.join(tmp_path, "r.npz")
        ckpt.save(path, x, step=1)
        shardings = {"w": sh.replicated(mesh)}
        restored, _ = ckpt.restore(path, jax.eval_shape(lambda: x),
                                   shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(x["w"]))


class TestOrchestrator:
    def test_training_workflow_chain(self):
        """The cluster workflow (data -> step -> metrics + checkpoints)
        runs on the WUKONG engine and reaches the final state."""
        ckpts = []

        def init_fn():
            return 0.0

        def step_fn(state, batch):
            return state + batch, {"loss": 100.0 - state}

        def data_fn(i):
            return float(i + 1)

        dag, final_key, metric_keys = build_training_workflow(
            n_steps=6, step_fn=step_fn, init_fn=init_fn,
            checkpoint_fn=lambda st, i: ckpts.append((i, st)),
            checkpoint_every=2, data_fn=data_fn)
        res = run_training_workflow(dag, final_key, metric_keys)
        assert res.report.results[final_key] == sum(range(1, 7))
        assert [i for i, _ in sorted(ckpts)] == [1, 3, 5]

    def test_training_workflow_with_failures(self):
        """Step tasks survive injected Lambda failures via retries.
        seed=8 is a verified recoverable injection under the
        process-stable fault hash (failures at attempt 0 only), so
        completion is guaranteed regardless of executor arrival order —
        which attempt number a task runs at is order-dependent."""
        def step_fn(state, i):
            return state + 1, {}

        dag, final_key, mk = build_training_workflow(
            n_steps=5, step_fn=step_fn, init_fn=lambda: 0)
        cfg = EngineConfig(faults=FaultConfig(
            task_failure_prob=0.05, max_retries=2, seed=8))
        res = run_training_workflow(dag, final_key, mk, cfg)
        assert res.report.results[final_key] == 5

    def test_real_train_steps_through_orchestrator(self):
        """End-to-end: jitted LM train steps as DAG task payloads."""
        cfg = reduced(get_config("smollm_360m"))
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=2)
        params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        jstep = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3)))

        def init_fn():
            return (params, opt)

        def step_fn(state, i):
            p, o = state
            batch = synthetic_batch(cfg, 2, 32, seed=i)
            p, o, m = jstep(p, o, batch)
            return (p, o), {"loss": float(m["loss"])}

        dag, final_key, mk = build_training_workflow(
            n_steps=3, step_fn=step_fn, init_fn=init_fn)
        res = run_training_workflow(dag, final_key, mk)
        final_params, final_opt = res.report.results[final_key]
        assert int(final_opt["count"]) == 3
