"""DAG structure + static schedule generation (paper §IV-B)."""
import operator

import pytest

from repro.core import DAG, GraphBuilder, delayed_graph
from repro.core.dag import CycleError, Task, TaskRef
from repro.core.schedule import generate_static_schedules


def fig6_dag() -> DAG:
    """The paper's Figure 6 example: two leaves, shared T4/T6."""
    g = GraphBuilder()
    t1 = g.add(lambda: 1, name="T1")
    t2 = g.add(lambda: 2, name="T2")
    t3 = g.add(lambda x: x + 10, t2, name="T3")
    t5 = g.add(lambda x: x * 2, t3, name="T5")
    g.add(operator.add, t1, t3, name="T4")
    g.add(operator.add, TaskRef("T4"), t5, name="T6")
    return g.build()


class TestDAG:
    def test_leaves_roots(self):
        dag = fig6_dag()
        assert set(dag.leaves) == {"T1", "T2"}
        assert set(dag.roots) == {"T6"}

    def test_topological_order(self):
        dag = fig6_dag()
        order = dag.topological_order()
        pos = {k: i for i, k in enumerate(order)}
        for k, deps in dag.deps.items():
            for d in deps:
                assert pos[d] < pos[k]

    def test_cycle_detection(self):
        with pytest.raises(CycleError):
            DAG([
                Task("a", lambda x: x, (TaskRef("b"),)),
                Task("b", lambda x: x, (TaskRef("a"),)),
            ])

    def test_missing_dep(self):
        with pytest.raises(ValueError, match="missing"):
            DAG([Task("a", lambda x: x, (TaskRef("zzz"),))])

    def test_duplicate_key(self):
        with pytest.raises(ValueError, match="duplicate"):
            DAG([Task("a", lambda: 1), Task("a", lambda: 2)])

    def test_from_dsk(self):
        dag = delayed_graph({
            "x": 1,
            "y": (operator.add, "x", 10),
        })
        assert dag.deps["y"] == ("x",)
        assert dag.leaves == ("x",)

    def test_reachability(self):
        dag = fig6_dag()
        assert dag.reachable_from("T1") == {"T1", "T4", "T6"}
        assert dag.reachable_from("T2") == {"T2", "T3", "T4", "T5", "T6"}


class TestStaticSchedules:
    def test_one_schedule_per_leaf(self):
        dag = fig6_dag()
        ss = generate_static_schedules(dag)
        assert set(ss.schedules) == {"T1", "T2"}

    def test_schedule_contents_match_paper(self):
        """Figure 6(b): schedule 1 = {T1,T4,T6}; schedule 2 covers the
        rest and the shared nodes T4, T6 appear in BOTH."""
        dag = fig6_dag()
        ss = generate_static_schedules(dag)
        assert ss.schedules["T1"].nodes == {"T1", "T4", "T6"}
        assert ss.schedules["T2"].nodes == {"T2", "T3", "T4", "T5", "T6"}
        shared = ss.schedules["T1"].nodes & ss.schedules["T2"].nodes
        assert shared == {"T4", "T6"}

    def test_fan_in_counters(self):
        dag = fig6_dag()
        counters = generate_static_schedules(dag).fan_in_counters()
        assert counters == {"__fanin__/T4": 2, "__fanin__/T6": 2}

    def test_code_size_positive(self):
        dag = fig6_dag()
        ss = generate_static_schedules(dag)
        for s in ss.schedules.values():
            assert s.code_size_bytes > 0
