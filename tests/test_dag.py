"""DAG structure + static schedule generation (paper §IV-B)."""
import operator
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_fallback import given, settings, st

from repro.core import DAG, GraphBuilder, delayed_graph
from repro.core.dag import CycleError, Task, TaskRef
from repro.core.schedule import generate_static_schedules


def fig6_dag() -> DAG:
    """The paper's Figure 6 example: two leaves, shared T4/T6."""
    g = GraphBuilder()
    t1 = g.add(lambda: 1, name="T1")
    t2 = g.add(lambda: 2, name="T2")
    t3 = g.add(lambda x: x + 10, t2, name="T3")
    t5 = g.add(lambda x: x * 2, t3, name="T5")
    g.add(operator.add, t1, t3, name="T4")
    g.add(operator.add, TaskRef("T4"), t5, name="T6")
    return g.build()


class TestDAG:
    def test_leaves_roots(self):
        dag = fig6_dag()
        assert set(dag.leaves) == {"T1", "T2"}
        assert set(dag.roots) == {"T6"}

    def test_topological_order(self):
        dag = fig6_dag()
        order = dag.topological_order()
        pos = {k: i for i, k in enumerate(order)}
        for k, deps in dag.deps.items():
            for d in deps:
                assert pos[d] < pos[k]

    def test_cycle_detection(self):
        with pytest.raises(CycleError):
            DAG([
                Task("a", lambda x: x, (TaskRef("b"),)),
                Task("b", lambda x: x, (TaskRef("a"),)),
            ])

    def test_missing_dep(self):
        with pytest.raises(ValueError, match="missing"):
            DAG([Task("a", lambda x: x, (TaskRef("zzz"),))])

    def test_duplicate_key(self):
        with pytest.raises(ValueError, match="duplicate"):
            DAG([Task("a", lambda: 1), Task("a", lambda: 2)])

    def test_from_dsk(self):
        dag = delayed_graph({
            "x": 1,
            "y": (operator.add, "x", 10),
        })
        assert dag.deps["y"] == ("x",)
        assert dag.leaves == ("x",)

    def test_reachability(self):
        dag = fig6_dag()
        assert dag.reachable_from("T1") == {"T1", "T4", "T6"}
        assert dag.reachable_from("T2") == {"T2", "T3", "T4", "T5", "T6"}


class TestStaticSchedules:
    def test_one_schedule_per_leaf(self):
        dag = fig6_dag()
        ss = generate_static_schedules(dag)
        assert set(ss.schedules) == {"T1", "T2"}

    def test_schedule_contents_match_paper(self):
        """Figure 6(b): schedule 1 = {T1,T4,T6}; schedule 2 covers the
        rest and the shared nodes T4, T6 appear in BOTH."""
        dag = fig6_dag()
        ss = generate_static_schedules(dag)
        assert ss.schedules["T1"].nodes == {"T1", "T4", "T6"}
        assert ss.schedules["T2"].nodes == {"T2", "T3", "T4", "T5", "T6"}
        shared = ss.schedules["T1"].nodes & ss.schedules["T2"].nodes
        assert shared == {"T4", "T6"}

    def test_fan_in_counters(self):
        dag = fig6_dag()
        counters = generate_static_schedules(dag).fan_in_counters()
        assert counters == {"__fanin__/T4": 2, "__fanin__/T6": 2}

    def test_code_size_positive(self):
        dag = fig6_dag()
        ss = generate_static_schedules(dag)
        for s in ss.schedules.values():
            assert s.code_size_bytes > 0


# ---------------------------------------------------------------------------
# Property tests: structural invariants on random DAGs
# ---------------------------------------------------------------------------


def _sum_plus_one(*xs):
    return sum(xs) + 1


def _random_spec(seed: int, n: int) -> "list[tuple[str, list[int]]]":
    """Random acyclic wiring: node i may only read nodes < i, so every
    generated graph is a DAG by construction."""
    rng = random.Random(seed)
    spec = []
    for i in range(n):
        k = rng.randint(0, min(3, i))
        parents = sorted(rng.sample(range(i), k)) if k else []
        spec.append((f"n{i}", parents))
    return spec


def _dag_from_dsk(spec) -> DAG:
    dsk = {}
    for i, (key, parents) in enumerate(spec):
        if parents:
            dsk[key] = (_sum_plus_one, *[f"n{p}" for p in parents])
        else:
            dsk[key] = i  # literal leaf
    return DAG.from_dsk(dsk)


def _dag_from_builder(spec) -> DAG:
    g = GraphBuilder()
    for i, (key, parents) in enumerate(spec):
        if parents:
            g.add(_sum_plus_one, *[TaskRef(f"n{p}") for p in parents],
                  name=key)
        else:
            g.literal(i, name=key)
    return g.build()


def _evaluate(dag: DAG) -> dict:
    vals = {}
    for k in dag.topological_order():
        t = dag.tasks[k]
        args = [vals[a.key] if isinstance(a, TaskRef) else a
                for a in t.args]
        vals[k] = t.fn(*args)
    return vals


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 60))
def test_topological_order_respects_all_edges(seed, n):
    """Property: every dependency precedes its dependent."""
    dag = _dag_from_dsk(_random_spec(seed, n))
    order = dag.topological_order()
    assert sorted(order) == sorted(dag.tasks)
    pos = {k: i for i, k in enumerate(order)}
    for k, deps in dag.deps.items():
        for d in deps:
            assert pos[d] < pos[k]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 60))
def test_critical_path_bounded_by_dag_size(seed, n):
    """Property: 1 <= critical_path_length <= |V| (the longest chain
    cannot visit a task twice in an acyclic graph)."""
    dag = _dag_from_dsk(_random_spec(seed, n))
    cp = dag.critical_path_length()
    assert 1 <= cp <= len(dag)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 60))
def test_from_dsk_agrees_with_graph_builder(seed, n):
    """Property: the Dask-dict front-end and the GraphBuilder front-end
    produce structurally identical DAGs that evaluate identically."""
    spec = _random_spec(seed, n)
    a, b = _dag_from_dsk(spec), _dag_from_builder(spec)
    assert set(a.tasks) == set(b.tasks)
    assert a.deps == b.deps
    assert a.children == b.children
    assert a.leaves == b.leaves
    assert a.roots == b.roots
    assert a.critical_path_length() == b.critical_path_length()
    assert _evaluate(a) == _evaluate(b)
