"""Virtual-time simulation core (PR 3): determinism, scale, primitives.

The tentpole properties:

- *determinism*: two identical virtual-mode runs produce bit-identical
  ``wall_s`` / ``charged_ms`` / metrics / results — even with latency
  jitter, cold starts, and fault injection enabled (all draws are
  seeded, and the cooperative scheduler serializes actors in a
  reproducible order).
- *scale decoupling*: simulated seconds cost zero wall time, so a
  4096-leaf tree reduction (~8k tasks, minutes of simulated time)
  completes in seconds of wall time, and ``job_timeout_s`` means
  *simulated* seconds (a timeout fires instantly in wall time).
- *cross-check*: the virtual clock charges exactly what the seed
  real-time mode charges for the same job.
"""
import queue
import time

import pytest

from repro.apps import tree_reduction_dag
from repro.apps.tree_reduction import tree_reduction_expected
from repro.core import (
    CostModel,
    EngineConfig,
    FaultConfig,
    JobError,
    WukongEngine,
)
from repro.core.simclock import (
    EventClock,
    RealtimeClock,
    VirtualClock,
    clock_for_scale,
)


# ---------------------------------------------------------------------------
# Clock primitives
# ---------------------------------------------------------------------------


class TestVirtualClockPrimitives:
    def test_mode_selection(self):
        # Event-driven is the default zero-scale substrate; the
        # thread-per-actor VirtualClock stays as the cross-check mode.
        assert isinstance(clock_for_scale(0.0), EventClock)
        assert isinstance(clock_for_scale(0.0, "thread"), VirtualClock)
        assert isinstance(clock_for_scale(0.0, "event"), EventClock)
        assert isinstance(clock_for_scale(0.1), RealtimeClock)
        with pytest.raises(ValueError):
            clock_for_scale(0.0, "bogus")

    def test_charge_outside_actor_accumulates_without_advancing(self):
        clock = VirtualClock()
        clock.charge(123.0)
        assert clock.charged_ms == 123.0
        assert clock.now_ms() == 0.0

    def test_actor_charge_advances_virtual_time(self):
        clock = VirtualClock()
        with clock.actor():
            clock.charge(250.0)
            clock.charge(125.0)
            assert clock.now_ms() == 375.0
        assert clock.charged_ms == 375.0

    def test_sleepers_wake_in_deadline_order(self):
        clock = VirtualClock()
        wakes = []

        def sleeper(ms):
            def body():
                clock.sleep_ms(ms)
                wakes.append((ms, clock.now_ms()))
            return body

        for ms in (300.0, 100.0, 200.0):
            clock.spawn(sleeper(ms), name=f"s{ms}")
        deadline = time.monotonic() + 5.0
        while len(wakes) < 3 and time.monotonic() < deadline:
            time.sleep(0.001)
        assert wakes == [(100.0, 100.0), (200.0, 200.0), (300.0, 300.0)]

    def test_queue_get_timeout_is_simulated(self):
        clock = VirtualClock()
        q = clock.queue()
        with clock.actor():
            t0 = time.perf_counter()
            with pytest.raises(queue.Empty):
                q.get(timeout=3600.0)  # one simulated hour...
            real = time.perf_counter() - t0
            assert clock.now_ms() == pytest.approx(3600e3)
        assert real < 5.0  # ...costs (essentially) zero wall time

    def test_queue_put_wakes_blocked_actor(self):
        clock = VirtualClock()
        q = clock.queue()
        got = []

        def consumer():
            got.append(q.get(timeout=60.0))

        clock.spawn(consumer, name="consumer")
        with clock.actor():
            clock.charge(5.0)  # let the consumer block first
            q.put("payload")
        deadline = time.monotonic() + 5.0
        while not got and time.monotonic() < deadline:
            time.sleep(0.001)
        assert got == ["payload"]
        assert clock.now_ms() < 60e3  # woken by the put, not the timeout

    def test_lock_contention_charges_waiters_for_the_hold(self):
        clock = VirtualClock()
        lane = clock.lock()
        spans = []

        def transfer(ms):
            def body():
                with lane:
                    t0 = clock.now_ms()
                    clock.charge(ms)
                    spans.append((t0, clock.now_ms()))
            return body

        for _ in range(3):
            clock.spawn(transfer(100.0), name="t")
        deadline = time.monotonic() + 5.0
        while len(spans) < 3 and time.monotonic() < deadline:
            time.sleep(0.001)
        # serialized: each holder's span starts when the previous ends
        assert spans == [(0.0, 100.0), (100.0, 200.0), (200.0, 300.0)]

    def test_event_wait_timeout_and_set(self):
        clock = VirtualClock()
        ev = clock.event()
        with clock.actor():
            assert ev.wait(timeout=0.5) is False  # simulated 500 ms
            assert clock.now_ms() == pytest.approx(500.0)
            ev.set()
            assert ev.wait(timeout=0.5) is True
            assert clock.now_ms() == pytest.approx(500.0)  # no extra wait

    def test_nonactor_threads_still_block_for_real(self):
        # Unit-test usage: no actors anywhere, plain threads must not
        # deadlock on the clock-aware primitives.
        import threading

        clock = VirtualClock()
        q = clock.queue()
        out = []
        t = threading.Thread(target=lambda: out.append(q.get(timeout=5.0)))
        t.start()
        q.put(42)
        t.join(timeout=5.0)
        assert out == [42]


# ---------------------------------------------------------------------------
# Engine-level determinism
# ---------------------------------------------------------------------------


def _rich_config():
    """Virtual-mode engine exercising every stochastic knob: latency
    jitter, cold starts, fault injection with retry backoff."""
    return EngineConfig(
        cost=CostModel(invoke_sigma=0.3, warm_fraction=0.7, latency_seed=7),
        faults=FaultConfig(task_failure_prob=0.04, max_retries=2, seed=21,
                           retry_backoff_base_ms=1000.0),
    )


class TestDeterminism:
    def test_identical_runs_bit_identical(self):
        """Acceptance: two identical seeded virtual runs agree on
        results, wall_s, charged_ms, AND the full metrics trace."""
        reps = []
        for _ in range(2):
            dag = tree_reduction_dag(64, compute_ms=250.0,
                                     payload_bytes=1 << 16)
            reps.append(WukongEngine(_rich_config()).compute(dag))
        a, b = reps
        (ka, va), = a.results.items()
        (kb, vb), = b.results.items()
        assert ka == kb and va[0] == vb[0] == tree_reduction_expected(64)
        assert a.wall_s == b.wall_s
        assert a.charged_ms == b.charged_ms
        assert a.kv_stats == b.kv_stats
        assert a.executors_invoked == b.executors_invoked
        assert a.metrics == b.metrics  # same records, same ORDER

    def test_metrics_stamped_with_virtual_time(self):
        rep = WukongEngine().compute(tree_reduction_dag(16,
                                                        compute_ms=100.0))
        stamps = [m["at_ms"] for m in rep.metrics]
        assert stamps and all(s >= 0.0 for s in stamps)
        assert max(stamps) <= rep.wall_s * 1e3 + 1e-6
        # simulated compute is visible in the per-task breakdown
        executed = [m for m in rep.metrics if m.get("event") == "executed"]
        assert all(m["compute_ms"] == pytest.approx(100.0)
                   for m in executed)

    def test_latency_seed_changes_the_trace(self):
        def run(seed):
            cfg = EngineConfig(cost=CostModel(
                invoke_sigma=0.3, warm_fraction=0.5, latency_seed=seed))
            return WukongEngine(cfg).compute(
                tree_reduction_dag(32, compute_ms=50.0))

        assert run(1).charged_ms != run(2).charged_ms
        assert run(3).charged_ms == run(3).charged_ms

    def test_cold_starts_cost_more_than_warm_pool(self):
        def run(warm):
            cfg = EngineConfig(cost=CostModel(warm_fraction=warm))
            return WukongEngine(cfg).compute(tree_reduction_dag(32))

        assert run(0.0).charged_ms > run(1.0).charged_ms


class TestCrossCheck:
    def test_virtual_matches_realtime_charges(self):
        """The virtual substrate must charge exactly what the seed
        real-time mode charges for the same job (protocol equivalence;
        only the passage of wall time differs)."""
        def run(scale):
            cfg = EngineConfig(cost=CostModel(time_scale=scale))
            return WukongEngine(cfg).compute(
                tree_reduction_dag(16, compute_ms=20.0))

        virt = run(0.0)
        real = run(0.001)
        assert virt.charged_ms == pytest.approx(real.charged_ms)
        assert virt.kv_stats == real.kv_stats
        (_, v), = virt.results.items()
        (_, r), = real.results.items()
        assert v[0] == r[0]


# ---------------------------------------------------------------------------
# Scale: simulated seconds are free
# ---------------------------------------------------------------------------


class TestScale:
    def test_job_timeout_means_simulated_seconds(self):
        """A 10-simulated-minute timeout on a stuck job fires instantly
        in wall time: the clock jumps straight to the deadline."""
        cfg = EngineConfig(
            cost=CostModel(),
            job_timeout_s=600.0,
            # a task that "runs" 20 simulated minutes can never finish
            faults=FaultConfig(straggler_prob=1.0,
                               straggler_slowdown_ms=1200e3, seed=1),
        )
        t0 = time.perf_counter()
        with pytest.raises(JobError, match="timed out"):
            WukongEngine(cfg).compute(tree_reduction_dag(4))
        assert time.perf_counter() - t0 < 30.0

    def test_4096_leaf_tree_reduction_under_wall_budget(self):
        """Acceptance: a 4096-leaf TR (8191 tasks, ~7 simulated minutes)
        completes correctly within a wall-time budget in virtual mode —
        the DAG scale the 512-thread real-time cap could never reach."""
        n = 8192  # 4096 leaf tasks
        dag = tree_reduction_dag(n, compute_ms=500.0)
        cfg = EngineConfig(max_concurrency=8192, job_timeout_s=3600.0)
        t0 = time.perf_counter()
        rep = WukongEngine(cfg).compute(dag)
        wall = time.perf_counter() - t0
        (_, v), = rep.results.items()
        assert v[0] == tree_reduction_expected(n)
        assert rep.tasks == n - 1
        assert rep.wall_s > 10.0       # minutes of simulated time...
        assert wall < 120.0            # ...in seconds of wall time


class TestRetryBackoff:
    def test_backoff_is_charged_not_slept(self):
        base = EngineConfig(faults=FaultConfig(
            task_failure_prob=0.04, max_retries=2, seed=21))
        slow = EngineConfig(faults=FaultConfig(
            task_failure_prob=0.04, max_retries=2, seed=21,
            retry_backoff_base_ms=60e3))  # Lambda-style ~1 min waits
        dag = tree_reduction_dag(32)
        r0 = WukongEngine(base).compute(tree_reduction_dag(32))
        t0 = time.perf_counter()
        r1 = WukongEngine(slow).compute(dag)
        assert time.perf_counter() - t0 < 30.0  # backoff cost no wall time
        (_, v), = r1.results.items()
        assert v[0] == tree_reduction_expected(32)
        assert r1.charged_ms - r0.charged_ms >= 60e3 - 1.0  # >= one backoff
