"""Stateful FaaS platform model (repro.platform): pool, throttle, billing.

The tentpole properties:

- *keep-alive expiry* is driven by the engine clock: a container
  released and re-acquired within the window is warm; past it, cold.
- *determinism*: two identical virtual-clock runs produce bit-identical
  ``platform_stats`` — cold/warm counts, throttle events, peak
  concurrency, and billed USD.
- *throttle-then-retry*: a burst wider than the concurrency cap gets
  429-throttled, retries with charged exponential backoff, and still
  completes correctly with peak concurrency never above the cap.
- *billing is clock-mode invariant*: billed duration is metered from
  the invocation's simulated charges (not wall deltas), so virtual and
  realtime runs of the same job bill identically.
"""
import dataclasses

import pytest

from repro.apps import tree_reduction_dag
from repro.apps.tree_reduction import tree_reduction_expected
from repro.core import (
    CostModel,
    EngineConfig,
    ParallelInvokerEngine,
    PlatformConfig,
    ServerfulConfig,
    ServerfulEngine,
    WukongEngine,
)
from repro.core.simclock import VirtualClock, charge_meter
from repro.platform import (
    BillingMeter,
    ComputeScaledClock,
    ConcurrencyThrottle,
    ContainerPool,
    FaaSPlatform,
)


# ---------------------------------------------------------------------------
# Component-level: pool / throttle / billing / config
# ---------------------------------------------------------------------------


class TestContainerPool:
    def test_keep_alive_expiry_on_virtual_clock(self):
        clock = VirtualClock()
        pool = ContainerPool(PlatformConfig(keep_alive_s=1.0), clock)
        with clock.actor():
            cid, cold = pool.acquire("f")
            assert cold
            pool.release("f", cid)
            clock.charge(500.0)  # 0.5 simulated s: still warm
            cid2, cold2 = pool.acquire("f")
            assert not cold2 and cid2 == cid
            pool.release("f", cid2)
            clock.charge(1500.0)  # past the 1 s keep-alive: expired
            cid3, cold3 = pool.acquire("f")
            assert cold3 and cid3 != cid
        assert pool.cold_starts == 2
        assert pool.warm_reuses == 1
        assert pool.expired == 1

    def test_zero_keep_alive_never_reuses(self):
        clock = VirtualClock()
        pool = ContainerPool(PlatformConfig(keep_alive_s=0.0), clock)
        with clock.actor():
            for _ in range(3):
                cid, cold = pool.acquire("f")
                assert cold
                pool.release("f", cid)
        assert pool.cold_starts == 3 and pool.warm_reuses == 0

    def test_lifo_reuse_and_per_function_isolation(self):
        clock = VirtualClock()
        pool = ContainerPool(PlatformConfig(keep_alive_s=60.0), clock)
        with clock.actor():
            a, _ = pool.acquire("f")
            b, _ = pool.acquire("f")
            pool.release("f", a)
            clock.charge(1.0)
            pool.release("f", b)
            got, cold = pool.acquire("f")
            assert got == b and not cold  # most recently released first
            other, cold_other = pool.acquire("g")
            assert cold_other  # "g" never saw a release

    def test_prewarm(self):
        clock = VirtualClock()
        pool = ContainerPool(PlatformConfig(keep_alive_s=60.0), clock)
        pool.prewarm("f", 2)
        with clock.actor():
            _, cold1 = pool.acquire("f")
            _, cold2 = pool.acquire("f")
            _, cold3 = pool.acquire("f")
        assert (cold1, cold2, cold3) == (False, False, True)


class TestConcurrencyThrottle:
    def test_burst_ramp_limit(self):
        clock = VirtualClock()
        th = ConcurrencyThrottle(PlatformConfig(
            account_concurrency=10, burst_concurrency=2,
            burst_ramp_per_min=60.0), clock)
        with clock.actor():
            assert th.limit_now() == 2
            assert th.try_reserve() and th.try_reserve()
            assert not th.try_reserve()  # 429
            assert th.throttle_events == 1
            clock.charge(1000.0)  # +1 simulated s -> +1 ramped slot
            assert th.limit_now() == 3
            assert th.try_reserve()
            clock.charge(600_000.0)  # ramp far past the account cap
            assert th.limit_now() == 10

    def test_backoff_schedule_is_charged_exponential(self):
        clock = VirtualClock()
        th = ConcurrencyThrottle(PlatformConfig(
            throttle_backoff_base_ms=100.0,
            throttle_backoff_cap_ms=350.0), clock)
        assert [th.backoff_ms(k) for k in range(4)] == [100.0, 200.0,
                                                        350.0, 350.0]

    def test_release_frees_slot(self):
        clock = VirtualClock()
        th = ConcurrencyThrottle(PlatformConfig(
            account_concurrency=1, burst_concurrency=1), clock)
        assert th.try_reserve()
        assert not th.try_reserve()
        th.release()
        assert th.try_reserve()
        assert th.peak_concurrency == 1


class TestBillingMeter:
    def test_granularity_rounds_up(self):
        meter = BillingMeter(PlatformConfig(billing_granularity_ms=100.0))
        assert meter.add_invocation(1.0) == 100.0
        assert meter.add_invocation(100.0) == 100.0
        assert meter.add_invocation(100.1) == 200.0
        assert meter.snapshot()["billed_duration_ms"] == 400.0

    def test_usd_formula(self):
        cfg = PlatformConfig(memory_mb=1024, price_per_request_usd=1e-6,
                             price_per_gb_s_usd=2e-5)
        meter = BillingMeter(cfg)
        meter.add_invocation(2000.0)  # 2 s at 1 GB -> 2 GB-s
        snap = meter.snapshot()
        assert snap["billed_requests"] == 1
        assert snap["billed_gb_s"] == pytest.approx(2.0)
        assert snap["billed_usd"] == pytest.approx(1e-6 + 2 * 2e-5)

    def test_empty(self):
        snap = BillingMeter(PlatformConfig()).snapshot()
        assert snap["billed_requests"] == 0
        assert snap["billed_usd"] == 0.0


class TestConfig:
    def test_compute_scale(self):
        assert PlatformConfig(memory_mb=896).compute_scale == 2.0
        assert PlatformConfig(memory_mb=3584).compute_scale == 0.5
        assert PlatformConfig().compute_scale == 1.0

    def test_validation(self):
        with pytest.raises(ValueError):
            PlatformConfig(memory_mb=0)
        with pytest.raises(ValueError):
            PlatformConfig(burst_concurrency=0)
        with pytest.raises(ValueError):
            PlatformConfig(throttle_backoff_base_ms=0.0)

    def test_scaled_clock_charges_scaled(self):
        clock = VirtualClock()
        scaled = ComputeScaledClock(clock, 2.0)
        with clock.actor():
            scaled.charge(100.0)
            assert clock.now_ms() == 200.0
        assert scaled.now_ms() == clock.now_ms()  # delegation


class TestChargeMeter:
    def test_accumulates_this_threads_charges(self):
        clock = VirtualClock()
        acc = [0.0]
        with charge_meter(acc):
            clock.charge(30.0)
            clock.charge(12.5)
        clock.charge(99.0)  # outside the meter
        assert acc[0] == 42.5

    def test_nesting_restores_outer(self):
        clock = VirtualClock()
        outer, inner = [0.0], [0.0]
        with charge_meter(outer):
            clock.charge(10.0)
            with charge_meter(inner):
                clock.charge(5.0)
            clock.charge(1.0)
        assert inner[0] == 5.0
        assert outer[0] == 11.0  # inner charges land innermost only


# ---------------------------------------------------------------------------
# Engine-level: the platform threaded through the invocation path
# ---------------------------------------------------------------------------


def _tr(n=32, compute_ms=5.0):
    return tree_reduction_dag(n, compute_ms=compute_ms)


def _warm_cfg(**platform_kw):
    # Few invoker lanes stagger invocations so container reuse can occur.
    return EngineConfig(
        cost=CostModel(cold_start_ms=250.0),
        platform=PlatformConfig(**platform_kw),
        num_initial_invokers=4, num_proxy_invokers=4,
    )


class TestPlatformEngine:
    def test_warm_reuse_and_correct_result(self):
        rep = WukongEngine(_warm_cfg(keep_alive_s=600.0)).compute(_tr())
        (_, root), = rep.results.items()
        assert float(root[0]) == tree_reduction_expected(32)
        ps = rep.platform_stats
        assert ps["mode"] == "pool"
        assert ps["warm_reuses"] > 0
        assert ps["cold_starts"] + ps["warm_reuses"] == ps["invocations"]
        assert ps["billed_requests"] == ps["invocations"]
        assert ps["billed_usd"] > 0

    def test_warm_reuse_deterministic_across_runs(self):
        cfg = _warm_cfg(keep_alive_s=600.0)
        r1 = WukongEngine(cfg).compute(_tr())
        r2 = WukongEngine(cfg).compute(_tr())
        assert r1.platform_stats == r2.platform_stats
        assert r1.wall_s == r2.wall_s
        assert r1.charged_ms == r2.charged_ms

    def test_warm_pool_charges_strictly_less_than_cold(self):
        warm = WukongEngine(_warm_cfg(keep_alive_s=600.0)).compute(_tr())
        cold = WukongEngine(_warm_cfg(keep_alive_s=0.0)).compute(_tr())
        assert warm.platform_stats["warm_reuses"] > 0
        assert cold.platform_stats["warm_reuses"] == 0
        assert warm.charged_ms < cold.charged_ms

    def test_throttle_then_retry_completes_under_burst(self):
        # 16 leaf invocations against a cap of 3: most of the burst is
        # 429-throttled and retried with charged backoff; the job still
        # resolves correctly and concurrency never exceeds the cap.
        cfg = EngineConfig(platform=PlatformConfig(
            account_concurrency=3, burst_concurrency=3,
            burst_ramp_per_min=0.0, keep_alive_s=600.0))
        rep = WukongEngine(cfg).compute(_tr())
        (_, root), = rep.results.items()
        assert float(root[0]) == tree_reduction_expected(32)
        ps = rep.platform_stats
        assert ps["throttle_events"] > 0
        assert ps["peak_concurrency"] <= 3
        # throttling staggered the burst into waves -> containers reused
        assert ps["warm_reuses"] > 0

    def test_throttling_charges_backoff(self):
        free = EngineConfig(platform=PlatformConfig(keep_alive_s=0.0))
        capped = EngineConfig(platform=PlatformConfig(
            account_concurrency=3, burst_concurrency=3,
            burst_ramp_per_min=0.0, keep_alive_s=0.0))
        r_free = WukongEngine(free).compute(_tr())
        r_capped = WukongEngine(capped).compute(_tr())
        assert r_free.platform_stats["throttle_events"] == 0
        assert r_capped.charged_ms > r_free.charged_ms

    def test_billed_cost_equal_virtual_vs_realtime(self):
        # Billed duration is metered from simulated charges, so the two
        # clock modes bill identically (wall_s obviously differs).
        def run(time_scale):
            cfg = EngineConfig(
                cost=CostModel(cold_start_ms=250.0, time_scale=time_scale),
                platform=PlatformConfig(),
                num_initial_invokers=4, num_proxy_invokers=4,
            )
            return WukongEngine(cfg).compute(_tr(16, compute_ms=2.0))

        virt, real = run(0.0), run(0.001)
        for field in ("billed_requests", "billed_duration_ms",
                      "billed_gb_s", "billed_usd"):
            assert virt.platform_stats[field] == \
                real.platform_stats[field], field

    def test_memory_knob_trades_cost_for_latency(self):
        small = WukongEngine(_warm_cfg(memory_mb=896)).compute(_tr())
        large = WukongEngine(_warm_cfg(memory_mb=1792)).compute(_tr())
        # half the memory -> compute runs 2x slower -> longer makespan
        assert small.wall_s > large.wall_s
        # ...but the GB-s product keeps billed cost in the same ballpark
        # (more ms x less GB), slightly cheaper for the small container
        # because the unscaled I/O time is billed over less memory.
        assert small.platform_stats["billed_usd"] < \
            large.platform_stats["billed_usd"]

    def test_prewarmed_pool_skips_all_cold_starts(self):
        rep = WukongEngine(_warm_cfg(prewarm=32)).compute(_tr())
        ps = rep.platform_stats
        assert ps["cold_starts"] == 0
        assert ps["warm_reuses"] == ps["invocations"]

    def test_centralized_engine_platform(self):
        rep = ParallelInvokerEngine(
            cost=CostModel(cold_start_ms=250.0),
            platform=PlatformConfig(keep_alive_s=600.0),
        ).compute(_tr(16, compute_ms=2.0))
        ps = rep.platform_stats
        assert ps["mode"] == "pool"
        # one Lambda per task: 15 invocations, with warm reuse across
        # the sequential dependency waves
        assert ps["invocations"] == 15
        assert ps["warm_reuses"] > 0


class TestReportingSatellites:
    def test_legacy_mode_surfaces_invoker_cold_starts(self):
        # The InvokerPool.cold_starts counter (previously incremented but
        # never reported) now rides JobReport.platform_stats.
        cfg = EngineConfig(cost=CostModel(warm_fraction=0.5,
                                          cold_start_ms=100.0))
        rep = WukongEngine(cfg).compute(_tr())
        ps = rep.platform_stats
        assert ps["mode"] == "legacy"
        assert ps["invocations"] > 0
        assert 0 < ps["cold_starts"] <= ps["invocations"]

    def test_legacy_all_warm_has_zero_cold_starts(self):
        rep = WukongEngine(EngineConfig()).compute(_tr())
        assert rep.platform_stats["cold_starts"] == 0

    def test_serverful_fixed_cluster_billing(self):
        cfg = ServerfulConfig(n_vms=5, vm_price_per_hour_usd=0.3712)
        rep = ServerfulEngine(cfg).compute(_tr())
        ps = rep.platform_stats
        assert ps["mode"] == "serverful"
        assert ps["billed_usd"] == pytest.approx(
            5 * 0.3712 * rep.wall_s / 3600.0)

    def test_platform_config_is_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            PlatformConfig().memory_mb = 512


class TestPlatformFacade:
    def test_wrap_meters_and_releases(self):
        clock = VirtualClock()
        platform = FaaSPlatform(PlatformConfig(keep_alive_s=60.0),
                                CostModel(), clock)
        with clock.actor():
            assert platform.try_reserve()
            cid, cold = platform.acquire()
            assert cold
            body = platform.wrap("executor", cid, lambda: clock.charge(7.5))
            body()
            snap = platform.snapshot()
            assert snap["billed_requests"] == 1
            assert snap["billed_duration_ms"] == 8.0  # ceil to 1 ms
            assert platform.throttle.active == 0
            # container back in the pool, warm
            _, cold2 = platform.acquire()
            assert not cold2

    def test_cancel_returns_slot_and_container_unbilled(self):
        clock = VirtualClock()
        platform = FaaSPlatform(PlatformConfig(keep_alive_s=60.0),
                                CostModel(), clock)
        with clock.actor():
            assert platform.try_reserve()
            cid, _ = platform.acquire()
            platform.cancel("executor", cid)
            assert platform.throttle.active == 0
            assert platform.snapshot()["billed_requests"] == 0
