"""DAG compiler passes: correctness equivalence + pass-specific invariants.

The central property: an optimized DAG computes exactly what a sequential
topological evaluation of the ORIGINAL graph computes, on every engine and
under every pass combination. Pass-specific invariants: fusion never
crosses a fan-in/fan-out boundary, clustering strictly reduces KV ``set``
counts, coalescing strictly reduces executor invocations.
"""
import itertools
import operator
import random

import pytest

from repro.core import (
    ALL_PASSES,
    NO_PASSES,
    CompiledDAG,
    EngineConfig,
    FaultConfig,
    GraphBuilder,
    OptimizeConfig,
    ParallelInvokerEngine,
    PubSubEngine,
    ServerfulConfig,
    ServerfulEngine,
    StrawmanEngine,
    WukongEngine,
    compile_dag,
)
from repro.core.dag import TaskRef
from repro.core.optimize import (
    coalesce_leaves,
    compute_clusters,
    find_chains,
    fuse_linear_chains,
    fusible_edges,
)


def seq_eval(dag):
    vals = {}
    for k in dag.topological_order():
        t = dag.tasks[k]
        args = [vals[a.key] if isinstance(a, TaskRef) else a for a in t.args]
        kwargs = {kk: vals[v.key] if isinstance(v, TaskRef) else v
                  for kk, v in t.kwargs.items()}
        vals[k] = t.fn(*args, **kwargs)
    return {k: vals[k] for k in dag.roots}


# -- DAG zoo ---------------------------------------------------------------


def chain_dag(n=20):
    """A pure linear chain (all interior edges fusible)."""
    g = GraphBuilder()
    cur = g.add(lambda: 1, name="start")
    for i in range(n):
        cur = g.add(lambda x: x + 1, cur, name=f"c{i}")
    return g.build()


def chained_fanin_dag(links=8):
    """A chain of fan-in diamonds: x_i = h(f(x_{i-1}), g(x_{i-1})).

    Every link has a width-2 fan-out followed by a width-2 fan-in, so no
    edge is fusible — isolating the clustering pass's delayed I/O.
    """
    g = GraphBuilder()
    cur = g.add(lambda: 1, name="x0")
    for i in range(links):
        a = g.add(lambda x: x + 1, cur, name=f"a{i}")
        b = g.add(lambda x: x * 2, cur, name=f"b{i}")
        cur = g.add(operator.add, a, b, name=f"x{i + 1}")
    return g.build()


def tree_dag(n):
    g = GraphBuilder()
    level = [g.add((lambda v: (lambda: v))(i), name=f"leaf-{i}")
             for i in range(n)]
    d = 0
    while len(level) > 1:
        level = [g.add(operator.add, level[i], level[i + 1],
                       name=f"add-{d}-{i // 2}")
                 for i in range(0, len(level), 2)]
        d += 1
    return g.build()


def random_dag(seed: int, n: int):
    rng = random.Random(seed)
    g = GraphBuilder()
    refs = []
    for i in range(n):
        k = rng.randint(0, min(4, len(refs)))
        deps = rng.sample(refs, k) if k else []
        if deps:
            refs.append(g.add(lambda *xs: sum(xs) + 1, *deps, name=f"n{i}"))
        else:
            refs.append(g.add((lambda v: (lambda: v))(i), name=f"n{i}"))
    return g.build()


def mixed_dag():
    """Chains + fan-outs + fan-ins + a wide sibling layer in one graph."""
    g = GraphBuilder()
    src = g.add(lambda: 2, name="src")
    pre = g.add(lambda x: x + 3, src, name="pre")      # fusible src->pre
    outs = []
    for i in range(12):
        h = g.add(lambda x, i=i: x * i, pre, name=f"h{i}")
        t = g.add(lambda x: x - 1, h, name=f"t{i}")    # fusible h->t
        outs.append(t)
    mid = g.add(lambda *xs: sum(xs), *outs, name="mid")
    g.add(lambda x: x % 97, mid, name="root")          # fusible mid->root
    return g.build()


ENGINES = [
    ("wukong", lambda o: WukongEngine(EngineConfig(optimize=o))),
    ("strawman", lambda o: StrawmanEngine(optimize=o)),
    ("pubsub", lambda o: PubSubEngine(optimize=o)),
    ("parallel_invoker", lambda o: ParallelInvokerEngine(optimize=o)),
    ("serverful",
     lambda o: ServerfulEngine(ServerfulConfig(optimize=o))),
]

PASS_COMBOS = [
    OptimizeConfig(fuse_chains=f, cluster_tasks=c, coalesce_fanouts=co)
    for f, c, co in itertools.product([False, True], repeat=3)
]


# -- equivalence: optimized == sequential, on every engine ------------------


@pytest.mark.parametrize("name,factory", ENGINES)
def test_all_engines_all_passes_tree(name, factory):
    want = seq_eval(tree_dag(32))
    assert factory(ALL_PASSES).compute(tree_dag(32)).results == want


@pytest.mark.parametrize("name,factory", ENGINES)
def test_all_engines_all_passes_mixed(name, factory):
    want = seq_eval(mixed_dag())
    assert factory(ALL_PASSES).compute(mixed_dag()).results == want


@pytest.mark.parametrize("combo", PASS_COMBOS,
                         ids=lambda c: f"fuse{int(c.fuse_chains)}-"
                                       f"clus{int(c.cluster_tasks)}-"
                                       f"coal{int(c.coalesce_fanouts)}")
def test_wukong_every_pass_combo_random_dags(combo):
    for seed in (3, 17, 42):
        dag = random_dag(seed, 45)
        want = seq_eval(dag)
        got = WukongEngine(
            EngineConfig(optimize=combo)).compute(random_dag(seed, 45))
        assert got.results == want


def test_chain_and_fanin_shapes_every_combo():
    for build in (chain_dag, chained_fanin_dag):
        want = seq_eval(build())
        for combo in PASS_COMBOS:
            rep = WukongEngine(EngineConfig(optimize=combo)).compute(build())
            assert rep.results == want, combo


def test_prebuilt_compiled_dag_equivalent_to_engine_config():
    g = GraphBuilder()
    cur = g.add(lambda: 5, name="s")
    for i in range(6):
        cur = g.add(lambda x: x * 2, cur, name=f"d{i}")
    via_build = WukongEngine().compute(g.build(optimize=True))
    via_config = WukongEngine(
        EngineConfig(optimize=ALL_PASSES)).compute(g.build())
    assert via_build.results == via_config.results == {"d5": 5 * 64}


# -- pass invariants: fusion ------------------------------------------------


def test_fusion_collapses_pure_chain_to_one_task():
    dag = chain_dag(20)
    compiled = compile_dag(dag, OptimizeConfig(
        cluster_tasks=False, coalesce_fanouts=False))
    assert isinstance(compiled, CompiledDAG)
    assert len(compiled) == 1
    assert compiled.roots == dag.roots
    assert compiled.fused["c19"][0] == "start"


def test_fusion_never_crosses_fanin_fanout_boundary():
    for build in (mixed_dag, lambda: random_dag(11, 60), chained_fanin_dag):
        dag = build()
        for chain in find_chains(dag):
            for u, v in zip(chain, chain[1:]):
                assert dag.fan_out_degree(u) == 1, (u, v)
                assert dag.fan_in_degree(v) == 1, (u, v)


def test_fusion_no_op_on_tree():
    # every tree edge targets a width-2 fan-in: nothing may fuse
    assert fusible_edges(tree_dag(16)) == set()


def test_fusion_respects_max_len():
    dag = chain_dag(20)  # 21 nodes
    _, provenance = fuse_linear_chains(dag, max_len=4)
    assert all(len(keys) <= 4 for keys in provenance.values())
    compiled = compile_dag(dag, OptimizeConfig(
        max_fusion_len=4, cluster_tasks=False, coalesce_fanouts=False))
    assert len(compiled) == 6  # ceil(21 / 4) segments
    rep = WukongEngine().compute(compiled)
    assert rep.results == seq_eval(dag)


def test_fused_task_preserves_kwargs_and_literals():
    g = GraphBuilder()
    a = g.add(lambda base, bump=0: base + bump, 10, bump=5, name="a")
    g.add(lambda x, scale=1: x * scale, a, scale=3, name="b")
    dag = g.build()
    rep = WukongEngine(EngineConfig(optimize=ALL_PASSES)).compute(dag)
    assert rep.results == {"b": 45}


# -- pass invariants: clustering (delayed I/O) ------------------------------


def test_clustering_reduces_kv_sets_on_chain_dag():
    """The delayed-I/O invariant on a chain of fan-in links: with fusion
    and coalescing off, clustering alone must strictly reduce KV ``set``
    operations (the completing arriver never writes its held value)."""
    clustered = OptimizeConfig(fuse_chains=False, coalesce_fanouts=False,
                               cluster_tasks=True)
    base = WukongEngine().compute(chained_fanin_dag(8))
    opt = WukongEngine(
        EngineConfig(optimize=clustered)).compute(chained_fanin_dag(8))
    assert opt.results == base.results == seq_eval(chained_fanin_dag(8))
    # one saved set per fan-in link
    assert opt.kv_stats["puts"] <= base.kv_stats["puts"] - 8


def test_cluster_annotations():
    dag = chained_fanin_dag(4)
    clusters, delayed = compute_clusters(dag)
    assert set(clusters) == set(dag.tasks)          # total assignment
    assert delayed == {f"x{i}" for i in range(1, 5)}  # every fan-in node
    # a fan-in node shares its cluster with its primary (first) parent
    for k in delayed:
        assert clusters[k] == clusters[dag.deps[k][0]]


def test_delayed_fanins_safe_under_retries():
    # seed=18: verified recoverable under the process-stable fault hash
    # (failures at attempt 0 only)
    dag = tree_dag(16)
    cfg = EngineConfig(optimize=ALL_PASSES, faults=FaultConfig(
        task_failure_prob=0.04, max_retries=2, seed=18))
    rep = WukongEngine(cfg).compute(dag)
    assert rep.results == seq_eval(tree_dag(16))


# -- pass invariants: coalescing --------------------------------------------


def test_coalescing_groups_only_true_siblings():
    dag = tree_dag(16)  # leaf pairs share a combine; pairs don't mix
    batches = coalesce_leaves(dag, batch=7)
    for b in batches:
        sigs = {tuple(sorted(dag.children[k])) for k in b}
        assert len(sigs) == 1
        assert len(b) <= 7
    assert sorted(k for b in batches for k in b) == sorted(dag.leaves)


def test_coalescing_reduces_invocations():
    coal = OptimizeConfig(fuse_chains=False, cluster_tasks=False,
                          coalesce_fanouts=True)
    base = WukongEngine().compute(tree_dag(64))
    opt = WukongEngine(EngineConfig(optimize=coal)).compute(tree_dag(64))
    assert opt.results == base.results
    assert opt.executors_invoked < base.executors_invoked


def test_coalescing_chunks_wide_fanout_below_proxy_threshold():
    g = GraphBuilder()
    src = g.add(lambda: 3, name="src")
    outs = [g.add(lambda x, i=i: x * i, src, name=f"m{i}")
            for i in range(32)]
    g.add(lambda *xs: sum(xs), *outs, name="total")
    dag = g.build()
    base = WukongEngine().compute(dag)
    opt = WukongEngine(EngineConfig(optimize=ALL_PASSES)).compute(dag)
    assert base.results == opt.results
    assert opt.results["total"] == 3 * sum(range(32))
    assert opt.executors_invoked < base.executors_invoked


# -- the acceptance criterion ----------------------------------------------


def test_tree_reduction_64_wide_all_passes_beats_unoptimized():
    """ISSUE acceptance: on a 64-wide tree reduction, all passes enabled
    must show strictly fewer KV ``set`` ops and lower simulated charged_ms
    than the unoptimized run, with results matching sequential evaluation
    on every engine."""
    from repro.apps.tree_reduction import tree_reduction_dag

    def dag64():
        return tree_reduction_dag(128)  # 64 leaf tasks

    want = seq_eval(dag64())
    (root_key,) = want.keys()

    base = WukongEngine().compute(dag64())
    opt = WukongEngine(EngineConfig(optimize=ALL_PASSES)).compute(dag64())
    assert opt.kv_stats["puts"] < base.kv_stats["puts"]
    assert opt.charged_ms < base.charged_ms

    for name, factory in ENGINES:
        got = factory(ALL_PASSES).compute(dag64()).results
        assert got[root_key][0] == want[root_key][0], name


def test_pass_stats_reported():
    rep = WukongEngine(
        EngineConfig(optimize=ALL_PASSES)).compute(mixed_dag())
    names = [s.name for s in rep.optimizer]
    assert names == ["fuse_chains", "cluster_tasks", "coalesce_fanouts"]
    fuse = rep.optimizer[0]
    assert fuse.after_tasks < fuse.before_tasks


def test_no_passes_is_identity_pipeline():
    dag = mixed_dag()
    compiled = compile_dag(dag, NO_PASSES)
    assert len(compiled) == len(dag)
    assert compiled.clusters == {}
    assert compiled.delayed_fanins == frozenset()
    assert [len(b) for b in compiled.leaf_batches] == [1] * len(dag.leaves)
    rep = WukongEngine().compute(compiled)
    assert rep.results == seq_eval(dag)
