"""Data-plane tests: striped large objects, batched round trips, and the
O(V+E) schedule generation (PR 2).

The KV-level tests drive the protocol directly (time_scale=0: we assert
*charged* simulated ms, not wall time); the engine-level tests assert the
end-to-end properties the ISSUE acceptance criteria name — bit-identical
results with striping on/off and a >=15% charged-ms reduction on the
fig08-style GEMM smoke workload.
"""
import threading

import numpy as np
import pytest

import repro.core.kvstore as kvstore_mod
from repro.core import (
    ALL_PASSES,
    CostModel,
    EngineConfig,
    WukongEngine,
)
from repro.core.kvstore import ShardedKVStore, _StripeManifest, _stripe_key
from repro.core.optimize import compile_dag
from repro.core.schedule import (
    generate_static_schedules,
    generate_static_schedules_dfs,
)


def make_kv(n_shards=10, threshold=1 << 10, max_stripes=8, **kw):
    return ShardedKVStore(
        n_shards=n_shards,
        cost=CostModel(stripe_threshold_bytes=threshold,
                       max_stripes=max_stripes, **kw),
    )


def stripe_entries(kv, key):
    found = []
    for idx, shard in enumerate(kv.shards):
        with shard.lock:
            for k in shard.data:
                if k.startswith(f"{key}/__stripe__/"):
                    found.append((idx, k))
    return found


class TestStriping:
    def test_round_trip_below_threshold_is_not_striped(self):
        kv = make_kv(threshold=1 << 10)
        small = b"x" * 100
        kv.put("small", small)
        assert kv.get("small") == small
        assert stripe_entries(kv, "small") == []
        assert kv.stats.striped_puts == 0

    def test_round_trip_above_threshold(self):
        kv = make_kv(threshold=1 << 10, max_stripes=4)
        big = np.arange(2048, dtype=np.float64)  # 16 KiB
        kv.put("big", big)
        out = kv.get("big")
        np.testing.assert_array_equal(out, big)
        assert out.dtype == big.dtype
        stripes = stripe_entries(kv, "big")
        assert len(stripes) == 4
        # stripes land on DISTINCT shards (that is the whole point)
        assert len({idx for idx, _ in stripes}) == 4
        assert kv.stats.striped_puts == 1
        assert kv.stats.striped_gets == 1
        assert kv.stats.bytes_read == big.nbytes

    def test_striped_transfer_charges_max_not_sum(self):
        nbytes = 1 << 20
        base = CostModel().kv_base_ms
        kv_plain = make_kv(threshold=0)  # striping disabled
        kv_plain.put("k", b"x" * nbytes)
        serial = kv_plain.clock.charged_ms - base
        kv_striped = make_kv(threshold=1 << 10, max_stripes=8)
        kv_striped.put("k", b"x" * nbytes)
        parallel = kv_striped.clock.charged_ms - base
        assert parallel == pytest.approx(serial / 8, rel=1e-6)

    def test_colocated_shards_degenerate_to_serial(self):
        nbytes = 1 << 20
        cost = CostModel(stripe_threshold_bytes=1 << 10, max_stripes=8)
        kv = ShardedKVStore(n_shards=10, cost=cost, colocate_shards=True)
        kv.put("k", b"x" * nbytes)
        serial = cost.transfer_ms(nbytes)
        assert kv.clock.charged_ms == pytest.approx(
            cost.kv_base_ms + serial, rel=1e-6)

    def test_exists_and_put_if_absent_resolve_through_manifest(self):
        kv = make_kv(threshold=1 << 10)
        big = b"y" * (1 << 14)
        assert kv.put_if_absent("k", big)
        assert kv.exists("k")
        assert not kv.put_if_absent("k", b"other")
        assert kv.get("k") == big

    def test_put_if_absent_idempotent_under_concurrent_retries(self):
        kv = make_kv(threshold=1 << 10, max_stripes=8)
        big = b"z" * (1 << 14)
        n_writers = 8
        barrier = threading.Barrier(n_writers)
        wins = []

        def writer():
            barrier.wait()
            wins.append(kv.put_if_absent("k", big))

        threads = [threading.Thread(target=writer) for _ in range(n_writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sum(wins) == 1  # exactly one writer installed the manifest
        assert kv.stats.puts == 1
        assert kv.stats.bytes_written == len(big)
        assert kv.get("k") == big
        # retried writers left a consistent stripe set, not duplicates
        assert len(stripe_entries(kv, "k")) == 8

    def test_overwrite_reclaims_stale_stripes(self):
        kv = make_kv(threshold=1 << 10, max_stripes=8)
        kv.put("k", b"a" * (1 << 14))          # 8 stripes
        kv.put("k", b"b" * 3000)               # re-striped: only 3 stripes
        assert len(stripe_entries(kv, "k")) == 3
        assert kv.get("k") == b"b" * 3000
        kv.put("k", b"small")                  # plain overwrite
        assert stripe_entries(kv, "k") == []
        assert kv.get("k") == b"small"
        kv.delete("k")
        assert all(not s.data for s in kv.shards)

    def test_delete_removes_all_stripes_and_manifest(self):
        kv = make_kv(threshold=1 << 10)
        kv.put("k", b"w" * (1 << 14))
        assert stripe_entries(kv, "k")
        kv.delete("k")
        assert not kv.exists("k")
        assert stripe_entries(kv, "k") == []
        assert all(not s.data for s in kv.shards)
        with pytest.raises(KeyError):
            kv.get("k")

    def test_deposit_and_increment_stripes_large_items(self):
        kv = make_kv(threshold=1 << 10)
        kv.register_counters({"c": 3})
        big = b"d" * (1 << 14)
        count, missing = kv.deposit_and_increment("c", "e1", {"dep": big})
        assert count == 1 and missing == []
        home = kv._shard("dep")
        with home.lock:
            assert isinstance(home.data["dep"], _StripeManifest)
        assert kv.get("dep") == big


class TestShardPlacement:
    def test_crc32_placement_is_process_stable(self):
        import zlib

        kv = make_kv(n_shards=7)
        for key in ("a", "tr-leaf-3", "gemm-P-1-2-3", "__fanin__/x"):
            assert kv._shard_index(key) == zlib.crc32(key.encode()) % 7

    def test_stripe_keys_are_derivable(self):
        assert _stripe_key("k", 3) == "k/__stripe__/3"


class TestBatchedRoundTrips:
    def test_mget_charges_one_base_per_shard_batch(self):
        kv = make_kv(n_shards=10, kv_bandwidth_mbps=1e12)  # transfer ~ 0
        keys = [f"key-{i}" for i in range(20)]
        for k in keys:
            kv.put(k, 1)
        n_batches = len({kv._shard_index(k) for k in keys})
        before = kv.clock.charged_ms
        vals = kv.mget(keys)
        charged = kv.clock.charged_ms - before
        assert vals == [1] * 20
        assert charged == pytest.approx(
            n_batches * kv.cost.kv_base_ms, abs=1e-6)
        assert kv.stats.mget_batches == n_batches
        # the per-key path would have paid one base per key
        assert charged < len(keys) * kv.cost.kv_base_ms

    def test_mget_single_shard_single_round_trip(self):
        kv = ShardedKVStore(n_shards=1, cost=CostModel(
            kv_bandwidth_mbps=1e12, stripe_threshold_bytes=0))
        for i in range(16):
            kv.put(f"k{i}", i)
        before = kv.clock.charged_ms
        kv.mget([f"k{i}" for i in range(16)])
        assert kv.clock.charged_ms - before == pytest.approx(
            kv.cost.kv_base_ms, abs=1e-9)

    def test_mget_preserves_order_dupes_and_striped_values(self):
        kv = make_kv(threshold=1 << 10)
        big = b"s" * (1 << 14)
        kv.put("big", big)
        kv.put("small", 7)
        out = kv.mget(["small", "big", "small"])
        assert out == [7, big, 7]
        assert kv.stats.striped_gets == 1
        with pytest.raises(KeyError):
            kv.mget(["small", "missing"])

    def test_batched_counter_registration_is_one_round_trip(self):
        kv = make_kv()
        kv.register_counters({})  # nothing to send -> nothing charged
        assert kv.clock.charged_ms == 0.0
        before = kv.clock.charged_ms
        kv.register_counters({f"c{i}": 2 for i in range(50)})
        assert kv.clock.charged_ms - before == pytest.approx(
            kv.cost.kv_base_ms, abs=1e-9)
        assert kv.counter_value("c0") == 0
        kv.increment_dependency("c0", "e")
        assert kv.counter_value("c0") == 1
        # the unbatched call pays one round trip per counter
        before = kv.clock.charged_ms
        kv.register_counter("extra", 2)
        assert kv.clock.charged_ms - before == pytest.approx(
            kv.cost.kv_base_ms, abs=1e-9)


class TestSizeCaching:
    def test_get_reuses_size_recorded_at_put(self, monkeypatch):
        calls = [0]
        real = kvstore_mod.sizeof

        def counting(value):
            calls[0] += 1
            return real(value)

        monkeypatch.setattr(kvstore_mod, "sizeof", counting)
        kv = make_kv()
        kv.put("k", [list(range(100)) for _ in range(10)])
        put_calls = calls[0]  # one top-level walk (sizeof recurses)
        assert put_calls > 0
        kv.get("k")
        kv.get("k")
        kv.mget(["k"])
        assert calls[0] == put_calls  # zero sizeof work on any read path
        assert kv.stats.bytes_read == 3 * kv.stats.bytes_written


def tree_dag(n):
    import operator

    from repro.core import GraphBuilder

    g = GraphBuilder()
    level = [g.add((lambda v: (lambda: v))(i), name=f"leaf-{i}")
             for i in range(n)]
    d = 0
    while len(level) > 1:
        level = [g.add(operator.add, level[i], level[i + 1],
                       name=f"add-{d}-{i // 2}")
                 for i in range(0, len(level), 2)]
        d += 1
    return g.build()


class TestScheduleGeneration:
    def test_sweep_matches_per_leaf_dfs_reference(self):
        from repro.apps import tree_reduction_dag

        for dag in (tree_dag(32), compile_dag(tree_dag(32)),
                    compile_dag(tree_reduction_dag(64))):
            a = generate_static_schedules(dag)
            b = generate_static_schedules_dfs(dag)
            assert set(a.schedules) == set(b.schedules)
            for leaf in b.schedules:
                assert a.schedules[leaf].nodes == b.schedules[leaf].nodes
                assert a.schedules[leaf].leaf == leaf
            assert ([(k, s.nodes) for k, s in a.batches]
                    == [(k, s.nodes) for k, s in b.batches])
            assert a.fan_in_counters() == b.fan_in_counters()

    def test_covering_index(self):
        dag = compile_dag(tree_dag(16))
        ss = generate_static_schedules(dag)
        for key in dag.tasks:
            sched = ss.covering_schedule(key)
            assert sched is not None and sched.covers(key)
        assert ss.covering_schedule("no-such-task") is None

    def test_sweep_beats_per_leaf_dfs_on_512_leaf_tree(self):
        """Acceptance: O(V+E) sweep >= 5x faster than the per-leaf DFS on
        a 512-leaf tree reduction. Asserts a conservative 3x floor so CI
        jitter cannot flake the suite; the measured ratio (~6-7x on an
        idle core, also recorded in BENCH_results.json by benchmarks/
        run.py) is printed for the log."""
        import gc
        import time

        from repro.apps import tree_reduction_dag

        dag = compile_dag(tree_reduction_dag(1024))  # 512 leaves

        # Interleaved so drifting background load lands on both equally.
        dfs_ts, sweep_ts = [], []
        gc.disable()
        try:
            for _ in range(15):
                t0 = time.perf_counter()
                generate_static_schedules_dfs(dag)
                dfs_ts.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                generate_static_schedules(dag)
                sweep_ts.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        dfs_s, sweep_s = min(dfs_ts), min(sweep_ts)
        ratio = dfs_s / sweep_s
        print(f"schedule-gen 512-leaf TR: dfs={dfs_s * 1e3:.2f}ms "
              f"sweep={sweep_s * 1e3:.2f}ms speedup={ratio:.1f}x")
        assert ratio >= 3.0


class TestEngineDataPlane:
    def _engines(self):
        # the fig08 data-plane regime: same cost model, only the two
        # data-plane factors differ (see benchmarks/common.py)
        on = WukongEngine(EngineConfig(
            cost=CostModel(kv_bandwidth_mbps=5.0,
                           stripe_threshold_bytes=8 << 10),
            optimize=ALL_PASSES, batch_kv_round_trips=True))
        off = WukongEngine(EngineConfig(
            cost=CostModel(kv_bandwidth_mbps=5.0,
                           stripe_threshold_bytes=0),
            optimize=ALL_PASSES, batch_kv_round_trips=False))
        return on, off

    def test_gemm_bit_identical_and_cheaper_with_data_plane(self):
        """Acceptance: striping + batched mget cut Wukong charged_ms by
        >=15% on the fig08 GEMM smoke workload, with bit-identical
        results."""
        from repro.apps import gemm_dag

        on, off = self._engines()
        rep_on = on.compute(gemm_dag(256, 128))
        rep_off = off.compute(gemm_dag(256, 128))
        assert set(rep_on.results) == set(rep_off.results)
        for k in rep_on.results:
            a = np.asarray(rep_on.results[k])
            b = np.asarray(rep_off.results[k])
            assert a.dtype == b.dtype
            assert a.tobytes() == b.tobytes()  # bit-identical
        assert rep_on.charged_ms <= 0.85 * rep_off.charged_ms
        assert rep_on.kv_stats["striped_puts"] > 0
        assert rep_on.kv_stats["mget_batches"] > 0
        assert rep_off.kv_stats["striped_puts"] == 0
        assert rep_off.kv_stats["mget_batches"] == 0

    def test_batching_knob_off_still_correct(self):
        dag = tree_dag(32)
        rep = WukongEngine(EngineConfig(
            batch_kv_round_trips=False)).compute(dag)
        assert rep.results["add-4-0"] == sum(range(32))

    def test_striping_safe_under_retries(self):
        """Striped writes stay idempotent through Lambda-style retries.
        seed=6: verified recoverable under the process-stable fault hash
        (failures at attempt 0 only)."""
        from repro.core import FaultConfig

        g_dag = tree_dag(8)
        cfg = EngineConfig(
            cost=CostModel(stripe_threshold_bytes=4),  # stripe everything
            faults=FaultConfig(task_failure_prob=0.1, max_retries=2,
                               seed=6))
        rep = WukongEngine(cfg).compute(g_dag)
        assert rep.results["add-2-0"] == sum(range(8))
