"""Paper workloads: numerical correctness on the WUKONG engine."""
import numpy as np
import pytest

from repro.apps.gemm import gemm_dag, gemm_expected
from repro.apps.svc import svc_dag, svc_expected
from repro.apps.svd import (
    randomized_svd_dag,
    randomized_svd_expected,
    tsqr_singular_values_expected,
    tsqr_svd_dag,
)
from repro.apps.tree_reduction import (
    tree_reduction_dag,
    tree_reduction_expected,
)
from repro.core import ServerfulEngine, WukongEngine


@pytest.fixture(scope="module")
def engine():
    return WukongEngine()


def test_tree_reduction(engine):
    rep = engine.compute(tree_reduction_dag(128))
    (_, v), = rep.results.items()
    assert v[0] == tree_reduction_expected(128)


def test_tree_reduction_payload_ballast(engine):
    rep = engine.compute(tree_reduction_dag(32, payload_bytes=4096))
    (_, v), = rep.results.items()
    assert v[0] == tree_reduction_expected(32)
    assert v.shape == (1 + 4096 // 8,)


def test_gemm(engine):
    rep = engine.compute(gemm_dag(256, 64))
    C = np.block([[np.asarray(rep.results[f"gemm-C-{i}-{j}"])
                   for j in range(4)] for i in range(4)])
    np.testing.assert_allclose(C, gemm_expected(256, 64),
                               rtol=2e-4, atol=2e-4)


def test_gemm_engines_agree(engine):
    dag = gemm_dag(128, 64)
    a = engine.compute(dag).results
    b = ServerfulEngine().compute(gemm_dag(128, 64)).results
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5)


def test_tsqr_svd(engine):
    rep = engine.compute(tsqr_svd_dag(1024, 32, 8))
    np.testing.assert_allclose(
        np.asarray(rep.results["svd1-S"]),
        tsqr_singular_values_expected(1024, 32, 8), rtol=1e-3)
    # U blocks present (the wide fan-out stage)
    assert sum(k.startswith("svd1-U-") for k in rep.results) == 8


def test_randomized_svd(engine):
    rep = engine.compute(randomized_svd_dag(512, 5, 5, 8))
    want = randomized_svd_expected(512, 5, 5, 8)
    np.testing.assert_allclose(np.asarray(rep.results["svd2-S"]), want,
                               rtol=1e-2)


def test_randomized_svd_ideal_storage_same_result_less_traffic(engine):
    want = randomized_svd_expected(512, 5, 5, 8)
    rep_n = engine.compute(randomized_svd_dag(512, 5, 5, 8))
    rep_i = engine.compute(
        randomized_svd_dag(512, 5, 5, 8, ideal_storage=True))
    np.testing.assert_allclose(np.asarray(rep_i.results["svd2-S"]), want,
                               rtol=1e-2)
    assert rep_i.kv_stats["bytes_written"] < \
        rep_n.kv_stats["bytes_written"] / 2


def test_svc(engine):
    rep = engine.compute(svc_dag(4096, 8, 3))
    np.testing.assert_allclose(np.asarray(rep.results["svc-w3"]),
                               svc_expected(4096, 8, 3),
                               rtol=1e-4, atol=1e-5)
