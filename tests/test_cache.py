"""Multi-tier executor cache (repro.core.cache).

The tentpole properties:

- *tier parity*: the same DAG run cacheless, with a zero-capacity
  cache, memory-only, and memory+disk produces identical task results —
  the tiers change charged ms and cache_stats, never values. The
  zero-capacity cache is charge-identical to ``cache=None`` bit for bit.
- *eviction correctness*: an evicted-then-needed object is transparently
  re-fetched from the next tier (disk, then KV) with the right charges,
  including under injected task retries.
- *warm retention*: a warm container keeps its cache across reuses
  (tier-0 hits > 0 on shared-input DAGs); cold start and keep-alive
  expiry clear it.
- *substrate parity*: cached runs stay bit-identical between the event
  and thread substrates, like every other charge in the system.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_fallback import given, settings, st

from repro.apps import gemm_dag, tree_reduction_dag
from repro.apps.tree_reduction import tree_reduction_expected
from repro.core import (
    ALL_PASSES,
    CacheConfig,
    CacheRegistry,
    CacheStats,
    CostModel,
    EngineConfig,
    ExecutorCache,
    FaultConfig,
    GraphBuilder,
    WukongEngine,
)
from repro.core.dag import TaskRef
from repro.platform import PlatformConfig


def drive(gen):
    """Run a cache effect generator to completion, collecting charges.
    Returns ``(return_value, [charged_ms, ...])``."""
    charges = []
    try:
        while True:
            eff = next(gen)
            assert eff[0] == "charge"
            charges.append(eff[1])
    except StopIteration as stop:
        return stop.value, charges


def seq_eval(dag):
    vals = {}
    for k in dag.topological_order():
        t = dag.tasks[k]
        args = [vals[a.key] if isinstance(a, TaskRef) else a
                for a in t.args]
        kwargs = {kk: vals[v.key] if isinstance(v, TaskRef) else v
                  for kk, v in t.kwargs.items()}
        vals[k] = t.fn(*args, **kwargs)
    return {k: vals[k] for k in dag.roots}


def random_dag(seed: int, n: int):
    import random

    rng = random.Random(seed)
    g = GraphBuilder()
    refs = []
    for i in range(n):
        k = rng.randint(0, min(4, len(refs)))
        deps = rng.sample(refs, k) if k else []
        if deps:
            refs.append(g.add(lambda *xs: sum(xs) + 1, *deps, name=f"n{i}"))
        else:
            refs.append(g.add((lambda v: (lambda: v))(i), name=f"n{i}"))
    return g.build()


# ---------------------------------------------------------------------------
# ExecutorCache unit behavior (drive the generators by hand — no clock)
# ---------------------------------------------------------------------------


class TestExecutorCacheUnit:
    def test_mem_hit_is_free_and_counted(self):
        c = ExecutorCache(CacheConfig(memory_bytes=100, disk_bytes=1000))
        _, ch = drive(c.deposit_g("k", "v", 10))
        assert ch == []  # fits tier 0: nothing charged
        (hit, val), ch = drive(c.probe_g("k"))
        assert hit and val == "v" and ch == []  # tier-0 hit: free
        assert c.stats.mem_hits == 1 and c.stats.bytes_local == 10

    def test_probe_miss_charges_nothing(self):
        c = ExecutorCache(CacheConfig(memory_bytes=100, disk_bytes=1000))
        (hit, val), ch = drive(c.probe_g("absent"))
        assert not hit and val is None and ch == []
        assert c.stats.misses == 1

    def test_lru_spill_and_disk_promotion_charges(self):
        cfg = CacheConfig(memory_bytes=25, disk_bytes=1000)
        c = ExecutorCache(cfg)
        drive(c.deposit_g("a", "A", 10))
        drive(c.deposit_g("b", "B", 10))
        # touch "a" so "b" becomes the LRU victim
        drive(c.probe_g("a"))
        _, ch = drive(c.deposit_g("c", "C", 10))
        assert ch == [cfg.disk_write_ms(10)]  # spill of "b" charged
        assert c.stats.spills == 1 and c.stats.mem_evictions == 1
        # disk hit: charged read, promoted back to memory (evicting the
        # new LRU "a", whose spill is charged in the same step)
        (hit, val), ch = drive(c.probe_g("b"))
        assert hit and val == "B"
        assert ch == [cfg.disk_read_ms(10) + cfg.disk_write_ms(10)]
        assert c.stats.disk_hits == 1 and c.stats.bytes_disk == 10
        (hit, _), _ = drive(c.probe_g("a"))  # now served from disk
        assert hit and c.stats.disk_hits == 2

    def test_deposit_existing_is_lru_touch_not_duplicate(self):
        c = ExecutorCache(CacheConfig(memory_bytes=25, disk_bytes=1000))
        drive(c.deposit_g("a", "A", 10))
        drive(c.deposit_g("b", "B", 10))
        drive(c.deposit_g("a", "A", 10))  # refresh: "b" is now LRU
        drive(c.deposit_g("c", "C", 10))
        assert c.mem_bytes == 20 and c.stats.spills == 1
        (hit, _), ch = drive(c.probe_g("a"))
        assert hit and ch == []  # "a" stayed in memory

    def test_disk_eviction_drops_oldest(self):
        c = ExecutorCache(CacheConfig(memory_bytes=10, disk_bytes=20))
        for k in ("a", "b", "c"):
            drive(c.deposit_g(k, k.upper(), 10))
        # "a" then "b" spilled; depositing "c" keeps mem, so disk holds
        # a+b at capacity. One more spill evicts "a" from disk.
        drive(c.deposit_g("d", "D", 10))
        assert c.stats.disk_evictions == 1
        (hit, _), _ = drive(c.probe_g("a"))
        assert not hit  # dropped from the whole hierarchy

    def test_too_large_for_disk_is_not_cached(self):
        c = ExecutorCache(CacheConfig(memory_bytes=10, disk_bytes=20))
        _, ch = drive(c.deposit_g("big", "X", 50))
        assert ch == []  # exceeds both tiers: charge nothing
        assert len(c) == 0
        (hit, _), _ = drive(c.probe_g("big"))
        assert not hit

    def test_zero_capacity_all_ops_chargeless(self):
        c = ExecutorCache(CacheConfig(memory_bytes=0, disk_bytes=0))
        _, ch = drive(c.deposit_g("k", "v", 1))
        assert ch == []
        (hit, _), ch = drive(c.probe_g("k"))
        assert not hit and ch == []
        assert len(c) == 0

    def test_invalidate_prefix_reclaims_both_tiers(self):
        c = ExecutorCache(CacheConfig(memory_bytes=10, disk_bytes=1000))
        drive(c.deposit_g("j1::a", "A", 10))
        drive(c.deposit_g("j1::b", "B", 10))  # spills j1::a to disk
        drive(c.deposit_g("j2::c", "C", 10))  # spills j1::b to disk
        assert c.invalidate_prefix("j1::") == 2
        assert not c.contains("j1::a") and not c.contains("j1::b")
        assert c.contains("j2::c")
        assert c.mem_bytes == 10 and c.disk_bytes == 0

    def test_resident_bytes_scores_both_tiers(self):
        c = ExecutorCache(CacheConfig(memory_bytes=10, disk_bytes=1000))
        drive(c.deposit_g("a", "A", 10))
        drive(c.deposit_g("b", "B", 10))  # "a" spills
        assert c.resident_bytes(["a", "b", "absent"]) == 20

    def test_config_validation(self):
        with pytest.raises(ValueError):
            CacheConfig(memory_bytes=-1)
        with pytest.raises(ValueError):
            CacheConfig(disk_read_mbps=0)
        with pytest.raises(ValueError):
            CacheConfig(disk_base_ms=-0.1)


class TestCacheRegistry:
    def test_cache_follows_container_and_drop_retires_stats(self):
        r = CacheRegistry(CacheConfig(memory_bytes=100, disk_bytes=100))
        c = r.cache_for("fn", 1)
        assert r.cache_for("fn", 1) is c  # warm reuse: same cache
        assert r.cache_for("fn", 2) is not c
        drive(c.deposit_g("k", "v", 10))
        drive(c.probe_g("k"))
        r.drop("fn", 1)
        assert r.get("fn", 1) is None
        snap = r.snapshot()  # retired stats survive the container
        assert snap["mem_hits"] == 1 and snap["deposits"] == 1
        assert snap["containers"] == 1  # only ("fn", 2) lives

    def test_invalidate_prefix_reaches_every_container(self):
        r = CacheRegistry(CacheConfig(memory_bytes=100, disk_bytes=100))
        drive(r.cache_for("fn", 1).deposit_g("j::a", "A", 10))
        drive(r.cache_for("fn", 2).deposit_g("j::b", "B", 10))
        assert r.invalidate_prefix("j::") == 2
        assert r.snapshot()["resident_mem_bytes"] == 0

    def test_per_job_sink_counts_alongside_container_stats(self):
        c = ExecutorCache(CacheConfig(memory_bytes=100, disk_bytes=100))
        sink = CacheStats()
        drive(c.deposit_g("k", "v", 10, stats=sink))
        drive(c.probe_g("k", stats=sink))
        drive(c.probe_g("nope", stats=sink))
        assert sink.snapshot() == c.stats.snapshot()
        assert sink.mem_hits == 1 and sink.misses == 1


# ---------------------------------------------------------------------------
# Engine-level: tier parity, eviction, retention
# ---------------------------------------------------------------------------


def _cfg(cache, substrate="event", **kw):
    kw.setdefault("num_initial_invokers", 4)
    kw.setdefault("num_proxy_invokers", 4)
    return EngineConfig(
        cost=CostModel(cold_start_ms=250.0, substrate=substrate),
        platform=PlatformConfig(keep_alive_s=600.0, cache=cache),
        **kw)


TIERS = [
    ("cacheless", None),
    ("zero", CacheConfig(memory_bytes=0, disk_bytes=0)),
    ("mem_only", CacheConfig(memory_bytes=64 << 20, disk_bytes=0)),
    ("mem_disk", CacheConfig(memory_bytes=64 << 20, disk_bytes=512 << 20)),
    ("tiny_mem", CacheConfig(memory_bytes=1 << 10, disk_bytes=512 << 20)),
]


class TestTierParity:
    def test_zero_capacity_cache_is_charge_identical_to_cacheless(self):
        dag = tree_reduction_dag(64, payload_bytes=1 << 16, compute_ms=5.0)
        r0 = WukongEngine(_cfg(None)).compute(dag)
        r1 = WukongEngine(
            _cfg(CacheConfig(memory_bytes=0, disk_bytes=0))).compute(dag)
        assert r0.charged_ms == r1.charged_ms
        assert r0.wall_s == r1.wall_s
        assert r0.kv_stats == r1.kv_stats
        assert r0.cache_stats == {}  # cacheless: no block at all
        assert r1.cache_stats["mem_hits"] == 0
        assert r1.cache_stats["disk_hits"] == 0

    @pytest.mark.parametrize("label,cache", TIERS)
    def test_tree_reduction_identical_results_across_tiers(self, label,
                                                           cache):
        dag = tree_reduction_dag(32, payload_bytes=1 << 14, compute_ms=2.0)
        rep = WukongEngine(_cfg(cache)).compute(dag)
        (_, root), = rep.results.items()
        assert float(root[0]) == tree_reduction_expected(32)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(2, 40))
    def test_random_dags_tier_parity(self, seed, n):
        """Property: tiers change charges and cache_stats, never values."""
        dag = random_dag(seed, n)
        expected = seq_eval(dag)
        for _, cache in TIERS:
            assert WukongEngine(_cfg(cache)).compute(dag).results == expected


class TestEvictionCorrectness:
    def test_spills_happen_and_results_stay_correct(self):
        # 16 KiB payloads against a 1 KiB tier 0: every deposit
        # overflows to disk; fan-in completers re-fetch through tier 1.
        dag = tree_reduction_dag(64, payload_bytes=1 << 14, compute_ms=2.0)
        rep = WukongEngine(
            _cfg(CacheConfig(memory_bytes=1 << 10,
                             disk_bytes=512 << 20))).compute(dag)
        (_, root), = rep.results.items()
        assert float(root[0]) == tree_reduction_expected(64)
        cs = rep.cache_stats
        assert cs["spills"] > 0 and cs["mem_evictions"] > 0

    def test_evicted_from_disk_too_falls_through_to_kv(self):
        # Tier 1 smaller than one payload: nothing is cacheable at all;
        # every read falls through to the KV store and still resolves.
        dag = tree_reduction_dag(32, payload_bytes=1 << 14, compute_ms=2.0)
        rep = WukongEngine(
            _cfg(CacheConfig(memory_bytes=1 << 10,
                             disk_bytes=1 << 10))).compute(dag)
        (_, root), = rep.results.items()
        assert float(root[0]) == tree_reduction_expected(32)
        assert rep.cache_stats["mem_hits"] == 0
        assert rep.cache_stats["disk_hits"] == 0

    @pytest.mark.parametrize("substrate", ["event", "thread"])
    def test_retries_with_tiny_cache_stay_correct_and_identical(
            self, substrate):
        # Injected failures + Lambda retries against a spilling cache:
        # the retry re-walks from its start key; host-side mutation is
        # atomic under the cache lock, so it never observes a
        # half-spilled entry — results and charges stay deterministic.
        cfg = EngineConfig(
            cost=CostModel(cold_start_ms=250.0, substrate=substrate),
            platform=PlatformConfig(
                keep_alive_s=600.0,
                cache=CacheConfig(memory_bytes=1 << 12,
                                  disk_bytes=512 << 20)),
            faults=FaultConfig(task_failure_prob=0.08, max_retries=2,
                               seed=11, retry_backoff_base_ms=100.0),
            num_initial_invokers=4, num_proxy_invokers=4)
        rep = WukongEngine(cfg).compute(
            tree_reduction_dag(64, payload_bytes=1 << 14, compute_ms=2.0))
        (_, root), = rep.results.items()
        assert float(root[0]) == tree_reduction_expected(64)
        assert rep.fault_stats["task_retries"] > 0
        rep2 = WukongEngine(cfg).compute(
            tree_reduction_dag(64, payload_bytes=1 << 14, compute_ms=2.0))
        assert rep.charged_ms == rep2.charged_ms
        assert rep.cache_stats == rep2.cache_stats


class TestWarmRetention:
    """A warm container RETAINS its cache; cold start / expiry clear it."""

    def test_shared_input_dag_hits_tier0_across_reuses(self):
        # GEMM: every A/B block feeds b multiply tasks. Read-through
        # caching + hint-steered placement turn warm reuse into tier-0
        # hits on the shared blocks.
        dag = gemm_dag(512, 128)
        rep = WukongEngine(_cfg(CacheConfig(),
                                optimize=ALL_PASSES)).compute(dag)
        cs = rep.cache_stats
        assert cs["mem_hits"] > 0 and cs["bytes_local"] > 0
        assert rep.platform_stats["cache"]["mem_hits"] >= cs["mem_hits"]

    def test_zero_keep_alive_clears_cache_every_invocation(self):
        # keep_alive 0: every container is reclaimed on release, its
        # cache with it. Hits within one invocation survive (a re-read
        # of an input the same walk already fetched IS local), but the
        # cross-invocation hits that warm retention adds disappear —
        # and no cache outlives the run.
        dag = gemm_dag(512, 128)

        def run(keep_alive_s):
            cfg = EngineConfig(
                cost=CostModel(cold_start_ms=250.0),
                platform=PlatformConfig(keep_alive_s=keep_alive_s,
                                        cache=CacheConfig()),
                optimize=ALL_PASSES,
                num_initial_invokers=4, num_proxy_invokers=4)
            return WukongEngine(cfg).compute(dag)

        cold, warm = run(0.0), run(600.0)
        assert cold.cache_stats["mem_hits"] < warm.cache_stats["mem_hits"]
        assert cold.platform_stats["cache"]["containers"] == 0
        assert warm.platform_stats["cache"]["containers"] > 0

    def test_cache_block_absent_without_cache_config(self):
        dag = tree_reduction_dag(16, compute_ms=2.0)
        rep = WukongEngine(_cfg(None)).compute(dag)
        assert "cache" not in rep.platform_stats
        assert rep.cache_stats == {}


class TestSubstrateParity:
    def test_cached_run_bit_identical_event_vs_thread(self):
        def run(substrate):
            dag = tree_reduction_dag(64, payload_bytes=1 << 16,
                                     compute_ms=5.0)
            return WukongEngine(_cfg(
                CacheConfig(memory_bytes=1 << 14, disk_bytes=512 << 20),
                substrate=substrate)).compute(dag)

        a, b = run("event"), run("thread")
        assert a.charged_ms == b.charged_ms
        assert a.wall_s == b.wall_s
        assert a.kv_stats == b.kv_stats
        assert a.cache_stats == b.cache_stats
        assert a.platform_stats["cache"] == b.platform_stats["cache"]
