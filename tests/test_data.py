"""Data pipeline: determinism, host sharding, packing invariants."""
import numpy as np

from repro.data import DataConfig, TokenPipeline, pack_documents

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_fallback import given, settings, st


def cfg(**kw):
    base = dict(vocab=1000, seq_len=128, batch_per_host=2, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = TokenPipeline(cfg()).batch()
    b = TokenPipeline(cfg()).batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_resume_replays_stream():
    p = TokenPipeline(cfg())
    p.batch()
    state = p.state()
    want = p.batch()
    q = TokenPipeline(cfg())
    q.restore(state)
    got = q.batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_step_keyed_batches_are_idempotent():
    """WUKONG retries re-run data tasks; same step => same batch."""
    p = TokenPipeline(cfg())
    a = p.batch(step=5)
    p.batch(step=9)
    b = p.batch(step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_hosts_disjoint():
    h0 = TokenPipeline(cfg(n_hosts=2, host_id=0)).batch()
    h1 = TokenPipeline(cfg(n_hosts=2, host_id=1)).batch()
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_shapes_and_ranges():
    b = TokenPipeline(cfg()).batch()
    assert b["tokens"].shape == (2, 128)
    assert b["labels"].shape == (2, 128)
    assert b["loss_mask"].shape == (2, 128)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000


@settings(max_examples=20, deadline=None)
@given(
    seq_len=st.integers(16, 256),
    doc_lens=st.lists(st.integers(1, 300), min_size=1, max_size=10),
)
def test_packing_properties(seq_len, doc_lens):
    """Property: pack fills exactly seq_len tokens; no token from any
    document is lost or duplicated (leftovers carry the rest)."""
    docs = [np.arange(1, n + 1, dtype=np.int32) + 1000 * i
            for i, n in enumerate(doc_lens)]
    row, mask, rest = pack_documents([d.copy() for d in docs], seq_len,
                                     eos_id=0)
    assert row.shape == (seq_len,)
    assert mask.shape == (seq_len,)
    packed_tokens = row[row != 0]
    rest_tokens = np.concatenate(rest) if rest else np.array([], np.int32)
    all_tokens = np.concatenate(docs)
    recovered = np.concatenate([packed_tokens, rest_tokens])
    # packed + leftover is a prefix-preserving split of the input stream
    np.testing.assert_array_equal(np.sort(recovered),
                                  np.sort(all_tokens[:len(recovered)]))
