"""Trigger bus: event-fired jobs (repro.core.triggers).

Covers the construction-time validation surface of ``TriggerRule`` /
``StreamConfig``, the durable rule + fire journals (journal-then-act,
replay dedupe across crash generations), windowed kv_write matching,
the pub/sub teardown guarantee behind the relay (``drop_namespace``
wakes blocked subscribers with ``PURGED``), and the orchestrator
integration: all four trigger sources firing real jobs, bit-identical
repeat runs, and exactly-once fires across a mid-stream dispatcher
crash."""
import pytest

from repro.core import (
    PURGED,
    EngineConfig,
    FaultConfig,
    JobOrchestrator,
    OrchestratorConfig,
    ShardedKVStore,
    StreamConfig,
    TenantSpec,
    TriggerBus,
    TriggerRule,
    WorkloadConfig,
    stream_arrivals,
)
from repro.core.kvstore import NAMESPACE_SEP, CostModel
from repro.core.simclock import EventClock

_ACTION = {"app": "tree_reduction", "size": 8, "tenant": "tenant-a"}


# ---------------------------------------------------------------------------
# Construction-time validation (the FaultConfig.__post_init__ discipline)
# ---------------------------------------------------------------------------


class TestTriggerRuleValidation:
    @pytest.mark.parametrize("kwargs,msg", [
        (dict(rule_id="", source="external", action=_ACTION, event="e"),
         "rule_id"),
        (dict(rule_id="a#b", source="external", action=_ACTION,
              event="e"), "rule_id"),
        (dict(rule_id="r", source="webhook", action=_ACTION), "source"),
        (dict(rule_id="r", source="external", action="not-a-mapping",
              event="e"), "action"),
        (dict(rule_id="r", source="external", action={"app": "x"},
              event="e"), "action"),
        (dict(rule_id="r", source="timer", action=_ACTION,
              period_ms=-1.0, max_fires=1), "period_ms"),
        (dict(rule_id="r", source="kv_write", action=_ACTION,
              key_prefix="p", window_ms=-2.0), "window_ms"),
        (dict(rule_id="r", source="kv_write", action=_ACTION,
              key_prefix="p", slide_ms=-2.0), "slide_ms"),
        (dict(rule_id="r", source="external", action=_ACTION, event="e",
              max_fires=-1), "max_fires"),
        (dict(rule_id="r", source="kv_write", action=_ACTION,
              key_prefix="p", min_window_events=0), "min_window_events"),
        (dict(rule_id="r", source="job_completed", action=_ACTION,
              every_n=0), "every_n"),
        (dict(rule_id="r", source="timer", action=_ACTION,
              max_fires=1), "period_ms"),
        (dict(rule_id="r", source="timer", action=_ACTION,
              period_ms=10.0), "max_fires"),
        (dict(rule_id="r", source="kv_write", action=_ACTION),
         "key_prefix"),
        (dict(rule_id="r", source="kv_write", action=_ACTION,
              key_prefix="p", window_ms=10.0, slide_ms=20.0), "slide"),
        (dict(rule_id="r", source="external", action=_ACTION), "event"),
    ])
    def test_rejects(self, kwargs, msg):
        with pytest.raises(ValueError, match=msg):
            TriggerRule(**kwargs)

    def test_valid_rule_copies_action(self):
        action = dict(_ACTION)
        rule = TriggerRule("r", "external", action, event="go")
        action["app"] = "mutated"
        assert rule.action["app"] == "tree_reduction"


class TestStreamConfigValidation:
    @pytest.mark.parametrize("kwargs,msg", [
        (dict(n_events=0), "n_events"),
        (dict(rate_per_s=0.0), "rate_per_s"),
        (dict(rate_per_s=-5.0), "rate_per_s"),
        (dict(payload_bytes=-1), "payload_bytes"),
        (dict(namespace=""), "namespace"),
        (dict(namespace=f"a{NAMESPACE_SEP}b"), "namespace"),
        (dict(key_prefix=""), "key_prefix"),
    ])
    def test_rejects(self, kwargs, msg):
        with pytest.raises(ValueError, match=msg):
            StreamConfig(**kwargs)

    def test_arrivals_deterministic_and_monotonic(self):
        cfg = StreamConfig(n_events=64, rate_per_s=100.0, seed=5)
        a, b = stream_arrivals(cfg), stream_arrivals(cfg)
        assert a == b
        assert all(x < y for x, y in zip(a, a[1:]))
        assert stream_arrivals(
            StreamConfig(n_events=64, rate_per_s=100.0, seed=6)) != a

    def test_store_prefix(self):
        cfg = StreamConfig(namespace="s", key_prefix="ev/")
        assert cfg.store_prefix == f"s{NAMESPACE_SEP}ev/"


# ---------------------------------------------------------------------------
# Bus unit tests: durable journals, dedupe, windowed matching
# ---------------------------------------------------------------------------


def _bus(id_base: int = 500):
    clock = EventClock()
    kv = ShardedKVStore(n_shards=4, clock=clock)
    return clock, kv, TriggerBus(kv, clock, id_base=id_base)


def _ext_event(name: str, ekey: str, at_ms: float = 0.0) -> dict:
    return {"source": "external", "name": name, "ekey": ekey,
            "payload": None, "at_ms": at_ms}


class TestBusJournals:
    def test_fire_journal_dedupes_and_replays(self):
        clock, kv, bus = _bus()
        rule = TriggerRule("r", "external", _ACTION, event="go")

        def main():
            yield from bus.add_rule_g(rule)
            with pytest.raises(ValueError, match="duplicate"):
                next(bus.add_rule_g(rule))
            (due,) = bus.match(_ext_event("go", "k1"))
            spec = yield from bus.fire_g(due, 1.0)
            assert spec["job_id"] == 500
            assert spec["app"] == "tree_reduction"
            assert spec["arrival_ms"] == 1.0
            # same fire key again: suppressed, not re-journaled
            (due2,) = bus.match(_ext_event("go", "k1", at_ms=9.0))
            assert (yield from bus.fire_g(due2, 9.0)) is None
            # a different dedup key is a genuine new fire
            (due3,) = bus.match(_ext_event("go", "k2", at_ms=9.0))
            spec3 = yield from bus.fire_g(due3, 9.0)
            assert spec3["job_id"] == 501

        clock.run(main())
        assert [r["fire_key"] for r in bus.fired_records()] \
            == ["r#k1", "r#k2"]

        # A fresh bus over the same store (the recovery path) folds the
        # journals back: same rules, same fires, same dedupe, and job
        # ids continue after the highest journaled one.
        clock2 = EventClock()
        bus2 = TriggerBus(kv, clock2, id_base=500)

        def recover():
            n = yield from bus2.replay_g()
            assert n == 3  # 1 rule + 2 fires
            (due,) = bus2.match(_ext_event("go", "k1"))
            assert (yield from bus2.fire_g(due, 0.0)) is None
            (due,) = bus2.match(_ext_event("go", "k3"))
            spec = yield from bus2.fire_g(due, 0.0)
            assert spec["job_id"] == 502

        clock2.run(recover())
        assert set(bus2.rules) == {"r"}
        assert len(bus2.fired_records()) == 3


class TestWindowedMatching:
    def _rule(self, **kw):
        kw.setdefault("window_ms", 100.0)
        return TriggerRule("w", "kv_write", _ACTION, key_prefix="s::ev/",
                           **kw)

    def _ev(self, key: str, at_ms: float) -> dict:
        return {"source": "kv_write", "key": key, "nbytes": 1,
                "at_ms": at_ms}

    def test_tumbling_close_on_watermark(self):
        _, _, bus = _bus()
        bus.rules["w"] = self._rule()
        # two events in window 0; nothing due until the watermark
        # (an event in a later window) passes the window end
        assert bus.match(self._ev("s::ev/000000@10.000", 10.0)) == []
        assert bus.match(self._ev("s::ev/000001@60.000", 60.0)) == []
        (due,) = bus.match(self._ev("s::ev/000002@150.000", 150.0))
        assert due["fire_key"] == "w#w0"
        assert due["event_times"] == [10.0, 60.0]

    def test_duplicate_write_delivery_ignored(self):
        _, _, bus = _bus()
        bus.rules["w"] = self._rule()
        key = "s::ev/000000@10.000"
        assert bus.match(self._ev(key, 10.0)) == []
        # crash-replay overlap: same durable key re-delivered
        assert bus.match(self._ev(key, 11.0)) == []
        (due,) = bus.match(self._ev("s::ev/000001@130.000", 130.0))
        assert due["event_times"] == [10.0]

    def test_min_window_events_suppresses_small_windows(self):
        _, _, bus = _bus()
        bus.rules["w"] = self._rule(min_window_events=2)
        assert bus.match(self._ev("s::ev/000000@10.000", 10.0)) == []
        # window 0 has 1 event < 2: closed silently, never fires
        assert bus.match(self._ev("s::ev/000001@150.000", 150.0)) == []
        assert bus.match(self._ev("s::ev/000002@160.000", 160.0)) == []
        (due,) = bus.match(self._ev("s::ev/000003@260.000", 260.0))
        assert due["fire_key"] == "w#w1"

    def test_flush_closes_open_windows(self):
        _, _, bus = _bus()
        bus.rules["w"] = self._rule()
        assert bus.match(self._ev("s::ev/000000@10.000", 10.0)) == []
        (due,) = bus.flush()
        assert due["fire_key"] == "w#w0"

    def test_sliding_windows_overlap(self):
        _, _, bus = _bus()
        bus.rules["w"] = self._rule(window_ms=100.0, slide_ms=50.0)
        # one event at 60 ms belongs to windows [0,100) and [50,150)
        assert bus.match(self._ev("s::ev/000000@60.000", 60.0)) == []
        dues = bus.match(self._ev("s::ev/000001@400.000", 400.0))
        assert [d["fire_key"] for d in dues] == ["w#w0", "w#w1"]
        assert all(d["event_times"] == [60.0] for d in dues)


# ---------------------------------------------------------------------------
# Pub/sub teardown behind the relay (drop_namespace wakes subscribers)
# ---------------------------------------------------------------------------


class TestPubSubTeardown:
    def test_drop_namespace_wakes_blocked_subscriber(self):
        clock = EventClock()
        kv = ShardedKVStore(n_shards=2, clock=clock)
        ns = kv.namespace("__triggers__")
        sub = ns.subscribe("events")
        woke = []

        def blocked():
            msg = yield ("get", sub, None)
            woke.append(msg)

        def main():
            yield ("charge", 1.0)
            kv.drop_namespace("__triggers__")
            yield ("flush",)

        clock.spawn(blocked, name="blocked")
        clock.run(main())
        assert woke == [PURGED]
        assert kv.subscriber_count(prefix="__triggers__") == 0


# ---------------------------------------------------------------------------
# Orchestrator integration: event-fired jobs end to end
# ---------------------------------------------------------------------------

_TENANTS = (TenantSpec("tenant-a"), TenantSpec("tenant-b"))


def _orch_config(substrate: "str | None" = None,
                 crash_at: "int | None" = None) -> OrchestratorConfig:
    stream = StreamConfig(n_events=40, rate_per_s=40.0, seed=3,
                          flush_event="eos")
    cost_kw = {} if substrate is None else {"substrate": substrate}
    faults = FaultConfig()
    if crash_at is not None:
        faults = FaultConfig(orchestrator_crash_point="dispatch",
                             orchestrator_crash_at=crash_at)
    return OrchestratorConfig(
        engine=EngineConfig(cost=CostModel(**cost_kw),
                            num_initial_invokers=4, num_proxy_invokers=4,
                            max_concurrency=512),
        workload=WorkloadConfig(n_jobs=2, tenants=_TENANTS, seed=1),
        max_concurrent_jobs=8,
        triggers=(
            TriggerRule("window", "kv_write", _ACTION,
                        key_prefix=stream.store_prefix, window_ms=250.0),
            TriggerRule("tick", "timer",
                        {"app": "tree_reduction", "size": 8,
                         "tenant": "tenant-b"},
                        period_ms=700.0, max_fires=2),
            TriggerRule("ckpt", "job_completed",
                        {"app": "dynamic_tree", "size": 8,
                         "tenant": "tenant-b"},
                        job_app="tree_reduction", every_n=4),
            TriggerRule("flush", "external", _ACTION, event="eos",
                        flush_windows=True),
        ),
        stream=stream,
        faults=faults,
    )


def _fire_summary(orch) -> "tuple[tuple, ...]":
    bus = orch.last_substrate.trigger_bus
    return tuple((r["fire_key"], r["source"], r["job_id"])
                 for r in bus.fired_records())


class TestOrchestratorStreaming:
    def test_all_four_sources_fire_jobs(self):
        orch = JobOrchestrator(_orch_config())
        rep = orch.run()
        assert rep.completed == rep.jobs and rep.failed == 0
        assert rep.jobs > 2  # trigger-fired jobs beyond the static two
        by_source = orch.last_substrate.trigger_bus.report().fires
        for source in ("timer", "kv_write", "job_completed", "external"):
            assert by_source.get(source, 0) >= 1, (source, by_source)
        # trigger-fired jobs carry bus-assigned ids above id_base
        trig_jobs = [r for r in rep.job_records
                     if r["job_id"] >= 1_000_000]
        assert len(trig_jobs) == rep.jobs - 2

    def test_repeat_runs_bit_identical(self):
        a = JobOrchestrator(_orch_config())
        b = JobOrchestrator(_orch_config())
        ra, rb = a.run(), b.run()
        assert ra.makespan_s == rb.makespan_s
        assert ra.billed_usd_total == rb.billed_usd_total
        assert _fire_summary(a) == _fire_summary(b)
        sa = a.last_substrate.trigger_bus.report(n_events=40)
        sb = b.last_substrate.trigger_bus.report(n_events=40)
        assert sa == sb

    def test_substrates_bit_identical(self):
        a = JobOrchestrator(_orch_config(substrate="event"))
        b = JobOrchestrator(_orch_config(substrate="thread"))
        ra, rb = a.run(), b.run()
        assert ra.makespan_s == rb.makespan_s
        assert ra.billed_usd_total == rb.billed_usd_total
        assert _fire_summary(a) == _fire_summary(b)

    def test_crash_mid_stream_recovers_exactly_once(self):
        base = JobOrchestrator(_orch_config())
        base_rep = base.run()
        crashed = JobOrchestrator(_orch_config(crash_at=5))
        rep = crashed.run_with_recovery()
        assert rep.crashes >= 1
        assert rep.completed == rep.jobs and rep.failed == 0
        # exactly-once: the journaled fire-key set matches the
        # uncrashed baseline (no lost window, no duplicate fire). Job
        # *ids* are allocated in event-arrival order, which legitimately
        # differs across crash generations — only uniqueness holds.
        assert [(k, s) for k, s, _ in _fire_summary(crashed)] \
            == [(k, s) for k, s, _ in _fire_summary(base)]
        ids = [r["job_id"] for r in rep.job_records]
        assert len(ids) == len(set(ids))
        assert rep.jobs == base_rep.jobs
