"""Engine correctness: all five engines, faults, stragglers, counters.

The central property: every engine computes exactly what a sequential
topological evaluation computes, for any DAG.
"""
import operator
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    CostModel,
    EngineConfig,
    FaultConfig,
    GraphBuilder,
    JobError,
    ParallelInvokerEngine,
    PubSubEngine,
    ServerfulEngine,
    StrawmanEngine,
    WukongEngine,
)
from repro.core.dag import TaskRef


def seq_eval(dag):
    vals = {}
    for k in dag.topological_order():
        t = dag.tasks[k]
        args = [vals[a.key] if isinstance(a, TaskRef) else a
                for a in t.args]
        kwargs = {kk: vals[v.key] if isinstance(v, TaskRef) else v
                  for kk, v in t.kwargs.items()}
        vals[k] = t.fn(*args, **kwargs)
    return {k: vals[k] for k in dag.roots}


def tree_dag(n):
    g = GraphBuilder()
    level = [g.add((lambda v: (lambda: v))(i), name=f"leaf-{i}")
             for i in range(n)]
    d = 0
    while len(level) > 1:
        level = [g.add(operator.add, level[i], level[i + 1],
                       name=f"add-{d}-{i // 2}")
                 for i in range(0, len(level), 2)]
        d += 1
    return g.build()


def random_dag(seed: int, n: int):
    rng = random.Random(seed)
    g = GraphBuilder()
    refs = []
    for i in range(n):
        k = rng.randint(0, min(4, len(refs)))
        deps = rng.sample(refs, k) if k else []
        if deps:
            refs.append(g.add(lambda *xs: sum(xs) + 1, *deps, name=f"n{i}"))
        else:
            refs.append(g.add((lambda v: (lambda: v))(i), name=f"n{i}"))
    return g.build()


ENGINES = [
    ("wukong", lambda: WukongEngine()),
    ("strawman", lambda: StrawmanEngine()),
    ("pubsub", lambda: PubSubEngine()),
    ("parallel_invoker", lambda: ParallelInvokerEngine()),
    ("serverful", lambda: ServerfulEngine()),
]


@pytest.mark.parametrize("name,factory", ENGINES)
def test_all_engines_tree(name, factory):
    dag = tree_dag(64)
    rep = factory().compute(dag)
    assert rep.results == seq_eval(dag)


@pytest.mark.parametrize("name,factory", ENGINES)
def test_all_engines_random_dag(name, factory):
    dag = random_dag(42, 50)
    assert factory().compute(dag).results == seq_eval(dag)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 60))
def test_wukong_matches_sequential_eval(seed, n):
    """Property: decentralized scheduling == topological evaluation."""
    dag = random_dag(seed, n)
    rep = WukongEngine().compute(dag)
    assert rep.results == seq_eval(dag)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_wukong_paper_counter_mode(seed):
    """Plain INCR counters (the paper's exact protocol) are equivalent
    when there are no retries."""
    dag = random_dag(seed, 40)
    rep = WukongEngine(EngineConfig(counter_mode="paper")).compute(dag)
    assert rep.results == seq_eval(dag)


def test_wide_fanout_uses_proxy():
    g = GraphBuilder()
    src = g.add(lambda: 3, name="src")
    outs = [g.add(lambda x, i=i: x * i, src, name=f"m{i}")
            for i in range(32)]
    g.add(lambda *xs: sum(xs), *outs, name="total")
    dag = g.build()
    rep = WukongEngine(EngineConfig(proxy_threshold=8)).compute(dag)
    assert rep.results["total"] == 3 * sum(range(32))


def test_executor_count_matches_paper_fig6():
    """Figure 6 walkthrough uses exactly 3 executors (E1, E2, E3)."""
    g = GraphBuilder()
    t1 = g.add(lambda: 1, name="T1")
    t2 = g.add(lambda: 2, name="T2")
    t3 = g.add(lambda x: x + 10, t2, name="T3")
    t5 = g.add(lambda x: x * 2, t3, name="T5")
    g.add(operator.add, t1, t3, name="T4")
    g.add(operator.add, TaskRef("T4"), t5, name="T6")
    rep = WukongEngine().compute(g.build())
    assert rep.results == {"T6": 37}
    assert rep.executors_invoked == 3


class TestFaultTolerance:
    def test_retries_recover(self):
        """seed=21 is a verified recoverable injection under the
        process-stable fault hash (failures at attempts 0/1 on disjoint
        keys, none at the final attempt), so completion is guaranteed
        regardless of executor arrival order."""
        dag = tree_dag(32)
        cfg = EngineConfig(faults=FaultConfig(
            task_failure_prob=0.04, max_retries=2, seed=21))
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)
        # the report's fault counters audit the recovery work: this seed
        # injects failures, every one is retried, and attempts account
        # for every task plus every retry
        assert rep.fault_stats["injected_failures"] > 0
        assert rep.fault_stats["task_retries"] == \
            rep.fault_stats["injected_failures"]
        assert rep.fault_stats["task_attempts"] >= \
            len(dag.tasks) + rep.fault_stats["injected_failures"]

    def test_exhausted_retries_fail_loudly(self):
        g = GraphBuilder()
        g.add(lambda: 1, name="only")
        cfg = EngineConfig(faults=FaultConfig(
            task_failure_prob=1.0, max_retries=2, seed=0),
            job_timeout_s=20.0)
        with pytest.raises(JobError, match="failed"):
            WukongEngine(cfg).compute(g.build())

    def test_task_exception_propagates(self):
        g = GraphBuilder()

        def boom():
            raise RuntimeError("kaboom")

        g.add(boom, name="bad")
        with pytest.raises(JobError, match="kaboom"):
            WukongEngine().compute(g.build())

    def test_speculative_straggler_duplicates_are_safe(self):
        dag = tree_dag(16)
        cfg = EngineConfig(
            cost=CostModel(time_scale=0.01),
            faults=FaultConfig(straggler_prob=0.2,
                               straggler_slowdown_ms=2000,
                               speculative_threshold_ms=200, seed=5),
            speculative_poll_s=0.005,
        )
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)
        # speculation fired (that is what this config provokes) and each
        # duplicate is counted — the billing-overhead audit trail
        assert rep.fault_stats["speculative_duplicates"] > 0

    def test_edge_set_counters_safe_under_retries(self):
        """Retries must not double-fire fan-ins. With the paper's plain
        INCR counters they CAN (the documented hazard, why a retry run
        cannot be asserted in that mode); edge_set counters close the
        hole, so the job must complete correctly. seed=6 is a verified
        recoverable injection under the process-stable fault hash
        (failures at attempt 0 but none at later attempts), so completion
        is guaranteed regardless of executor arrival order."""
        dag = tree_dag(8)
        cfg = EngineConfig(
            counter_mode="edge_set",
            faults=FaultConfig(task_failure_prob=0.1, max_retries=2,
                               seed=6))
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)


class TestFaultStatsReporting:
    """JobReport.fault_stats: the per-job retry/failure audit trail."""

    def test_clean_run_reports_zero_fault_activity(self):
        dag = tree_dag(16)
        rep = WukongEngine().compute(dag)
        stats = rep.fault_stats
        assert stats["task_attempts"] == len(dag.tasks)
        for field in ("injected_failures", "task_retries",
                      "speculative_duplicates", "throttle_retries",
                      "tasks_resumed"):
            assert stats[field] == 0

    def test_throttle_retries_counted(self):
        # 2-slot account + eager invokers: 429s are inevitable, and each
        # charged backoff round trip is counted in the report.
        from repro.platform import PlatformConfig
        dag = tree_dag(32)
        cfg = EngineConfig(
            platform=PlatformConfig(account_concurrency=2,
                                    burst_concurrency=2),
            num_initial_invokers=8)
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)
        assert rep.fault_stats["throttle_retries"] > 0

    def test_deterministic_across_runs(self):
        dag = tree_dag(32)
        cfg = EngineConfig(faults=FaultConfig(
            task_failure_prob=0.04, max_retries=2, seed=21))
        r1 = WukongEngine(cfg).compute(dag)
        r2 = WukongEngine(cfg).compute(dag)
        assert r1.fault_stats == r2.fault_stats


class TestFaultConfigValidation:
    """Satellite: every bad knob is rejected at construction, not
    discovered as a silent mid-run misbehavior."""

    @pytest.mark.parametrize("field,value", [
        ("task_failure_prob", -0.1),
        ("task_failure_prob", 1.1),
        ("straggler_prob", -0.5),
        ("straggler_prob", 2.0),
        ("max_retries", -1),
        ("retry_backoff_base_ms", -1.0),
        ("straggler_slowdown_ms", -10.0),
        ("max_backoff_ms", 0.0),
        ("max_backoff_ms", -5.0),
        ("speculative_threshold_ms", 0.0),
        ("speculative_threshold_ms", -1.0),
        ("orchestrator_crash_point", "bogus"),
        ("orchestrator_crash_at", 0),
    ])
    def test_bad_field_raises(self, field, value):
        with pytest.raises(ValueError, match=field):
            FaultConfig(**{field: value})

    def test_boundary_values_accepted(self):
        FaultConfig(task_failure_prob=0.0)
        FaultConfig(task_failure_prob=1.0)
        FaultConfig(straggler_prob=1.0, max_retries=0,
                    retry_backoff_base_ms=0.0, straggler_slowdown_ms=0.0)
        FaultConfig(speculative_threshold_ms=float("inf"))
        FaultConfig(orchestrator_crash_point=None)
        FaultConfig(orchestrator_crash_point="dispatch",
                    orchestrator_crash_at=1)


class TestRetryBackoffCap:
    """Satellite: exponential retry backoff saturates at max_backoff_ms
    instead of letting 2**k dominate the simulated makespan."""

    def test_exponential_growth_then_cap(self):
        from repro.core.faults import exponential_backoff_ms
        assert exponential_backoff_ms(100.0, 0, cap_ms=1e4) == 100.0
        assert exponential_backoff_ms(100.0, 3, cap_ms=1e4) == 800.0
        assert exponential_backoff_ms(100.0, 20, cap_ms=1e4) == 1e4
        assert exponential_backoff_ms(0.0, 50, cap_ms=1e4) == 0.0

    def test_injector_applies_configured_cap(self):
        from repro.core.faults import FaultInjector
        inj = FaultInjector(FaultConfig(retry_backoff_base_ms=1000.0,
                                        max_backoff_ms=4000.0))
        assert [inj.retry_backoff_ms(k) for k in range(5)] == \
            [1000.0, 2000.0, 4000.0, 4000.0, 4000.0]

    def test_cap_bounds_charged_retry_delay(self):
        # seed=21 on tree_dag(32) is the verified recoverable injection
        # (see test_retries_recover). Same faults, huge backoff base:
        # a tight cap must make the charged makespan strictly smaller
        # than a loose one, by at least the backoff delta it shaves.
        dag = tree_dag(32)

        def run(cap_ms):
            cfg = EngineConfig(faults=FaultConfig(
                task_failure_prob=0.04, max_retries=2, seed=21,
                retry_backoff_base_ms=5e4, max_backoff_ms=cap_ms))
            rep = WukongEngine(cfg).compute(dag)
            assert rep.results == seq_eval(dag)
            return rep

        tight, loose = run(10.0), run(1e6)
        assert tight.fault_stats["task_retries"] == \
            loose.fault_stats["task_retries"] > 0
        assert tight.charged_ms < loose.charged_ms


class TestCostAccounting:
    def test_invocations_charged(self):
        dag = tree_dag(16)
        rep = WukongEngine().compute(dag)
        # 16 leaf schedules; every invocation costs >= invoke_ms
        assert rep.executors_invoked >= 16
        assert rep.charged_ms >= rep.executors_invoked * 50.0

    def test_locality_reduces_kv_traffic(self):
        """WUKONG's executor-local caching must move fewer KV bytes than
        the centralized engine on the same chain-heavy DAG."""
        g = GraphBuilder()
        cur = g.add(lambda: list(range(2048)), name="start")
        for i in range(20):  # a pure chain: all local for WUKONG
            cur = g.add(lambda x: x, cur, name=f"c{i}")
        dag = g.build()
        w = WukongEngine().compute(dag)
        c = PubSubEngine().compute(dag)
        wb = w.kv_stats["bytes_read"] + w.kv_stats["bytes_written"]
        cb = c.kv_stats["bytes_read"] + c.kv_stats["bytes_written"]
        assert wb < cb / 5
