"""Engine correctness: all five engines, faults, stragglers, counters.

The central property: every engine computes exactly what a sequential
topological evaluation computes, for any DAG.
"""
import operator
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    CostModel,
    EngineConfig,
    FaultConfig,
    GraphBuilder,
    JobError,
    ParallelInvokerEngine,
    PubSubEngine,
    ServerfulEngine,
    StrawmanEngine,
    WukongEngine,
)
from repro.core.dag import TaskRef


def seq_eval(dag):
    vals = {}
    for k in dag.topological_order():
        t = dag.tasks[k]
        args = [vals[a.key] if isinstance(a, TaskRef) else a
                for a in t.args]
        kwargs = {kk: vals[v.key] if isinstance(v, TaskRef) else v
                  for kk, v in t.kwargs.items()}
        vals[k] = t.fn(*args, **kwargs)
    return {k: vals[k] for k in dag.roots}


def tree_dag(n):
    g = GraphBuilder()
    level = [g.add((lambda v: (lambda: v))(i), name=f"leaf-{i}")
             for i in range(n)]
    d = 0
    while len(level) > 1:
        level = [g.add(operator.add, level[i], level[i + 1],
                       name=f"add-{d}-{i // 2}")
                 for i in range(0, len(level), 2)]
        d += 1
    return g.build()


def random_dag(seed: int, n: int):
    rng = random.Random(seed)
    g = GraphBuilder()
    refs = []
    for i in range(n):
        k = rng.randint(0, min(4, len(refs)))
        deps = rng.sample(refs, k) if k else []
        if deps:
            refs.append(g.add(lambda *xs: sum(xs) + 1, *deps, name=f"n{i}"))
        else:
            refs.append(g.add((lambda v: (lambda: v))(i), name=f"n{i}"))
    return g.build()


ENGINES = [
    ("wukong", lambda: WukongEngine()),
    ("strawman", lambda: StrawmanEngine()),
    ("pubsub", lambda: PubSubEngine()),
    ("parallel_invoker", lambda: ParallelInvokerEngine()),
    ("serverful", lambda: ServerfulEngine()),
]


@pytest.mark.parametrize("name,factory", ENGINES)
def test_all_engines_tree(name, factory):
    dag = tree_dag(64)
    rep = factory().compute(dag)
    assert rep.results == seq_eval(dag)


@pytest.mark.parametrize("name,factory", ENGINES)
def test_all_engines_random_dag(name, factory):
    dag = random_dag(42, 50)
    assert factory().compute(dag).results == seq_eval(dag)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 60))
def test_wukong_matches_sequential_eval(seed, n):
    """Property: decentralized scheduling == topological evaluation."""
    dag = random_dag(seed, n)
    rep = WukongEngine().compute(dag)
    assert rep.results == seq_eval(dag)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_wukong_paper_counter_mode(seed):
    """Plain INCR counters (the paper's exact protocol) are equivalent
    when there are no retries."""
    dag = random_dag(seed, 40)
    rep = WukongEngine(EngineConfig(counter_mode="paper")).compute(dag)
    assert rep.results == seq_eval(dag)


def test_wide_fanout_uses_proxy():
    g = GraphBuilder()
    src = g.add(lambda: 3, name="src")
    outs = [g.add(lambda x, i=i: x * i, src, name=f"m{i}")
            for i in range(32)]
    g.add(lambda *xs: sum(xs), *outs, name="total")
    dag = g.build()
    rep = WukongEngine(EngineConfig(proxy_threshold=8)).compute(dag)
    assert rep.results["total"] == 3 * sum(range(32))


def test_executor_count_matches_paper_fig6():
    """Figure 6 walkthrough uses exactly 3 executors (E1, E2, E3)."""
    g = GraphBuilder()
    t1 = g.add(lambda: 1, name="T1")
    t2 = g.add(lambda: 2, name="T2")
    t3 = g.add(lambda x: x + 10, t2, name="T3")
    t5 = g.add(lambda x: x * 2, t3, name="T5")
    g.add(operator.add, t1, t3, name="T4")
    g.add(operator.add, TaskRef("T4"), t5, name="T6")
    rep = WukongEngine().compute(g.build())
    assert rep.results == {"T6": 37}
    assert rep.executors_invoked == 3


class TestFaultTolerance:
    def test_retries_recover(self):
        """seed=21 is a verified recoverable injection under the
        process-stable fault hash (failures at attempts 0/1 on disjoint
        keys, none at the final attempt), so completion is guaranteed
        regardless of executor arrival order."""
        dag = tree_dag(32)
        cfg = EngineConfig(faults=FaultConfig(
            task_failure_prob=0.04, max_retries=2, seed=21))
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)

    def test_exhausted_retries_fail_loudly(self):
        g = GraphBuilder()
        g.add(lambda: 1, name="only")
        cfg = EngineConfig(faults=FaultConfig(
            task_failure_prob=1.0, max_retries=2, seed=0),
            job_timeout_s=20.0)
        with pytest.raises(JobError, match="failed"):
            WukongEngine(cfg).compute(g.build())

    def test_task_exception_propagates(self):
        g = GraphBuilder()

        def boom():
            raise RuntimeError("kaboom")

        g.add(boom, name="bad")
        with pytest.raises(JobError, match="kaboom"):
            WukongEngine().compute(g.build())

    def test_speculative_straggler_duplicates_are_safe(self):
        dag = tree_dag(16)
        cfg = EngineConfig(
            cost=CostModel(time_scale=0.01),
            faults=FaultConfig(straggler_prob=0.2,
                               straggler_slowdown_ms=2000,
                               speculative_threshold_ms=200, seed=5),
            speculative_poll_s=0.005,
        )
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)

    def test_edge_set_counters_safe_under_retries(self):
        """Retries must not double-fire fan-ins. With the paper's plain
        INCR counters they CAN (the documented hazard, why a retry run
        cannot be asserted in that mode); edge_set counters close the
        hole, so the job must complete correctly. seed=6 is a verified
        recoverable injection under the process-stable fault hash
        (failures at attempt 0 but none at later attempts), so completion
        is guaranteed regardless of executor arrival order."""
        dag = tree_dag(8)
        cfg = EngineConfig(
            counter_mode="edge_set",
            faults=FaultConfig(task_failure_prob=0.1, max_retries=2,
                               seed=6))
        rep = WukongEngine(cfg).compute(dag)
        assert rep.results == seq_eval(dag)


class TestCostAccounting:
    def test_invocations_charged(self):
        dag = tree_dag(16)
        rep = WukongEngine().compute(dag)
        # 16 leaf schedules; every invocation costs >= invoke_ms
        assert rep.executors_invoked >= 16
        assert rep.charged_ms >= rep.executors_invoked * 50.0

    def test_locality_reduces_kv_traffic(self):
        """WUKONG's executor-local caching must move fewer KV bytes than
        the centralized engine on the same chain-heavy DAG."""
        g = GraphBuilder()
        cur = g.add(lambda: list(range(2048)), name="start")
        for i in range(20):  # a pure chain: all local for WUKONG
            cur = g.add(lambda x: x, cur, name=f"c{i}")
        dag = g.build()
        w = WukongEngine().compute(dag)
        c = PubSubEngine().compute(dag)
        wb = w.kv_stats["bytes_read"] + w.kv_stats["bytes_written"]
        cb = c.kv_stats["bytes_read"] + c.kv_stats["bytes_written"]
        assert wb < cb / 5
