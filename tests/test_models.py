"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned architecture instantiates a REDUCED config of the same
family and runs one forward + one train step on CPU, asserting output
shapes and finiteness; decode-vs-forward agreement validates the cache
machinery for serving.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import model as M
from repro.models.config import applicable_shapes, sub_quadratic
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.train import build_train_step, synthetic_batch

# Architectures whose reduced configs still take tens of seconds to
# trace/compile on CPU. They run in the default tier (`pytest` with no
# -m filter) but CI's fast tier deselects them with `-m "not slow"` and
# runs them in a separate job.
SLOW_ARCHS = {"jamba_1_5_large_398b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
            else a for a in archs]


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, specs = M.init_model(key, cfg)
    # spec tree parallels the param tree
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(
            lambda s: 0, specs,
            is_leaf=lambda s: isinstance(s, tuple) and all(
                isinstance(e, (str, type(None))) for e in s)))

    B, S = 2, 64
    batch = synthetic_batch(cfg, B, S, seed=1)
    logits = M.forward(params, cfg, batch["tokens"],
                       batch.get("enc_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    step = build_train_step(cfg, AdamWConfig(lr=1e-3, warmup=1))
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2["count"]) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", _arch_params(["llama3_405b",
                                               "mixtral_8x7b",
                                               "xlstm_350m",
                                               "jamba_1_5_large_398b",
                                               "qwen2_72b"]))
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params, _ = M.init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full = M.forward(params, cfg, tokens)
    cache = M.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, cache, tokens[:, t],
                                  jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 1e-3, rel


def test_train_loss_decreases_on_memorization():
    """Integration: a tiny model memorizes one batch in a few steps."""
    cfg = reduced(get_config("smollm_360m"))
    cfg = dataclasses.replace(cfg, n_layers=2, vocab=64)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, 4, 32, seed=7)
    step = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=5e-3, weight_decay=0.0, warmup=1)))
    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced(get_config("smollm_360m"))
    cfg = dataclasses.replace(cfg, n_layers=2)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    batch = synthetic_batch(cfg, 8, 32, seed=3)
    opt = adamw_init(params)
    p1, _, m1 = jax.jit(build_train_step(cfg, AdamWConfig()))(
        params, opt, batch)
    p2, _, m2 = jax.jit(build_train_step(cfg, AdamWConfig(),
                                         n_microbatches=4))(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert err < 2e-3, err


def test_param_counts_match_published_sizes():
    expected = {
        "llama3_405b": 405.9e9,
        "nemotron_4_340b": 341e9,
        "qwen2_72b": 72.7e9,
        "jamba_1_5_large_398b": 397.5e9,
        "mixtral_8x7b": 46.7e9,
        "mixtral_8x22b": 140.6e9,
        "chameleon_34b": 34.3e9,
        "smollm_360m": 362e6,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_counts()["total"]
        assert abs(got - want) / want < 0.05, (arch, got, want)


def test_long_context_applicability():
    assert sub_quadratic(get_config("xlstm_350m"))
    assert sub_quadratic(get_config("mixtral_8x7b"))       # SWA
    assert sub_quadratic(get_config("jamba_1_5_large_398b"))
    assert not sub_quadratic(get_config("llama3_405b"))
    assert not sub_quadratic(get_config("whisper_large_v3"))
    assert "long_500k" not in applicable_shapes(get_config("chameleon_34b"))


def test_sliding_window_cache_is_bounded():
    cfg = reduced(get_config("mixtral_8x7b"))  # window 32 after reduction
    cache = M.init_cache(cfg, batch=2, seq_len=4096)
    k = cache[0]["k"]
    assert k.shape[2] == cfg.sliding_window  # bounded by the window
