"""Durable control plane: state machine, journal, crash → replay recovery.

The acceptance properties (ISSUE 7):

- the job state machine is monotonic and replay-safe: duplicate /
  regressive transitions are no-ops, the first terminal state wins, and
  replaying the journal rebuilds the exact state;
- journal primitives are charged KV operations and are reclaimed with
  their namespace;
- for every injected orchestrator crash point, on BOTH simulation
  substrates, a recovered run completes all jobs, journaled-complete
  jobs are returned from the journal (never re-executed), and their
  billed USD is bit-identical to the uncrashed baseline.
"""
import dataclasses

import pytest

from repro.core import (
    ADMITTED,
    COMPLETED,
    CostModel,
    EngineConfig,
    FAILED,
    FaultConfig,
    JobOrchestrator,
    JobStateMachine,
    OrchestratorConfig,
    OrchestratorCrashed,
    PENDING,
    RUNNING,
    ShardedKVStore,
    TenantSpec,
    WorkloadConfig,
)
from repro.core.statemachine import InvalidTransition

SUBSTRATES = ("event", "thread")
CRASH_POINTS = ("admit", "dispatch", "complete")


def _cost(substrate):
    return CostModel(substrate=substrate)


def _engine_cfg(substrate="event", **kw):
    kw.setdefault("num_initial_invokers", 2)
    kw.setdefault("num_proxy_invokers", 2)
    kw.setdefault("max_concurrency", 64)
    kw.setdefault("cost", _cost(substrate))
    return EngineConfig(**kw)


def _workload(n_jobs=6, seed=3):
    return WorkloadConfig(
        n_jobs=n_jobs, arrival_rate_per_s=8.0, seed=seed,
        tenants=(TenantSpec("t-a", 1792, tier="standard", priority=1,
                            slo_s=120.0),
                 TenantSpec("t-b", 896, tier="batch", priority=0)),
        app_mix=(("tree_reduction", 1.0),), compute_ms=5.0)


def _orch_cfg(substrate="event", crash_point=None, crash_at=2, **kw):
    faults = FaultConfig(orchestrator_crash_point=crash_point,
                         orchestrator_crash_at=crash_at)
    kw.setdefault("engine", _engine_cfg(substrate))
    kw.setdefault("workload", _workload())
    kw.setdefault("max_concurrent_jobs", 3)
    return OrchestratorConfig(faults=faults, **kw)


# ---------------------------------------------------------------------------
# State machine semantics
# ---------------------------------------------------------------------------


class TestJobStateMachine:
    def test_monotonic_forward_transitions(self):
        kv = ShardedKVStore(n_shards=4)
        m = JobStateMachine(kv.namespace("__control__"))
        for state in (PENDING, ADMITTED, RUNNING, COMPLETED):
            assert kv.clock.run(m.record_g(0, state)) is True
        assert m.state(0) == COMPLETED

    def test_duplicates_and_regressions_are_noops(self):
        kv = ShardedKVStore(n_shards=4)
        m = JobStateMachine(kv.namespace("__control__"))
        kv.clock.run(m.record_g(0, RUNNING))
        before = m.journal_len()
        # duplicate, regression, and a second terminal after the first:
        assert kv.clock.run(m.record_g(0, RUNNING)) is False
        assert kv.clock.run(m.record_g(0, PENDING)) is False
        kv.clock.run(m.record_g(0, COMPLETED))
        assert kv.clock.run(m.record_g(0, FAILED)) is False  # first wins
        assert m.state(0) == COMPLETED
        # no-ops are not journaled (replay must not grow the log)
        assert m.journal_len() == before + 1

    def test_unknown_state_raises(self):
        kv = ShardedKVStore(n_shards=4)
        m = JobStateMachine(kv.namespace("__control__"))
        with pytest.raises(InvalidTransition):
            kv.clock.run(m.record_g(0, "EXPLODED"))

    def test_replay_rebuilds_state_and_payloads(self):
        kv = ShardedKVStore(n_shards=4)
        ctrl = kv.namespace("__control__")
        m = JobStateMachine(ctrl)
        kv.clock.run(m.record_g(0, PENDING, payload={"app": "x"}))
        kv.clock.run(m.record_g(0, RUNNING))
        kv.clock.run(m.record_g(1, PENDING, payload={"app": "y"}))
        kv.clock.run(m.record_g(1, COMPLETED, payload={"latency_s": 2.0}))
        fresh = JobStateMachine(ctrl)
        assert kv.clock.run(fresh.replay_g()) == 4
        assert fresh.jobs() == m.jobs() == {0: RUNNING, 1: COMPLETED}
        assert fresh.payload(0, PENDING) == {"app": "x"}
        assert fresh.payload(1, COMPLETED) == {"latency_s": 2.0}
        # replay is idempotent: a second replay changes nothing
        assert kv.clock.run(fresh.replay_g()) == 4
        assert fresh.jobs() == {0: RUNNING, 1: COMPLETED}

    def test_transitions_are_charged(self):
        kv = ShardedKVStore(n_shards=4)
        m = JobStateMachine(kv.namespace("__control__"))
        t0 = kv.clock.charged_ms
        kv.clock.run(m.record_g(0, PENDING, payload={"app": "x"}))
        assert kv.clock.charged_ms > t0
        assert kv.stats.journal_appends == 1
        t1 = kv.clock.charged_ms
        kv.clock.run(m.replay_g())
        assert kv.clock.charged_ms > t1
        assert kv.stats.journal_scans == 1


# ---------------------------------------------------------------------------
# Journal primitives (kvstore layer)
# ---------------------------------------------------------------------------


class TestJournalPrimitives:
    def test_append_scan_order_and_len(self):
        kv = ShardedKVStore(n_shards=4)
        assert kv.journal_append("log", {"n": 0}) == 0
        assert kv.journal_append("log", {"n": 1}) == 1
        assert kv.journal_scan("log") == [{"n": 0}, {"n": 1}]
        assert kv.journal_len("log") == 2
        assert kv.journal_scan("absent") == []
        assert kv.journal_len("absent") == 0

    def test_journals_live_outside_shard_data(self):
        kv = ShardedKVStore(n_shards=4)
        kv.journal_append("log", {"n": 0})
        assert sum(len(s.data) for s in kv.shards) == 0

    def test_scan_cost_grows_with_journal(self):
        kv = ShardedKVStore(n_shards=4)
        kv.journal_append("log", b"x" * 1000)
        t0 = kv.clock.charged_ms
        kv.journal_scan("log")
        short = kv.clock.charged_ms - t0
        for _ in range(8):
            kv.journal_append("log", b"x" * 1000)
        t1 = kv.clock.charged_ms
        kv.journal_scan("log")
        assert kv.clock.charged_ms - t1 > short

    def test_namespaced_journals_are_prefixed_and_purged(self):
        kv = ShardedKVStore(n_shards=4)
        ns = kv.namespace("ctrl")
        ns.journal_append("log", {"n": 0})
        assert ns.journal_len("log") == 1
        assert kv.journal_len("ctrl::log") == 1
        assert kv.journal_len("log") == 0
        assert ns.stats.journal_appends == 1
        kv.drop_namespace("ctrl")
        assert ns.journal_len("log") == 0
        assert ns.journal_scan("log") == []


# ---------------------------------------------------------------------------
# Crash → replay recovery (the tentpole acceptance sweep)
# ---------------------------------------------------------------------------


def _baseline(substrate):
    rep = JobOrchestrator(_orch_cfg(substrate)).run()
    assert rep.completed == rep.jobs
    return rep


class TestCrashRecovery:
    @pytest.mark.parametrize("substrate", SUBSTRATES)
    @pytest.mark.parametrize("point", CRASH_POINTS)
    def test_recovered_run_completes_with_billing_parity(
            self, substrate, point):
        base = _baseline(substrate)
        base_by_id = {r["job_id"]: r for r in base.job_records}

        orch = JobOrchestrator(_orch_cfg(substrate, crash_point=point))
        rep = orch.run_with_recovery()

        assert rep.crashes == 1
        assert rep.completed == rep.jobs == base.jobs
        assert rep.failed == 0
        # every journaled-complete job is returned from the journal with
        # billed USD (and latency) bit-identical to the uncrashed
        # baseline — no double execution, no double billing
        from_journal = [r for r in rep.job_records if r.get("from_journal")]
        for rec in from_journal:
            b = base_by_id[rec["job_id"]]
            assert rec["billed_usd"] == b["billed_usd"]
            assert rec["latency_s"] == b["latency_s"]
        # per-tenant billed USD over the already-completed jobs matches
        # the baseline sum exactly
        for tenant in {r["tenant"] for r in from_journal}:
            assert sum(r["billed_usd"] for r in from_journal
                       if r["tenant"] == tenant) == \
                sum(base_by_id[r["job_id"]]["billed_usd"]
                    for r in from_journal if r["tenant"] == tenant)
        if point in ("admit", "dispatch"):
            # crash hits while jobs are in flight: recovery re-admits
            assert rep.recovered_jobs > 0

    @pytest.mark.parametrize("substrate", SUBSTRATES)
    def test_complete_crash_leaves_journaled_jobs_untouched(
            self, substrate):
        """Crash between COMPLETED journal and purge: the job's bill in
        the shared meter must not grow during recovery (its work is
        never re-executed)."""
        orch = JobOrchestrator(
            _orch_cfg(substrate, crash_point="complete", crash_at=2))
        rep = orch.run_with_recovery()
        from_journal = [r for r in rep.job_records if r.get("from_journal")]
        assert from_journal  # the 2nd completion was journaled pre-crash
        platform = orch.last_substrate.platform
        for rec in from_journal:
            metered = platform.meter.job_snapshot(
                f"job{rec['job_id']}")["billed_usd"]
            assert metered == rec["billed_usd"]

    def test_recovery_purges_orphaned_namespace(self):
        """The 'complete' crash orphans the finished job's namespace in
        the shared store; replay recovery must reclaim it (and every
        later job's) so the store ends empty."""
        orch = JobOrchestrator(_orch_cfg(crash_point="complete"))
        rep = orch.run_with_recovery()
        assert rep.completed == rep.jobs
        kv = orch.last_substrate.kv
        assert sum(len(s.data) for s in kv.shards) == 0
        assert kv._counters == {}
        assert kv._channels == {}
        # the control journal itself survives (it IS the durable state)
        assert kv.journal_len("__control__::journal") > 0

    def test_crash_is_deterministic_on_event_substrate(self):
        cfg = _orch_cfg(crash_point="dispatch")
        r1 = JobOrchestrator(cfg).run_with_recovery()
        r2 = JobOrchestrator(cfg).run_with_recovery()
        assert r1.job_records == r2.job_records
        assert r1.crashes == r2.crashes == 1
        assert r1.recovered_jobs == r2.recovered_jobs

    def test_run_raises_without_supervision(self):
        orch = JobOrchestrator(_orch_cfg(crash_point="admit", crash_at=1))
        with pytest.raises(OrchestratorCrashed) as ei:
            orch.run()
        assert ei.value.point == "admit"
        # the substrate carried on the exception is the run's substrate
        assert ei.value.substrate is orch.last_substrate

    def test_manual_recover_on_fresh_instance(self):
        """Recovery needs nothing from the dead process: a brand-new
        orchestrator + the crashed substrate's journal completes the
        workload."""
        cfg = _orch_cfg(crash_point="dispatch")
        crashed = JobOrchestrator(cfg)
        with pytest.raises(OrchestratorCrashed) as ei:
            crashed.run()
        fresh = JobOrchestrator(cfg)
        rep = fresh.recover(ei.value.substrate, injector=ei.value.injector)
        assert rep.completed == rep.jobs
        assert rep.recovered_jobs > 0

    def test_resume_skips_durable_outputs(self):
        """A 'complete'-point crash leaves earlier jobs' in-flight peers
        mid-run; their recovery re-admission must reuse durable task
        outputs (tasks_resumed > 0) rather than recompute everything."""
        rep = JobOrchestrator(
            _orch_cfg(crash_point="complete")).run_with_recovery()
        assert rep.tasks_resumed > 0

    def test_injected_crash_fires_exactly_once(self):
        """The injector's occurrence counter spans generations: the
        recovered dispatcher passes the same crash point again without
        re-crashing."""
        cfg = _orch_cfg(crash_point="admit", crash_at=1)
        rep = JobOrchestrator(cfg).run_with_recovery()
        assert rep.crashes == 1
        assert rep.completed == rep.jobs


# ---------------------------------------------------------------------------
# Orchestrator-level FaultConfig plumbing
# ---------------------------------------------------------------------------


class TestCrashConfigValidation:
    def test_unknown_crash_point_rejected(self):
        with pytest.raises(ValueError):
            FaultConfig(orchestrator_crash_point="reboot")

    def test_crash_at_must_be_positive(self):
        with pytest.raises(ValueError):
            FaultConfig(orchestrator_crash_point="admit",
                        orchestrator_crash_at=0)

    def test_tenant_spec_validation(self):
        with pytest.raises(ValueError):
            TenantSpec("t", memory_mb=0)
        with pytest.raises(ValueError):
            TenantSpec("t", max_concurrent_jobs=0)
        with pytest.raises(ValueError):
            TenantSpec("t", slo_s=0.0)

    def test_engine_faults_and_orchestrator_faults_are_independent(self):
        cfg = _orch_cfg(crash_point="admit")
        assert cfg.engine.faults.orchestrator_crash_point is None
        assert cfg.faults.orchestrator_crash_point == "admit"
        # dataclasses.replace round-trips the new fields
        again = dataclasses.replace(cfg.faults, orchestrator_crash_at=3)
        assert again.orchestrator_crash_at == 3
