"""Fallback shims so the suite collects without ``hypothesis`` installed.

Test modules guard their hypothesis import with::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

When hypothesis is missing (the dev extra is not installed), ``given``
replaces each property test with a zero-argument test that skips with an
explanatory reason, so example-based tests in the same module still run.
"""
from __future__ import annotations

from typing import Any, Callable

import pytest

_REASON = "hypothesis not installed (pip install -e .[dev])"


class _AnyStrategy:
    """Stands in for ``hypothesis.strategies``; every attribute is a
    callable returning an opaque placeholder (never drawn from)."""

    def __getattr__(self, name: str) -> Callable[..., Any]:
        def strategy(*args: Any, **kwargs: Any) -> Any:
            return None

        strategy.__name__ = name
        return strategy


st = _AnyStrategy()


def settings(*args: Any, **kwargs: Any) -> Callable[[Callable], Callable]:
    if args and callable(args[0]) and len(args) == 1 and not kwargs:
        return args[0]  # bare @settings

    def decorate(fn: Callable) -> Callable:
        return fn

    return decorate


def given(*args: Any, **kwargs: Any) -> Callable[[Callable], Callable]:
    def decorate(fn: Callable) -> Callable:
        # Replace with a zero-arg stand-in so pytest does not try to
        # resolve the property arguments as fixtures.
        def skipped() -> None:  # pragma: no cover - always skipped
            pass

        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return pytest.mark.skip(reason=_REASON)(skipped)

    return decorate
