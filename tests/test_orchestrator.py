"""Multi-tenant orchestrator: shared substrate, fairness, leak fixes.

The tentpole properties:

- *substrate reuse*: back-to-back jobs on one engine instance — and two
  jobs on one shared substrate — produce equivalent reports, leave the
  store's pub/sub channel table empty, and keep the simclock worker
  cache bounded.
- *fair admission*: a flooding tenant cannot starve a light tenant of
  admission slots.
- *per-tenant billing isolation*: the shared account's per-tenant bill
  equals what each tenant would be billed on a private platform.
- *leak fixes*: subscriptions are released at job teardown
  (``_channels`` ends empty), and a failed (cancelled) job's in-flight
  executors stop at the next task boundary instead of walking — and
  billing — the rest of the DAG against the shared platform.
"""
import pytest

from repro.apps import tree_reduction_dag
from repro.apps.tree_reduction import tree_reduction_expected
from repro.core import (
    CacheConfig,
    CostModel,
    EngineConfig,
    FaultConfig,
    GraphBuilder,
    JobError,
    JobOrchestrator,
    JobRequest,
    OrchestratorConfig,
    ShardedKVStore,
    TenantSpec,
    WorkloadConfig,
    WukongEngine,
    generate_workload,
)
from repro.core.orchestrator import Substrate, _SIZE_LADDERS
from repro.core.simclock import simulated_compute
from repro.platform import PlatformConfig


def _engine_cfg(**kw):
    kw.setdefault("num_initial_invokers", 4)
    kw.setdefault("num_proxy_invokers", 4)
    return EngineConfig(**kw)


def _tr_workload(n_jobs=8, rate=4.0, tenants=None, seed=0, compute_ms=10.0):
    return WorkloadConfig(
        n_jobs=n_jobs, arrival_rate_per_s=rate, seed=seed,
        tenants=tenants or (TenantSpec("t-a", 1792), TenantSpec("t-b", 896)),
        app_mix=(("tree_reduction", 1.0),), compute_ms=compute_ms)


def _round(x, digits=12):
    return float(f"{x:.{digits}g}")


def _normalize(report):
    """A JobReport projected onto substrate-offset-independent form.

    Everything discrete must be bit-identical between two identical jobs
    on one shared substrate; timing floats are rounded to 12 significant
    digits because the shared clock does not restart between jobs, and
    float arithmetic at different absolute offsets differs in the last
    ulp (representation noise, not behavioral divergence)."""
    return {
        "results": {k: float(v[0]) for k, v in report.results.items()},
        "wall_s": _round(report.wall_s),
        "charged_ms": _round(report.charged_ms),
        "tasks": report.tasks,
        "executors": report.executors_invoked,
        "kv_stats": report.kv_stats,
        "metrics": [
            {k: (_round(v) if isinstance(v, float) else v)
             for k, v in m.items()}
            for m in report.metrics
        ],
    }


# ---------------------------------------------------------------------------
# KV namespaces (the per-job views of the shared store)
# ---------------------------------------------------------------------------


class TestKVNamespace:
    def test_namespaces_do_not_collide(self):
        kv = ShardedKVStore(n_shards=4)
        a, b = kv.namespace("job0"), kv.namespace("job1")
        a.put("x", 1)
        b.put("x", 2)
        assert a.get("x") == 1 and b.get("x") == 2
        assert a.exists("x") and not a.exists("y")
        a.delete("x")
        assert not a.exists("x") and b.get("x") == 2

    def test_placement_ignores_registered_namespace_prefix(self):
        kv = ShardedKVStore(n_shards=10)
        kv.namespace("job7")
        kv.namespace("another")
        for key in ("tr-leaf-0", "gemm-C-1-2", "some/task"):
            base = kv._shard_index(key)
            assert kv._shard_index(f"job7::{key}") == base
            assert kv._shard_index(f"another::{key}") == base

    def test_placement_of_bare_keys_containing_separator_unchanged(self):
        import zlib

        # A direct store user whose own keys happen to contain "::" must
        # keep full-key placement: only REGISTERED namespace prefixes
        # are stripped, so 'layerA::out' and 'layerB::out' do not
        # collapse onto crc32('out')'s shard.
        kv = ShardedKVStore(n_shards=10)
        for key in ("layerA::out", "layerB::out"):
            assert kv._shard_index(key) == \
                zlib.crc32(key.encode()) % len(kv.shards)

    def test_per_view_stats_are_isolated(self):
        kv = ShardedKVStore(n_shards=4)
        a, b = kv.namespace("job0"), kv.namespace("job1")
        a.put("x", b"abcd")
        a.get("x")
        b.put("y", b"zz")
        assert a.stats.puts == 1 and a.stats.gets == 1
        assert a.stats.bytes_written == 4 and a.stats.bytes_read == 4
        assert b.stats.puts == 1 and b.stats.gets == 0
        # the parent store aggregates everything
        assert kv.stats.puts == 2 and kv.stats.gets == 1

    def test_counters_and_deposit_are_namespaced(self):
        kv = ShardedKVStore(n_shards=4)
        a, b = kv.namespace("job0"), kv.namespace("job1")
        a.register_counters({"c": 2})
        b.register_counters({"c": 2})
        assert a.increment_dependency("c", "e1") == 1
        assert b.counter_value("c") == 0
        count, missing = a.deposit_and_increment(
            "c", "e2", {"dep": 42}, expected=("other",))
        assert count == 2
        assert missing == ["other"]  # un-prefixed on the way out
        # the completing arrival skipped the write; the first arriver's
        # items went in under the view's names
        assert not a.exists("dep") or a.get("dep") == 42

    def test_pubsub_is_namespaced_and_unsubscribe_empties_channels(self):
        kv = ShardedKVStore(n_shards=2)
        a, b = kv.namespace("job0"), kv.namespace("job1")
        qa, qb = a.subscribe("results"), b.subscribe("results")
        a.publish("results", {"from": "a"})
        assert qa.get(timeout=0.1) == {"from": "a"}
        assert qb.empty()
        assert kv.subscriber_count() == 2
        a.unsubscribe("results", qa)
        b.unsubscribe("results", qb)
        assert kv.subscriber_count() == 0
        assert kv._channels == {}

    def test_unsubscribe_is_idempotent(self):
        kv = ShardedKVStore(n_shards=2)
        q = kv.subscribe("ch")
        kv.unsubscribe("ch", q)
        kv.unsubscribe("ch", q)          # second release: no-op
        kv.unsubscribe("never", object())  # unknown channel: no-op
        assert kv._channels == {}

    def test_subscriber_count_is_view_scoped(self):
        kv = ShardedKVStore(n_shards=2)
        a, b = kv.namespace("job0"), kv.namespace("job1")
        qb = b.subscribe("results")
        # job0 leaked nothing: its view must report zero even while
        # job1 holds a live subscription on the shared store
        assert a.subscriber_count() == 0
        assert b.subscriber_count() == 1
        assert kv.subscriber_count() == 1
        b.unsubscribe("results", qb)

    def test_purge_reclaims_namespaced_state(self):
        kv = ShardedKVStore(n_shards=4)
        a, b = kv.namespace("job0"), kv.namespace("job1")
        a.put("x", b"abcd")
        a.register_counters({"c": 2})
        a.increment_dependency("c", "e1")
        b.put("x", b"keep")
        removed = a.purge()
        assert removed == 1
        assert not a.exists("x")
        assert a.counter_value("c") == 0
        assert b.get("x") == b"keep"  # other jobs untouched
        assert sum(len(s.data) for s in kv.shards) == 1

    def test_publish_stops_fanning_to_dead_subscribers(self):
        kv = ShardedKVStore(n_shards=2)
        dead = kv.subscribe("ch")
        live = kv.subscribe("ch")
        kv.unsubscribe("ch", dead)
        kv.publish("ch", "msg")
        assert live.get(timeout=0.1) == "msg"
        assert dead.empty()


# ---------------------------------------------------------------------------
# Substrate reuse
# ---------------------------------------------------------------------------


class TestSubstrateReuse:
    def test_back_to_back_computes_on_one_engine_bit_identical(self):
        engine = WukongEngine(_engine_cfg())
        dag = tree_reduction_dag(32, compute_ms=25.0)
        r1 = engine.compute(dag)
        r2 = engine.compute(dag)
        (k1, v1), = r1.results.items()
        (k2, v2), = r2.results.items()
        assert k1 == k2 and float(v1[0]) == float(v2[0])
        assert r1.wall_s == r2.wall_s
        assert r1.charged_ms == r2.charged_ms
        assert r1.kv_stats == r2.kv_stats
        assert r1.metrics == r2.metrics
        assert r1.executors_invoked == r2.executors_invoked

    def test_sequential_jobs_on_shared_substrate_report_identically(self):
        cfg = _engine_cfg()
        substrate = Substrate(cfg, None)
        dag = tree_reduction_dag(32, compute_ms=25.0)
        reports = []
        with substrate.clock.actor():
            for i in range(3):
                sub = substrate.job_substrate(f"job{i}", "tenant-x")
                reports.append(WukongEngine(cfg).compute(dag, substrate=sub))
        n1, n2, n3 = (_normalize(r) for r in reports)
        assert n1 == n2 == n3
        assert n1["results"] == {
            "tr-3-0": tree_reduction_expected(32)}
        # teardown left the shared store clean: no leaked subscriptions
        assert substrate.kv.subscriber_count() == 0
        assert substrate.kv._channels == {}

    def test_worker_cache_stays_bounded(self):
        import repro.core.simclock as sc

        cfg = _engine_cfg()
        substrate = Substrate(cfg, None)
        dag = tree_reduction_dag(16, compute_ms=5.0)
        with substrate.clock.actor():
            for i in range(5):
                sub = substrate.job_substrate(f"job{i}", "tenant-x")
                WukongEngine(cfg).compute(dag, substrate=sub)
        assert len(sc._worker_cache) <= sc._WORKER_CACHE_MAX

    def test_shared_platform_carries_warmth_across_jobs(self):
        cfg = _engine_cfg(cost=CostModel(cold_start_ms=250.0))
        substrate = Substrate(cfg, PlatformConfig(keep_alive_s=600.0),
                              tenants=(TenantSpec("t", 1792),))
        dag = tree_reduction_dag(16, compute_ms=5.0)
        with substrate.clock.actor():
            sub0 = substrate.job_substrate("job0", "t")
            WukongEngine(cfg).compute(dag, substrate=sub0)
            cold_after_first = substrate.platform.pool.cold_starts
            sub1 = substrate.job_substrate("job1", "t")
            WukongEngine(cfg).compute(dag, substrate=sub1)
        # the second job found the first job's containers warm: no (or
        # almost no) additional cold starts
        assert substrate.platform.pool.cold_starts == cold_after_first
        assert substrate.platform.pool.warm_reuses > 0

    def test_prewarm_applies_to_tenant_functions(self):
        cfg = _engine_cfg(cost=CostModel(cold_start_ms=250.0))
        substrate = Substrate(
            cfg, PlatformConfig(keep_alive_s=600.0, prewarm=32),
            tenants=(TenantSpec("t-a", 1792), TenantSpec("t-b", 896)))
        dag = tree_reduction_dag(16, compute_ms=5.0)
        with substrate.clock.actor():
            WukongEngine(cfg).compute(
                dag, substrate=substrate.job_substrate("job0", "t-a"))
            WukongEngine(cfg).compute(
                dag, substrate=substrate.job_substrate("job1", "t-b"))
        # the prewarm knob warms each tenant's function, not just the
        # default single-job function name
        assert substrate.platform.pool.cold_starts == 0
        assert substrate.platform.pool.warm_reuses > 0

    def test_tenants_never_share_containers(self):
        cfg = _engine_cfg(cost=CostModel(cold_start_ms=250.0))
        substrate = Substrate(
            cfg, PlatformConfig(keep_alive_s=600.0),
            tenants=(TenantSpec("t-a"), TenantSpec("t-b")))
        dag = tree_reduction_dag(16, compute_ms=5.0)
        with substrate.clock.actor():
            WukongEngine(cfg).compute(
                dag, substrate=substrate.job_substrate("job0", "t-a"))
            cold_a = substrate.platform.pool.cold_starts
            WukongEngine(cfg).compute(
                dag, substrate=substrate.job_substrate("job1", "t-b"))
        # tenant B's function has its own (empty) pool: it provisions
        # cold even though tenant A's warm containers are sitting idle
        assert substrate.platform.pool.cold_starts > cold_a


# ---------------------------------------------------------------------------
# Job cancellation (the second leak fix)
# ---------------------------------------------------------------------------


def _failing_fanin_dag(chain_len=50, compute_ms=100.0):
    """A fan-in whose left leaf fails instantly while the right arm is a
    long chain of slow tasks: the job errors out almost immediately with
    the chain executor still near its start."""
    g = GraphBuilder()

    def boom():
        raise RuntimeError("boom")

    def slow_leaf():
        simulated_compute(compute_ms)
        return 1.0

    def slow_id(x):
        simulated_compute(compute_ms)
        return x

    bad = g.add(boom, name="bad-leaf")
    node = g.add(slow_leaf, name="chain-leaf")
    for i in range(chain_len):
        node = g.add(slow_id, node, name=f"chain-{i}")
    g.add(lambda a, b: (a, b), bad, node, name="root")
    return g.build()


class TestJobCancellation:
    def test_failed_job_stops_consuming_shared_capacity(self):
        chain_len, compute_ms = 50, 100.0
        cfg = _engine_cfg()
        substrate = Substrate(cfg, PlatformConfig(keep_alive_s=600.0),
                              tenants=(TenantSpec("t", 1792),))
        clock = substrate.clock
        with clock.actor():
            sub = substrate.job_substrate("job0", "t")
            with pytest.raises(JobError):
                WukongEngine(cfg).compute(_failing_fanin_dag(chain_len,
                                                             compute_ms),
                                          substrate=sub)
            # Give leaked work a full simulated minute to show itself.
            clock.charge(60_000.0)
            snap1 = substrate.platform.snapshot()
            clock.charge(60_000.0)
            snap2 = substrate.platform.snapshot()
        # no executor activity after the cancelled job wound down:
        # billing and pool counters are frozen
        assert snap1 == snap2
        # every concurrency slot was handed back
        assert substrate.platform.throttle.active == 0
        # the chain executor stopped at a task boundary instead of
        # walking (and billing) the whole chain against the dead job
        full_walk_ms = chain_len * compute_ms
        assert snap1["billed_duration_ms"] < full_walk_ms / 2
        # and teardown released every subscription
        assert substrate.kv.subscriber_count() == 0

    def test_teardown_releases_reservations_of_queued_bodies(self):
        # A runtime pool of ONE worker forces invocations to queue up
        # already holding a concurrency slot + container (reserved by
        # the invoker lane before runtime_pool.submit). A job timeout
        # then tears the job down with those wrapped bodies still
        # queued; dropping them would leak the reservations into the
        # shared account forever — they must run their release path.
        # Cheap invokes + a single runtime worker pinned on a 10 s task:
        # the other 7 leaf invocations are all queued (reservations
        # held) when the 0.5 s job timeout fires.
        cfg = _engine_cfg(max_concurrency=1, job_timeout_s=0.5,
                          cost=CostModel(invoke_ms=1.0, cold_start_ms=0.0))
        substrate = Substrate(cfg, PlatformConfig(keep_alive_s=600.0),
                              tenants=(TenantSpec("t", 1792),))
        clock = substrate.clock
        dag = tree_reduction_dag(16, compute_ms=10_000.0)
        with clock.actor():
            sub = substrate.job_substrate("job0", "t")
            with pytest.raises(JobError):
                WukongEngine(cfg).compute(dag, substrate=sub)
            clock.charge(60_000.0)  # let the cancelled job wind down
        assert substrate.platform.throttle.active == 0
        assert substrate.kv.subscriber_count() == 0

    def test_failed_job_leaves_channels_empty_self_contained(self):
        cfg = _engine_cfg(cost=CostModel())
        engine = WukongEngine(cfg)
        with pytest.raises(JobError):
            engine.compute(_failing_fanin_dag(chain_len=4, compute_ms=1.0))
        # self-contained path: can't reach the private kv afterwards, but
        # the substrate path above asserts the channel table; here we
        # assert the job still fails fast and deterministically
        r = None
        try:
            engine.compute(_failing_fanin_dag(chain_len=4, compute_ms=1.0))
        except JobError as exc:
            r = str(exc)
        assert r and "bad-leaf" in r


# ---------------------------------------------------------------------------
# Defensive platform snapshots (satellite 3)
# ---------------------------------------------------------------------------


class TestPlatformStatsAliasing:
    def test_two_reports_on_one_platform_never_alias(self):
        cfg = _engine_cfg(cost=CostModel(cold_start_ms=250.0))
        substrate = Substrate(cfg, PlatformConfig(keep_alive_s=600.0),
                              tenants=(TenantSpec("t", 1792),))
        dag = tree_reduction_dag(16, compute_ms=5.0)
        with substrate.clock.actor():
            r1 = WukongEngine(cfg).compute(
                dag, substrate=substrate.job_substrate("job0", "t"))
            r2 = WukongEngine(cfg).compute(
                dag, substrate=substrate.job_substrate("job1", "t"))
        assert r1.platform_stats is not r2.platform_stats
        before = dict(r2.platform_stats)
        nested_before = {k: dict(v) for k, v in r2.platform_stats.items()
                         if isinstance(v, dict)}
        # vandalize report 1, including its nested per-tenant block
        r1.platform_stats["cold_starts"] = -999
        r1.platform_stats.clear()
        for v in nested_before.values():
            assert v  # sanity: the nested billing block exists
        assert r2.platform_stats == before
        for k, v in nested_before.items():
            assert r2.platform_stats[k] == v

    def test_snapshot_returns_fresh_structures(self):
        from repro.core.simclock import VirtualClock
        from repro.platform import FaaSPlatform

        platform = FaaSPlatform(PlatformConfig(), CostModel(),
                                VirtualClock())
        platform.configure_function("tenant-x", 896)
        platform.meter.add_invocation(10.0, memory_mb=896, key="tenant-x")
        s1, s2 = platform.snapshot(), platform.snapshot()
        assert s1 is not s2 and s1 == s2
        s1["billing_by_function"]["tenant-x"]["billed_usd"] = 1e9
        assert s2["billing_by_function"]["tenant-x"]["billed_usd"] != 1e9


# ---------------------------------------------------------------------------
# Workload generator
# ---------------------------------------------------------------------------


class TestWorkload:
    def test_deterministic_and_well_formed(self):
        cfg = WorkloadConfig(n_jobs=64, seed=7)
        jobs1, jobs2 = generate_workload(cfg), generate_workload(cfg)
        assert jobs1 == jobs2
        assert len(jobs1) == 64
        arrivals = [j.arrival_ms for j in jobs1]
        assert arrivals == sorted(arrivals)
        tenant_names = {t.name for t in cfg.tenants}
        for j in jobs1:
            assert j.tenant in tenant_names
            assert j.size in _SIZE_LADDERS[j.app]

    def test_seed_changes_the_stream(self):
        a = generate_workload(WorkloadConfig(n_jobs=16, seed=1))
        b = generate_workload(WorkloadConfig(n_jobs=16, seed=2))
        assert a != b

    def test_heavy_tail_prefers_small_sizes(self):
        jobs = generate_workload(WorkloadConfig(
            n_jobs=200, seed=3, app_mix=(("tree_reduction", 1.0),)))
        smallest = _SIZE_LADDERS["tree_reduction"][0]
        small = sum(1 for j in jobs if j.size == smallest)
        assert small > len(jobs) * 0.4  # ~55% expected at tail=0.45


# ---------------------------------------------------------------------------
# The orchestrator itself
# ---------------------------------------------------------------------------


class TestOrchestrator:
    def test_runs_workload_and_is_deterministic(self):
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(n_jobs=8),
                                 max_concurrent_jobs=4)
        r1 = JobOrchestrator(cfg).run()
        r2 = JobOrchestrator(cfg).run()
        assert r1.jobs == r1.completed == 8 and r1.failed == 0
        assert (r1.p50_s, r1.p95_s, r1.p99_s) == (r2.p50_s, r2.p95_s,
                                                  r2.p99_s)
        assert r1.billed_usd_total == r2.billed_usd_total
        assert r1.per_tenant == r2.per_tenant
        assert r1.job_records == r2.job_records
        assert r1.makespan_s > 0
        assert 0.0 <= r1.warm_share <= 1.0

    def test_rejects_engine_level_platform(self):
        with pytest.raises(ValueError):
            JobOrchestrator(OrchestratorConfig(
                engine=EngineConfig(platform=PlatformConfig())))

    def test_admission_gate_limits_running_jobs(self):
        # 6 jobs arriving at once through a 2-wide gate: completions must
        # overlap at most 2 at a time -> end times form >= 3 waves.
        jobs = [JobRequest(job_id=i, tenant="t", app="tree_reduction",
                           size=8, arrival_ms=0.0, compute_ms=10.0)
                for i in range(6)]
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(),
                                 max_concurrent_jobs=2)
        rep = JobOrchestrator(cfg).run(jobs)
        assert rep.completed == 6
        waits = sorted(r["queue_wait_s"] for r in rep.job_records)
        assert waits[0] == 0.0 and waits[-1] > 0.0  # later jobs queued

    def test_fair_admission_protects_light_tenant(self):
        # Tenant "heavy" floods 10 jobs at t=0; tenant "light" submits 2
        # shortly after. Through a 2-wide admission gate, fair admission
        # must admit light's jobs as soon as a slot frees; FIFO makes
        # them wait behind the whole flood.
        def jobs():
            out = [JobRequest(job_id=i, tenant="heavy",
                              app="tree_reduction", size=16,
                              arrival_ms=float(i), compute_ms=20.0)
                   for i in range(10)]
            out += [JobRequest(job_id=10 + i, tenant="light",
                               app="tree_reduction", size=16,
                               arrival_ms=20.0 + i, compute_ms=20.0)
                    for i in range(2)]
            return out

        def light_wait(fair):
            cfg = OrchestratorConfig(engine=_engine_cfg(),
                                     workload=_tr_workload(),
                                     max_concurrent_jobs=2,
                                     fair_admission=fair)
            rep = JobOrchestrator(cfg).run(jobs())
            assert rep.completed == 12
            waits = [r["queue_wait_s"] for r in rep.job_records
                     if r["tenant"] == "light"]
            return sum(waits) / len(waits)

        assert light_wait(True) < light_wait(False)

    def test_per_tenant_billing_isolation(self):
        wl = _tr_workload(n_jobs=10, tenants=(
            TenantSpec("t-big", 1792), TenantSpec("t-small", 896)))

        def run(isolated):
            cfg = OrchestratorConfig(engine=_engine_cfg(),
                                     workload=wl, max_concurrent_jobs=8,
                                     isolate_platform=isolated)
            return JobOrchestrator(cfg).run()

        shared, isolated = run(False), run(True)
        assert shared.completed == isolated.completed == 10
        # one account's per-tenant attribution == per-tenant private
        # platforms (billed duration is metered per invocation thread,
        # so shared-pool contention cannot leak across tenants)
        for tenant in shared.per_tenant:
            assert shared.per_tenant[tenant]["billed_usd"] == \
                pytest.approx(isolated.per_tenant[tenant]["billed_usd"],
                              rel=1e-12)
        # ...and the attribution is complete: tenant bills sum to the
        # account total
        assert sum(b["billed_usd"] for b in shared.per_tenant.values()) \
            == pytest.approx(shared.billed_usd_total, rel=1e-12)

    def test_shared_pool_beats_isolated_on_latency(self):
        wl = _tr_workload(n_jobs=12, rate=8.0)

        def run(isolated):
            cfg = OrchestratorConfig(
                engine=_engine_cfg(cost=CostModel(cold_start_ms=250.0)),
                workload=wl, max_concurrent_jobs=12,
                isolate_platform=isolated)
            return JobOrchestrator(cfg).run()

        shared, isolated = run(False), run(True)
        assert shared.warm_share > isolated.warm_share
        assert shared.p50_s < isolated.p50_s

    def test_failed_job_recorded_without_blocking_others(self):
        jobs = [JobRequest(job_id=0, tenant="t", app="tree_reduction",
                           size=16, arrival_ms=0.0, compute_ms=5.0),
                JobRequest(job_id=1, tenant="t", app="no-such-app",
                           size=16, arrival_ms=1.0, compute_ms=5.0),
                JobRequest(job_id=2, tenant="t", app="tree_reduction",
                           size=16, arrival_ms=2.0, compute_ms=5.0)]
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(),
                                 max_concurrent_jobs=2)
        rep = JobOrchestrator(cfg).run(jobs)
        assert rep.jobs == 3 and rep.completed == 2 and rep.failed == 1
        by_id = {r["job_id"]: r for r in rep.job_records}
        assert by_id[1]["error"] is not None
        assert by_id[0]["error"] is None and by_id[2]["error"] is None

    def test_store_memory_is_reclaimed_per_completed_job(self):
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(n_jobs=12),
                                 max_concurrent_jobs=3)
        orch = JobOrchestrator(cfg)
        rep = orch.run()
        assert rep.completed == 12
        kv = orch.last_substrate.kv
        # every completed job's namespace was purged: store memory is
        # O(concurrent jobs), not O(total traffic)
        assert sum(len(s.data) for s in kv.shards) == 0
        assert kv._counters == {} and kv._channels == {}

    def test_orchestrator_leaves_substrate_clean(self):
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(n_jobs=6),
                                 max_concurrent_jobs=3)
        orch = JobOrchestrator(cfg)
        rep = orch.run()
        assert rep.completed == 6
        # every job's waiter/proxy subscription was released: the job
        # records and per-tenant blocks exist, and nothing leaked into
        # the per-job channel table (asserted via a fresh run's store)
        substrate = Substrate(cfg.engine, None)
        with substrate.clock.actor():
            sub = substrate.job_substrate("probe", "t")
            WukongEngine(cfg.engine).compute(
                tree_reduction_dag(8, compute_ms=1.0), substrate=sub)
        assert substrate.kv._channels == {}

    def test_namespace_purged_when_job_dies_mid_flight(self):
        # A job whose every task attempt fails dies mid-flight with
        # executors still holding fan-in counters, channel subscriptions
        # and partial outputs in its namespace. The orchestrator's purge
        # must reclaim ALL of it: zero leaked keys, counters, channels.
        cfg = OrchestratorConfig(
            engine=_engine_cfg(faults=FaultConfig(task_failure_prob=1.0,
                                                  max_retries=1)),
            workload=_tr_workload(), max_concurrent_jobs=2)
        jobs = [JobRequest(job_id=i, tenant="t", app="tree_reduction",
                           size=16, arrival_ms=float(i), compute_ms=5.0)
                for i in range(3)]
        orch = JobOrchestrator(cfg)
        rep = orch.run(jobs)
        assert rep.failed == 3 and rep.completed == 0
        kv = orch.last_substrate.kv
        assert sum(len(s.data) for s in kv.shards) == 0
        assert kv._counters == {}
        assert kv._channels == {}


# ---------------------------------------------------------------------------
# Tenant tiers: priority admission, quotas, per-tier SLO accounting
# ---------------------------------------------------------------------------


class TestTenantTiers:
    def _jobs(self, spec):
        """spec: list of (tenant, arrival_ms); all jobs identical."""
        return [JobRequest(job_id=i, tenant=t, app="tree_reduction",
                           size=8, arrival_ms=at, compute_ms=10.0)
                for i, (t, at) in enumerate(spec)]

    def test_priority_admission_prefers_premium(self):
        # All jobs queued at t=0 behind a 1-wide gate: the premium
        # tenant's job must be admitted first despite arriving last in
        # job-id order, and the batch tenant's job last.
        tenants = (TenantSpec("std", 1024, tier="standard", priority=1),
                   TenantSpec("batch", 1024, tier="batch", priority=0),
                   TenantSpec("prem", 1024, tier="premium", priority=2))
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(tenants=tenants),
                                 max_concurrent_jobs=1)
        rep = JobOrchestrator(cfg).run(self._jobs(
            [("batch", 0.0), ("std", 0.0), ("prem", 0.0)]))
        assert rep.completed == 3
        order = [r["tenant"] for r in sorted(rep.job_records,
                                             key=lambda r: r["end_ms"])]
        assert order == ["prem", "std", "batch"]

    def test_per_tenant_quota_caps_concurrency(self):
        # Tenant "capped" may run at most 1 job at a time even though the
        # global gate is 4-wide: its 4 jobs must serialize (>= 4 waves),
        # while the uncapped tenant's jobs overlap freely.
        tenants = (TenantSpec("capped", 1024, max_concurrent_jobs=1),
                   TenantSpec("free", 1024))
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(tenants=tenants),
                                 max_concurrent_jobs=4)
        rep = JobOrchestrator(cfg).run(self._jobs(
            [("capped", 0.0)] * 4 + [("free", 0.0)] * 2))
        assert rep.completed == 6
        capped = sorted((r["admit_ms"], r["end_ms"])
                        for r in rep.job_records if r["tenant"] == "capped")
        for (_, prev_end), (next_admit, _) in zip(capped, capped[1:]):
            assert next_admit >= prev_end  # never two in flight
        # the quota never blocks the gate for the uncapped tenant: its
        # jobs are admitted in the first wave (waits are journaling-
        # scale milliseconds, not job-duration-scale serialization)
        free_waits = [r["queue_wait_s"] for r in rep.job_records
                      if r["tenant"] == "free"]
        capped_waits = sorted(r["queue_wait_s"] for r in rep.job_records
                              if r["tenant"] == "capped")
        assert max(free_waits) < 0.01
        assert capped_waits[-1] > max(free_waits)  # serialized behind quota

    def test_quota_does_not_deadlock_gate(self):
        # Only quota-blocked jobs queued: the admission loop must yield
        # (not spin or deadlock) until a slot frees.
        tenants = (TenantSpec("only", 1024, max_concurrent_jobs=1),)
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(tenants=tenants),
                                 max_concurrent_jobs=8)
        rep = JobOrchestrator(cfg).run(self._jobs([("only", 0.0)] * 3))
        assert rep.completed == 3

    def test_per_tier_report_block(self):
        tenants = (TenantSpec("std", 1792, tier="standard", priority=1,
                              slo_s=120.0),
                   TenantSpec("bat", 896, tier="batch", priority=0))
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(n_jobs=8,
                                                       tenants=tenants),
                                 max_concurrent_jobs=4)
        rep = JobOrchestrator(cfg).run()
        assert set(rep.per_tier) == {"standard", "batch"}
        for tier, block in rep.per_tier.items():
            assert block["jobs"] > 0 or block["failed"] > 0
            assert block["p50_s"] <= block["p95_s"] <= block["p99_s"]
        assert rep.per_tier["standard"]["slo_s"] == 120.0
        assert rep.per_tier["batch"]["slo_s"] is None
        assert rep.per_tier["batch"]["slo_violations"] == 0
        # tier billing is the sum of its tenants' bills
        assert rep.per_tier["standard"]["billed_usd"] == \
            pytest.approx(rep.per_tenant["std"]["billed_usd"], rel=1e-12)
        # per-tenant blocks now carry tier + tail percentiles
        assert rep.per_tenant["std"]["tier"] == "standard"
        assert "p95_s" in rep.per_tenant["std"]
        assert "p99_s" in rep.per_tenant["std"]

    def test_slo_violations_counted(self):
        # An absurdly tight SLO: every completed job violates it.
        tenants = (TenantSpec("tight", 1024, tier="rt", priority=1,
                              slo_s=1e-9),)
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(n_jobs=4,
                                                       tenants=tenants),
                                 max_concurrent_jobs=4)
        rep = JobOrchestrator(cfg).run()
        assert rep.per_tier["rt"]["slo_violations"] == rep.completed > 0


# ---------------------------------------------------------------------------
# Container-cache coherence (locality PR): purge reaches caches, and a
# recycled warm container never serves a stale bare key across jobs
# ---------------------------------------------------------------------------


def _deposit(cache, key, value="v", nbytes=8):
    for _ in cache.deposit_g(key, value, nbytes):
        pass


class TestContainerCacheCoherence:
    def test_drop_namespace_invalidates_container_cached_entries(self):
        cfg = _engine_cfg()
        substrate = Substrate(
            cfg, PlatformConfig(keep_alive_s=600.0, cache=CacheConfig()),
            tenants=(TenantSpec("t", 1792),))
        cache = substrate.platform.caches.cache_for("t", 1)
        k_dead = substrate.kv.namespace("job0").qualified_key("x")
        k_live = substrate.kv.namespace("job1").qualified_key("x")
        _deposit(cache, k_dead)
        _deposit(cache, k_live)
        # the shared-substrate purge listener reclaims job0's entry from
        # the container cache along with its KV objects
        substrate.kv.drop_namespace("job0")
        assert not cache.contains(k_dead)
        assert cache.contains(k_live)

    def test_recycled_warm_container_serves_no_stale_bare_key(self):
        # Two sequential jobs use the SAME bare task keys with different
        # values, on one shared platform with a long keep-alive — the
        # second job's fan-in completer re-fetches "left"/"right", and a
        # bare-keyed container cache would hand it the first job's
        # objects. Store-qualified cache keys (+ purge invalidation)
        # must keep the results exact.
        def dag_with(v):
            g = GraphBuilder()
            a = g.add((lambda x: (lambda: x))(v), name="left")
            b = g.add((lambda x: (lambda: x * 10))(v), name="right")
            g.add(lambda x, y: x + y, a, b, name="root")
            return g.build()

        cfg = _engine_cfg(cost=CostModel(cold_start_ms=250.0))
        substrate = Substrate(
            cfg, PlatformConfig(keep_alive_s=600.0, cache=CacheConfig()),
            tenants=(TenantSpec("t", 1792),))
        with substrate.clock.actor():
            sub0 = substrate.job_substrate("job0", "t")
            r0 = WukongEngine(cfg).compute(dag_with(1), substrate=sub0)
            sub0.kv.purge()  # what the orchestrator does on completion
            sub1 = substrate.job_substrate("job1", "t")
            r1 = WukongEngine(cfg).compute(dag_with(2), substrate=sub1)
        assert r0.results == {"root": 11}
        assert r1.results == {"root": 22}  # never 11, 12, or 21
        # and the purge reclaimed job0's entries from every cache
        reg = substrate.platform.caches
        prefix = substrate.kv.namespace("job0").qualified_key("")
        assert reg.invalidate_prefix(prefix) == 0  # nothing left to drop

    def test_orchestrator_reports_cache_and_stays_deterministic(self):
        cfg = OrchestratorConfig(
            engine=_engine_cfg(),
            platform=PlatformConfig(keep_alive_s=600.0,
                                    cache=CacheConfig()),
            workload=_tr_workload(n_jobs=6), max_concurrent_jobs=4)
        r1 = JobOrchestrator(cfg).run()
        r2 = JobOrchestrator(cfg).run()
        assert r1.completed == 6 and r1.failed == 0
        assert r1.cache and r1.cache["deposits"] > 0
        assert r1.cache == r2.cache
        assert r1.job_records == r2.job_records

    def test_cacheless_orchestrator_report_has_empty_cache_block(self):
        cfg = OrchestratorConfig(engine=_engine_cfg(),
                                 workload=_tr_workload(n_jobs=4),
                                 max_concurrent_jobs=4)
        rep = JobOrchestrator(cfg).run()
        assert rep.completed == 4
        assert rep.cache == {}
