"""Tests for the determinism sanitizer and static analysis
(``repro.analysis``): every lint rule catches its seeded fixture
violation at the expected line, the real source tree is clean under the
shipped baseline, the unified dagcheck pass rejects seeded structural
corruption, and ``diff_traces`` pinpoints injected nondeterminism.
"""
import dataclasses
import random
from pathlib import Path

import pytest

from repro.analysis import (
    ConsistencyError,
    CycleError,
    ExpansionError,
    Tracer,
    check_compiled,
    check_expansion,
    check_fan_in_counters,
    check_schedule_set,
    diff_traces,
    lint_file,
    load_baseline,
    new_findings,
    verify_dag,
)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.dagcheck import fan_in_counter_id, toposort
from repro.analysis.divergence import TraceEvent
from repro.analysis.effects import lint_source, lint_tree
from repro.core.dag import DAG, DynamicDAG, Expansion, Task, TaskRef
from repro.core.optimize import compile_dag
from repro.core.schedule import generate_static_schedules
from repro.core.simclock import EventClock, VirtualClock

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO = Path(__file__).parent.parent


def mark_line(name: str, mark: str) -> int:
    """1-indexed line of the ``MARK:<mark>`` sentinel in a fixture."""
    text = (FIXTURES / name).read_text().splitlines()
    for i, line in enumerate(text, 1):
        if f"MARK:{mark}" in line:
            return i
    raise AssertionError(f"no MARK:{mark} in {name}")


def rule_lines(name: str, rule: str) -> set:
    return {f.line for f in lint_file(FIXTURES / name, FIXTURES)
            if f.rule == rule}


# ---------------------------------------------------------------------------
# Lint rules, one seeded fixture violation each (file:line asserted)
# ---------------------------------------------------------------------------


def test_wallclock_rule_flags_each_call_form():
    lines = rule_lines("bad_wallclock.py", "REPRO001")
    for mark in ("time-time", "perf-counter", "datetime-now",
                 "from-import-monotonic"):
        assert mark_line("bad_wallclock.py", mark) in lines, mark


def test_wallclock_pragma_suppresses_site():
    lines = rule_lines("bad_wallclock.py", "REPRO001")
    assert mark_line("bad_wallclock.py", "pragma-ok") not in lines


def test_random_rule_flags_global_and_unseeded():
    lines = rule_lines("bad_random.py", "REPRO002")
    for mark in ("global-random", "from-import-shuffle", "unseeded-ctor"):
        assert mark_line("bad_random.py", mark) in lines, mark
    assert mark_line("bad_random.py", "seeded-ok") not in lines


def test_mutation_after_yield_rule():
    lines = rule_lines("bad_generator.py", "REPRO010")
    assert mark_line("bad_generator.py", "post-yield-mutation") in lines
    # not: pre-yield mutation, effect-lane-held mutation, or any
    # mutation in a frame-confined (lock-free) class
    for mark in ("pre-yield-ok", "lane-held-ok", "frame-local-ok"):
        assert mark_line("bad_generator.py", mark) not in lines, mark


def test_lock_across_yield_rule():
    lines = rule_lines("bad_generator.py", "REPRO011")
    assert lines == {mark_line("bad_generator.py", "lock-across-yield")}


def test_blocking_kv_in_generator_rule():
    lines = rule_lines("bad_generator.py", "REPRO012")
    assert lines == {mark_line("bad_generator.py", "blocking-kv")}


def test_task_clock_without_flush_rule():
    lines = rule_lines("bad_generator.py", "REPRO013")
    assert lines == {mark_line("bad_generator.py", "task-clock-no-flush")}


def test_key_hygiene_rules():
    assert mark_line("bad_keys.py", "namespace-literal") in \
        rule_lines("bad_keys.py", "REPRO020")
    assert rule_lines("bad_keys.py", "REPRO021") == \
        {mark_line("bad_keys.py", "builtin-hash")}
    assert mark_line("bad_keys.py", "crc32-ok") not in \
        rule_lines("bad_keys.py", "REPRO021")


def test_clean_actor_fixture_has_no_findings():
    assert lint_file(FIXTURES / "good_actor.py", FIXTURES) == []


def test_findings_carry_snippet_and_str():
    f = [x for x in lint_file(FIXTURES / "bad_keys.py", FIXTURES)
         if x.rule == "REPRO021"][0]
    assert "hash(key)" in f.snippet
    assert f"bad_keys.py:{f.line}" in str(f)


def test_substrate_file_is_exempt_from_wallclock_rule():
    src = "import time\n\ndef now() -> float:\n    return time.time()\n"
    assert any(f.rule == "REPRO001"
               for f in lint_source(src, "repro/core/other.py"))
    assert not any(f.rule == "REPRO001"
                   for f in lint_source(src, "repro/core/simclock.py"))


def test_jax_side_dirs_exempt_from_determinism_rules():
    src = "import time\nT0 = time.time()\nKEY = 'a::b'\n"
    findings = lint_source(src, "repro/runtime/train_loop.py")
    assert not any(f.rule == "REPRO001" for f in findings)
    # key hygiene still applies everywhere
    assert any(f.rule == "REPRO020" for f in findings)


def test_real_source_tree_clean_under_shipped_baseline():
    findings = lint_tree(REPO / "src")
    baseline = load_baseline(REPO / "analysis-baseline.json")
    fresh = new_findings(findings, baseline)
    assert fresh == [], "\n".join(str(f) for f in fresh)


def test_cli_gate_and_baseline_roundtrip(tmp_path, capsys):
    baseline = tmp_path / "baseline.json"
    argv = ["--check", str(FIXTURES), "--baseline", str(baseline)]
    assert analysis_main(argv) == 1  # seeded violations, empty baseline
    capsys.readouterr()
    assert analysis_main(argv + ["--write-baseline"]) == 0
    capsys.readouterr()
    assert analysis_main(argv) == 0  # grandfathered now
    capsys.readouterr()
    assert analysis_main(["--check", str(tmp_path / "nope")]) == 2
    assert analysis_main(["--explain"]) == 0


# ---------------------------------------------------------------------------
# Unified dagcheck pass
# ---------------------------------------------------------------------------


def _add(*xs):
    return sum(xs)


def _diamond() -> DAG:
    return DAG([
        Task("a", _add),
        Task("b", _add, (TaskRef("a"),)),
        Task("c", _add, (TaskRef("a"),)),
        Task("d", _add, (TaskRef("b"), TaskRef("c"))),
    ])


def test_verify_dag_accepts_built_graph():
    order = verify_dag(_diamond())
    assert set(order) == {"a", "b", "c", "d"}
    assert order.index("a") < order.index("d")


def test_verify_dag_catches_tampered_children():
    dag = _diamond()
    dag.children["a"].remove("b")  # corrupt the edge mirror
    with pytest.raises(ConsistencyError, match="dep edges missing"):
        verify_dag(dag)


def test_verify_dag_catches_tampered_leaves():
    dag = _diamond()
    dag.leaves = ("a", "b")
    with pytest.raises(ConsistencyError, match="leaves"):
        verify_dag(dag)


def test_toposort_raises_on_cycle():
    deps = {"x": ("y",), "y": ("x",)}
    children = {"x": ["y"], "y": ["x"]}
    with pytest.raises(CycleError, match="cycle"):
        toposort({"x": None, "y": None}, deps, children)


def test_check_expansion_rejects_collision_and_orphan():
    dag = DynamicDAG([Task("root", _add)])
    collide = Expansion(
        tasks=(Task("root", _add, (TaskRef("__expand_base__"),)),),
        final="root", value=1)
    with pytest.raises(ExpansionError, match="collide"):
        check_expansion(dag.tasks, "root", collide, "root/__base0__", 1, 8)
    orphan = Expansion(
        tasks=(Task("s0", _add, (TaskRef("__expand_base__"),)),
               Task("s1", _add)),
        final="s0", value=1)
    with pytest.raises(ExpansionError, match="never be triggered"):
        check_expansion(dag.tasks, "root", orphan, "root/__base0__", 1, 8)


def test_check_expansion_depth_cap():
    dag = DynamicDAG([Task("root", _add)])
    ok = Expansion(
        tasks=(Task("s0", _add, (TaskRef("__expand_base__"),)),),
        final="s0", value=1)
    with pytest.raises(ExpansionError, match="depth"):
        check_expansion(dag.tasks, "root", ok, "root/__base0__", 9, 8)


def test_fan_in_counter_check():
    dag = _diamond()
    good = {fan_in_counter_id("d"): 2}
    check_fan_in_counters(dag, good)
    with pytest.raises(ConsistencyError, match="width"):
        check_fan_in_counters(dag, {fan_in_counter_id("d"): 3})
    with pytest.raises(ConsistencyError, match="missing"):
        check_fan_in_counters(dag, {})
    with pytest.raises(ConsistencyError, match="non-fan-in"):
        check_fan_in_counters(
            dag, dict(good, **{fan_in_counter_id("b"): 1}))


def test_schedule_set_check_and_tampering():
    dag = _diamond()
    ss = generate_static_schedules(dag)
    check_schedule_set(ss)
    # drop an initial batch: the leaf is no longer covered exactly once
    tampered = dataclasses.replace(ss, batches=ss.batches[1:])
    with pytest.raises(ConsistencyError, match="covered by 0"):
        check_schedule_set(tampered)
    doubled = dataclasses.replace(ss, batches=ss.batches + ss.batches[:1])
    with pytest.raises(ConsistencyError, match="covered by 2"):
        check_schedule_set(doubled)


def test_compiled_dag_check_and_tampering():
    dag = _diamond()
    compiled = compile_dag(dag)  # runs check_compiled internally
    check_compiled(compiled)
    compiled.clusters["d"] = "not-a-task"
    with pytest.raises(ConsistencyError, match="non-task"):
        check_compiled(compiled)


def test_compiled_dag_leaf_batch_partition_check():
    compiled = compile_dag(_diamond())
    compiled.leaf_batches = compiled.leaf_batches + (("a",),)
    with pytest.raises(ConsistencyError, match="multiple leaf batches"):
        check_compiled(compiled)


# ---------------------------------------------------------------------------
# Runtime determinism sanitizer (trace mode + diff_traces)
# ---------------------------------------------------------------------------


def _traced_run(clock_cls, seed: int) -> Tracer:
    """One run of a job whose effect order depends on ``seed`` —
    standing in for an actor calling the *unseeded* global shuffle,
    which draws a different order every run."""
    clock = clock_cls()
    clock.tracer = Tracer()

    def actor():
        charges = [1.0, 2.0, 3.0, 4.0]
        random.Random(seed).shuffle(charges)
        for ms in charges:
            yield ("charge", ms)
        yield ("flush",)
        return sum(charges)

    assert clock.run(actor()) == 10.0
    return clock.tracer


def test_identical_runs_produce_identical_traces():
    assert diff_traces(_traced_run(EventClock, 7),
                       _traced_run(EventClock, 7)) is None


def test_cross_substrate_traces_match():
    assert diff_traces(_traced_run(EventClock, 7),
                       _traced_run(VirtualClock, 7)) is None


def test_diff_pinpoints_first_divergent_event_and_actor():
    div = diff_traces(_traced_run(EventClock, 7),
                      _traced_run(EventClock, 8))
    assert div is not None
    # the shuffled charge order splits at the very first charge
    assert div.index == 0
    assert div.left.effect == "charge" and div.right.effect == "charge"
    assert div.left.charge != div.right.charge
    assert div.left.actor.startswith("root#")
    desc = div.describe()
    assert "diverge" in desc and "charge" in desc


def test_diff_reports_truncated_trace():
    a = _traced_run(EventClock, 7)
    div = diff_traces(a, a.events[:-1])
    assert div is not None and div.right is None
    assert div.index == len(a.events) - 1


def test_diff_by_actor_tolerates_interleaving():
    def ev(seq, actor, charge):
        return TraceEvent(seq=seq, actor=actor, effect="charge",
                          charge=charge, src="x.py:1")

    a = [ev(0, "a#0", 1.0), ev(1, "b#1", 9.0), ev(2, "a#0", 2.0)]
    b = [ev(0, "b#1", 9.0), ev(1, "a#0", 1.0), ev(2, "a#0", 2.0)]
    assert diff_traces(a, b) is not None  # global order differs...
    # ...but per-actor sequences are identical (actors paired by
    # first-appearance order: a's [a#0, b#1] vs b's [b#1, a#0] pairs
    # a#0 with b#1 — use matching spawn order for a clean comparison)
    b_spawn_ordered = [ev(0, "a#0", 1.0), ev(1, "a#0", 2.0),
                       ev(2, "b#1", 9.0)]
    assert diff_traces(a, b_spawn_ordered, by_actor=True) is None
    # a per-actor divergence is attributed to the right actor
    b_bad = [ev(0, "a#0", 1.0), ev(1, "a#0", 5.0), ev(2, "b#1", 9.0)]
    div = diff_traces(a, b_bad, by_actor=True)
    assert div is not None and div.actor == "a#0" and div.index == 1
