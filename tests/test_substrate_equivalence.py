"""Event-driven vs thread-per-actor substrate equivalence (PR 6).

The tentpole property: swapping the simulation substrate changes ONLY
how actors are executed (continuations on the clock's ready queue vs
one OS thread per actor) — every simulated quantity is bit-identical.
``CostModel.substrate`` selects the mode ("event" is the default;
"thread" is the cross-check mode, the same role ``RealtimeClock``
plays for the virtual clock as a whole).

Also here: the EventClock primitive semantics (effect protocol), the
worker-cache drain hook, and the slow-marked 10^5-task scale test.
"""
import queue
import threading

import pytest

from repro.apps import tree_reduction_dag
from repro.apps.tree_reduction import tree_reduction_expected
from repro.core import (
    CostModel,
    EngineConfig,
    FaultConfig,
    JobOrchestrator,
    OrchestratorConfig,
    TenantSpec,
    WorkloadConfig,
    WukongEngine,
    drain_worker_cache,
    worker_cache_size,
)
from repro.core.simclock import EventClock, run_effects
from repro.platform import PlatformConfig

SUBSTRATES = ("event", "thread")


# ---------------------------------------------------------------------------
# EventClock primitives (the effect protocol)
# ---------------------------------------------------------------------------


class TestEventClockPrimitives:
    def test_charge_advances_and_run_returns(self):
        clock = EventClock()

        def main():
            yield ("charge", 250.0)
            yield ("charge", 125.0)
            return clock.now_ms()

        assert clock.run(main()) == 375.0
        assert clock.charged_ms == 375.0

    def test_sleepers_wake_in_deadline_order(self):
        clock = EventClock()
        wakes = []

        def sleeper(ms):
            def body():
                yield ("sleep", ms)
                wakes.append((ms, clock.now_ms()))
            return body

        for ms in (300.0, 100.0, 200.0):
            clock.spawn(sleeper(ms), name=f"s{ms}")

        def main():
            yield ("sleep", 400.0)

        clock.run(main())
        assert wakes == [(100.0, 100.0), (200.0, 200.0), (300.0, 300.0)]

    def test_queue_get_timeout_is_simulated(self):
        clock = EventClock()
        q = clock.queue()

        def main():
            try:
                yield ("get", q, 3600.0)  # one simulated hour
            except queue.Empty:
                return clock.now_ms()
            raise AssertionError("get should have timed out")

        assert clock.run(main()) == pytest.approx(3600e3)

    def test_queue_put_wakes_blocked_actor(self):
        clock = EventClock()
        q = clock.queue()
        got = []

        def consumer():
            got.append((yield ("get", q, 60.0)))

        clock.spawn(consumer, name="consumer")

        def main():
            yield ("charge", 5.0)  # let the consumer park first
            q.put("payload")
            yield ("charge", 1.0)

        clock.run(main())
        assert got == ["payload"]
        assert clock.now_ms() < 60e3  # woken by the put, not the timeout

    def test_lock_contention_charges_waiters_for_the_hold(self):
        clock = EventClock()
        lane = clock.lock()
        spans = []

        def transfer():
            yield ("acquire", lane)
            t0 = clock.now_ms()
            yield ("charge", 100.0)
            spans.append((t0, clock.now_ms()))
            lane.release()

        for _ in range(3):
            clock.spawn(transfer, name="t")

        def main():
            yield ("sleep", 1000.0)

        clock.run(main())
        assert spans == [(0.0, 100.0), (100.0, 200.0), (200.0, 300.0)]

    def test_event_wait_timeout_and_set(self):
        clock = EventClock()
        ev = clock.event()

        def main():
            flag = yield ("wait", ev, 0.5)  # simulated 500 ms
            assert flag is False
            assert clock.now_ms() == pytest.approx(500.0)
            ev.set()
            flag = yield ("wait", ev, 0.5)
            assert flag is True
            return clock.now_ms()

        assert clock.run(main()) == pytest.approx(500.0)  # no extra wait

    def test_flush_applies_deferred_direct_charges(self):
        # Non-yieldable code (simulated_compute inside task fns) calls
        # clock.charge() directly: billed immediately, time advance
        # deferred until the frame's next ("flush",).
        clock = EventClock()

        def main():
            clock.charge(42.0)
            assert clock.charged_ms == 42.0
            assert clock.now_ms() == 0.0  # not yet advanced
            yield ("flush",)
            return clock.now_ms()

        assert clock.run(main()) == 42.0

    def test_external_thread_drives_effects_blockingly(self):
        # run_effects is the bridge for code running on a real OS thread
        # (the same generator protocol, mapped onto blocking waits).
        clock = EventClock()
        q = clock.queue()
        out = []

        def external():
            def gen():
                out.append((yield ("get", q, 5.0)))
            run_effects(clock, gen())

        t = threading.Thread(target=external)
        t.start()

        def main():
            yield ("charge", 1.0)
            q.put(42)

        clock.run(main())
        t.join(timeout=5.0)
        assert out == [42]


# ---------------------------------------------------------------------------
# Worker-cache hygiene (pool workers parked between jobs)
# ---------------------------------------------------------------------------


class TestWorkerCache:
    def test_drain_resets_cache_between_runs(self):
        # Thread-substrate runs park finished pool workers in the
        # process-global cache; drain dispatches their shutdown sentinel
        # so benchmark iterations / test runs start cold.
        cfg = EngineConfig(cost=CostModel(substrate="thread"))
        rep = WukongEngine(cfg).compute(tree_reduction_dag(16))
        assert rep.tasks == 15
        assert worker_cache_size() > 0
        assert drain_worker_cache() > 0
        assert worker_cache_size() == 0
        assert drain_worker_cache() == 0  # idempotent
        # and the substrate still works after a drain
        rep = WukongEngine(cfg).compute(tree_reduction_dag(16))
        assert rep.tasks == 15
        drain_worker_cache()


# ---------------------------------------------------------------------------
# Substrate equivalence: identical simulated quantities
# ---------------------------------------------------------------------------


def _run(substrate: str, **cost_kw) -> "tuple":
    """The fig07-style smoke workload: latency jitter, cold starts,
    fault injection with retry backoff — every stochastic knob on."""
    cfg = EngineConfig(
        cost=CostModel(invoke_sigma=0.3, warm_fraction=0.7, latency_seed=7,
                       substrate=substrate, **cost_kw),
        faults=FaultConfig(task_failure_prob=0.04, max_retries=2, seed=21,
                           retry_backoff_base_ms=1000.0),
    )
    dag = tree_reduction_dag(64, compute_ms=250.0, payload_bytes=1 << 16)
    return WukongEngine(cfg).compute(dag)


class TestSubstrateEquivalence:
    def test_fig07_workload_bit_identical(self):
        reps = {s: _run(s) for s in SUBSTRATES}
        a, b = reps["event"], reps["thread"]
        (ka, va), = a.results.items()
        (kb, vb), = b.results.items()
        assert ka == kb and va[0] == vb[0] == tree_reduction_expected(64)
        assert a.charged_ms == b.charged_ms
        assert a.wall_s == b.wall_s
        assert a.kv_stats == b.kv_stats
        assert a.executors_invoked == b.executors_invoked

    def test_fig14_platform_workload_bit_identical(self):
        # The stateful-platform path: warm pool, throttle, billing meter
        # — platform_stats (incl. billed USD) must agree bit-for-bit.
        def run(substrate):
            cfg = EngineConfig(
                cost=CostModel(cold_start_ms=250.0, substrate=substrate),
                platform=PlatformConfig(keep_alive_s=600.0),
                num_initial_invokers=4, num_proxy_invokers=4,
            )
            return WukongEngine(cfg).compute(
                tree_reduction_dag(64, compute_ms=25.0))

        a, b = run("event"), run("thread")
        assert a.charged_ms == b.charged_ms
        assert a.wall_s == b.wall_s
        assert a.kv_stats == b.kv_stats
        assert a.platform_stats == b.platform_stats
        assert a.platform_stats["billed_usd"] > 0

    def test_orchestrator_workload_bit_identical(self):
        # N concurrent jobs on one shared clock/store/platform.
        def run(substrate):
            cfg = OrchestratorConfig(
                engine=EngineConfig(
                    cost=CostModel(substrate=substrate),
                    num_initial_invokers=4, num_proxy_invokers=4),
                workload=WorkloadConfig(
                    n_jobs=8, arrival_rate_per_s=4.0, seed=0,
                    tenants=(TenantSpec("t-a", 1792),
                             TenantSpec("t-b", 896)),
                    app_mix=(("tree_reduction", 1.0),), compute_ms=10.0),
                max_concurrent_jobs=4)
            return JobOrchestrator(cfg).run()

        a, b = run("event"), run("thread")
        assert a.completed == b.completed == 8 and a.failed == 0
        assert a.makespan_s == b.makespan_s
        assert a.billed_usd_total == b.billed_usd_total
        assert a.per_tenant == b.per_tenant
        assert a.job_records == b.job_records


# ---------------------------------------------------------------------------
# Scale: the event substrate carries 10^5 tasks in seconds
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestScale:
    def test_100k_task_tree_reduction_under_wall_budget(self):
        import time

        n = 131072  # 131071 tasks
        dag = tree_reduction_dag(n, compute_ms=1.0)
        cfg = EngineConfig(max_concurrency=n, job_timeout_s=1e6,
                           record_metrics=False)
        t0 = time.perf_counter()
        rep = WukongEngine(cfg).compute(dag)
        wall = time.perf_counter() - t0
        (_, v), = rep.results.items()
        assert v[0] == tree_reduction_expected(n)
        assert rep.tasks == n - 1
        assert rep.metrics == []  # record_metrics=False
        assert wall < 30.0
