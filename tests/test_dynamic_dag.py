"""Dynamic DAGs: runtime graph expansion (repro.core.dag.DynamicDAG).

The tentpole property: a DAG that grows at runtime (a task returns an
``Expansion`` instead of a value) charges EXACTLY what the statically
pre-expanded equivalent graph charges — same results, same charged_ms,
same KV traffic, on both simulation substrates. Plus the expansion
validation surface, iterate-until-converged chaining with the depth
cap, and the idempotent-replay path that makes duplicate execution of
an expanding task (crash resume) safe.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.apps import (
    dynamic_tree_reduction_dag,
    dynamic_tree_reduction_expected,
    static_tree_reduction_equivalent,
)
from repro.core import (
    EXPAND_BASE,
    CostModel,
    DynamicDAG,
    EngineConfig,
    Expansion,
    ExpansionError,
    Task,
    TaskRef,
    WukongEngine,
    expansion_base_key,
)

SUBSTRATES = ("event", "thread")


def _engine(substrate: str) -> WukongEngine:
    # schedule_ship_mbps=inf: expansion schedules are built after
    # dispatch, so static-schedule shipping is the one cost the dynamic
    # arm structurally cannot share with the pre-expanded equivalent.
    return WukongEngine(EngineConfig(
        cost=CostModel(substrate=substrate,
                       schedule_ship_mbps=float("inf")),
        num_initial_invokers=4, num_proxy_invokers=4,
        max_concurrency=512))


def _dyn() -> DynamicDAG:
    return DynamicDAG([
        Task("src", lambda: np.array([1.0]), ()),
        Task("out", lambda x: x, (TaskRef("src"),)),
    ])


def _sub(final: str = "b") -> "tuple[Task, ...]":
    return (
        Task("a", lambda v: v, (TaskRef(EXPAND_BASE),)),
        Task("b", lambda v: v, (TaskRef("a"),)),
    )[: (2 if final == "b" else 1)]


# ---------------------------------------------------------------------------
# Construction-time / expansion-time validation
# ---------------------------------------------------------------------------


class TestValidation:
    @pytest.mark.parametrize("depth", [0, -1, 1.5, True, "8"])
    def test_bad_max_expansion_depth(self, depth):
        with pytest.raises(ValueError, match="max_expansion_depth"):
            DynamicDAG([Task("t", lambda: 1, ())],
                       max_expansion_depth=depth)

    def test_unknown_key(self):
        with pytest.raises(ExpansionError, match="unknown task"):
            _dyn().apply_expansion(
                "nope", Expansion(1.0, _sub(), "b"))

    def test_empty_expansion(self):
        with pytest.raises(ExpansionError, match="empty"):
            _dyn().apply_expansion("out", Expansion(1.0, (), "b"))

    def test_duplicate_keys(self):
        dup = (Task("a", lambda v: v, (TaskRef(EXPAND_BASE),)),
               Task("a", lambda v: v, (TaskRef(EXPAND_BASE),)))
        with pytest.raises(ExpansionError, match="duplicate keys"):
            _dyn().apply_expansion("out", Expansion(1.0, dup, "a"))

    def test_final_not_in_tasks(self):
        with pytest.raises(ExpansionError, match="final"):
            _dyn().apply_expansion("out", Expansion(1.0, _sub(), "zzz"))

    def test_key_collision_with_existing_task(self):
        clash = (Task("src", lambda v: v, (TaskRef(EXPAND_BASE),)),)
        with pytest.raises(ExpansionError, match="collide"):
            _dyn().apply_expansion("out", Expansion(1.0, clash, "src"))

    def test_external_dependency_rejected(self):
        leaky = (Task("a", lambda v, w: v,
                      (TaskRef(EXPAND_BASE), TaskRef("src"))),)
        with pytest.raises(ExpansionError, match="self-contained"):
            _dyn().apply_expansion("out", Expansion(1.0, leaky, "a"))

    def test_dependency_on_final_rejected(self):
        bad = (Task("a", lambda v: v, (TaskRef("b"),)),
               Task("b", lambda v: v, (TaskRef(EXPAND_BASE),)))
        with pytest.raises(ExpansionError,
                           match="depends on the final"):
            _dyn().apply_expansion("out", Expansion(1.0, bad, "b"))

    def test_orphan_task_rejected(self):
        orphan = (Task("a", lambda v: v, (TaskRef(EXPAND_BASE),)),
                  Task("b", lambda: 1.0, ()))
        with pytest.raises(ExpansionError, match="never be triggered"):
            _dyn().apply_expansion("out", Expansion(1.0, orphan, "a"))

    def test_no_base_consumer_rejected(self):
        # No task reads EXPAND_BASE: the subgraph has no entry point.
        lone = (Task("a", lambda v: v, (TaskRef("b"),)),
                Task("b", lambda v: v, (TaskRef("a"),)),
                Task("z", lambda v: v, (TaskRef("a"),)))
        with pytest.raises(ExpansionError, match="EXPAND_BASE"):
            _dyn().apply_expansion("out", Expansion(1.0, lone, "z"))

    def test_cycle_rejected(self):
        cyc = (Task("e", lambda v: v, (TaskRef(EXPAND_BASE),)),
               Task("a", lambda v, w: v, (TaskRef("e"), TaskRef("b"))),
               Task("b", lambda v: v, (TaskRef("a"),)),
               Task("f", lambda v: v, (TaskRef("b"),)))
        with pytest.raises(ExpansionError, match="cycle"):
            _dyn().apply_expansion("out", Expansion(1.0, cyc, "f"))

    def test_dag_factory_validation(self):
        with pytest.raises(ValueError, match="power of two"):
            dynamic_tree_reduction_dag(6)
        with pytest.raises(ValueError, match="power of two"):
            dynamic_tree_reduction_dag(2)


# ---------------------------------------------------------------------------
# Expansion mechanics: delta shape, chaining, depth cap, replay
# ---------------------------------------------------------------------------


class TestExpansionMechanics:
    def test_delta_shape(self):
        dag = _dyn()
        delta = dag.apply_expansion("out", Expansion(7.0, _sub(), "b"))
        assert delta.key == "out"
        assert delta.base_key == expansion_base_key("out", 0)
        assert delta.value == 7.0
        assert delta.new_keys == ("a",)  # final excluded
        assert delta.topo[0] == delta.base_key
        assert delta.topo[-1] == "out"  # final re-bound under key
        assert not delta.replayed
        assert dag.expansions_applied == 1
        # the re-bound graph stays acyclic and topo-sortable
        order = dag.topological_order()
        assert order.index(delta.base_key) < order.index("a") \
            < order.index("out")

    def test_identical_replay_is_idempotent(self):
        # A duplicate execution (crash resume re-running the expanding
        # task with identical inputs) re-produces the same value and the
        # same subgraph: deduped, the graph does not grow twice.
        dag = _dyn()
        first = dag.apply_expansion("out", Expansion(7.0, _sub(), "b"))
        again = dag.apply_expansion("out", Expansion(7.0, _sub(), "b"))
        assert again.replayed
        assert again.base_key == first.base_key
        assert again.new_keys == first.new_keys
        assert dag.expansions_applied == 1

    def test_new_value_same_keys_is_not_a_replay(self):
        # Same subgraph shape but a NEW value is the next round of an
        # iteration, not a replay — and with multi-task subgraphs the
        # non-final key names must be fresh, so this one collides.
        dag = _dyn()
        dag.apply_expansion("out", Expansion(7.0, _sub(), "b"))
        with pytest.raises(ExpansionError, match="collide"):
            dag.apply_expansion("out", Expansion(9.0, _sub(), "b"))

    def test_depth_cap(self):
        dag = DynamicDAG([
            Task("src", lambda: 1.0, ()),
            Task("out", lambda x: x, (TaskRef("src"),)),
        ], max_expansion_depth=2)
        for i in range(2):
            dag.apply_expansion("out", Expansion(
                1.0,
                (Task(f"t{i}", lambda v: v, (TaskRef(EXPAND_BASE),)),),
                f"t{i}"))
        with pytest.raises(ExpansionError, match="depth"):
            dag.apply_expansion("out", Expansion(
                1.0,
                (Task("t9", lambda v: v, (TaskRef(EXPAND_BASE),)),),
                "t9"))


# ---------------------------------------------------------------------------
# Engine-level: iterate-until-converged + charged parity (hypothesis)
# ---------------------------------------------------------------------------


def _countdown_dag(rounds: int, depth_cap: int = 16) -> DynamicDAG:
    """Each expansion's final decrements and re-expands until zero —
    the iterate-until-converged shape (rounds chained expansions)."""

    def step(v):
        v = np.asarray(v, dtype=float) - 1.0
        if v[0] <= 0.0:
            return v
        return Expansion(value=v,
                         tasks=(Task("next", step,
                                     (TaskRef(EXPAND_BASE),)),),
                         final="next")

    return DynamicDAG([
        Task("seed", lambda: np.array([float(rounds)]), ()),
        Task("iter", step, (TaskRef("seed"),)),
    ], max_expansion_depth=depth_cap)


class TestEngineIntegration:
    @pytest.mark.parametrize("substrate", SUBSTRATES)
    def test_iterate_until_converged(self, substrate):
        rep = _engine(substrate).compute(_countdown_dag(4))
        (_, v), = rep.results.items()
        assert v[0] == 0.0
        # 1 seed + the initial iter + 3 re-expanded finals
        assert rep.tasks == 5

    def test_depth_cap_surfaces_as_job_error(self):
        from repro.core import JobError
        with pytest.raises(JobError, match="depth"):
            _engine("event").compute(_countdown_dag(6, depth_cap=2))

    @settings(max_examples=5, deadline=None)
    @given(n=st.sampled_from([4, 8, 16, 32]),
           compute_ms=st.sampled_from([0.0, 3.0]))
    def test_dynamic_matches_static_equivalent(self, n, compute_ms):
        """The PR's core parity property: data-dependent fan-out priced
        bit-identically to the pre-expanded graph, both substrates."""
        per_substrate = []
        for substrate in SUBSTRATES:
            dyn = _engine(substrate).compute(
                dynamic_tree_reduction_dag(n, compute_ms=compute_ms))
            sta = _engine(substrate).compute(
                static_tree_reduction_equivalent(
                    n, compute_ms=compute_ms))
            assert np.array_equal(np.asarray(dyn.results["reduce"]),
                                  np.asarray(sta.results["reduce"]))
            assert dyn.results["reduce"][0] \
                == dynamic_tree_reduction_expected(n)
            assert dyn.charged_ms == sta.charged_ms
            assert dyn.tasks == sta.tasks
            assert dyn.kv_stats == sta.kv_stats
            per_substrate.append((dyn.charged_ms, dyn.tasks,
                                  float(dyn.results["reduce"][0])))
        # and the whole parity tuple is substrate-invariant
        assert per_substrate[0] == per_substrate[1]
