"""REPRO002 fixture: unseeded randomness in actor code."""
import random
import zlib
from random import shuffle


def jitter() -> float:
    return random.random()  # MARK:global-random


def pick(xs: list) -> None:
    shuffle(xs)  # MARK:from-import-shuffle


def fresh_rng() -> "random.Random":
    return random.Random()  # MARK:unseeded-ctor


def seeded_rng(token: str) -> "random.Random":
    return random.Random(zlib.crc32(token.encode()))  # MARK:seeded-ok
