"""REPRO02x fixture: key-hygiene violations."""
import zlib


def shard_of(key: str, n: int) -> int:
    return hash(key) % n  # MARK:builtin-hash


def good_shard_of(key: str, n: int) -> int:
    return zlib.crc32(key.encode()) % n  # MARK:crc32-ok


def composed_key(job: str, task: str) -> str:
    return "jobs::" + job + "::" + task  # MARK:namespace-literal
