"""REPRO001 fixture: wall-clock reads in actor code (every sentinel
line is asserted by tests/test_analysis.py)."""
import time
from datetime import datetime
from time import monotonic


def elapsed_cost() -> float:
    start = time.time()  # MARK:time-time
    return time.perf_counter() - start  # MARK:perf-counter


def stamp() -> str:
    return datetime.now().isoformat()  # MARK:datetime-now


def tick() -> float:
    return monotonic()  # MARK:from-import-monotonic


def allowed_knob() -> None:
    time.sleep(0.0)  # lint: allow(REPRO001) — MARK:pragma-ok
