"""Negative fixture: idiomatic actor code — zero findings expected."""
import random
import threading
import zlib


class PlacementModel:
    """Shared (lock-owning) host whose generators stay disciplined."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.placed = 0

    def place_g(self, lane, kv, key):
        rng = random.Random(zlib.crc32(key.encode()))
        choice = rng.random()
        value = yield from kv.get_g(key)
        yield ("acquire", lane)
        self.placed += 1
        lane.release()
        yield ("charge", 1.0)
        return (choice, value)

    def reset(self) -> None:
        with self._lock:
            self.placed = 0
