"""REPRO01x fixture: ``*_g`` generator-discipline violations.

``SharedCounter`` owns a ``threading.Lock`` — the marker the linter
uses for "instances are shared across actors", which is what arms
REPRO010 for its ``*_g`` methods. ``FrameLocal`` has no lock: a
frame-confined host whose post-yield mutations are legitimate.
"""
import threading


class SharedCounter:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.count = 0
        self.log: list = []

    def bump_g(self):
        self.count = 0  # MARK:pre-yield-ok (before first yield)
        yield ("charge", 1.0)
        self.count += 1  # MARK:post-yield-mutation
        self.log.append(self.count)

    def locked_bump_g(self):
        yield ("charge", 1.0)
        with self._lock:  # MARK:lock-across-yield
            yield ("charge", 1.0)
            self.count += 1

    def lane_bump_g(self, lane):
        yield ("acquire", lane)
        self.count += 1  # MARK:lane-held-ok
        lane.release()

    def fetch_g(self, kv, key):
        yield ("charge", 1.0)
        return kv.get(key)  # MARK:blocking-kv

    def timed_g(self, task_clock, compute, fn):
        yield ("charge", 1.0)
        with task_clock(compute):  # MARK:task-clock-no-flush
            fn()
        return self.count


class FrameLocal:
    """No threading lock: one actor drives every generator."""

    def __init__(self) -> None:
        self.count = 0

    def bump_g(self):
        yield ("charge", 1.0)
        self.count += 1  # MARK:frame-local-ok
