"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int = 200, total: int = 10000,
                    min_frac: float = 0.1):
    step = jnp.asarray(step, dtype=jnp.float32)
    # step 0 is the FIRST step: lr must be nonzero ((step+1)/warmup)
    warm = (step + 1.0) / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)
