"""AdamW with global-norm clipping and optional gradient compression.

Optimizer moments are fp32 and inherit the parameter shardings (so under
FSDP rules they are ZeRO-sharded). ``grad_compress="bf16"`` casts
gradients to bf16 *before* the (implicit, XLA-inserted) cross-replica
reduction finishes propagating into the update math — on multi-pod meshes
this halves DCN all-reduce bytes, the classic large-scale trick.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compress: str | None = None  # None | "bf16"
    warmup: int = 200                 # schedule warmup steps


def adamw_init(params: Any) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def adamw_update(
    grads: Any, state: dict[str, Any], params: Any, cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    if cfg.grad_compress == "bf16":
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state["nu"], grads)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no weight decay on norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}, {
        "grad_norm": gnorm}
