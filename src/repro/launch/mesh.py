"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips (TPU v5e pod),
axes ("data", "model"). Multi-pod: 2 pods = 512 chips, axes
("pod", "data", "model") — the pod axis carries pure data parallelism
(DCN-friendly; only gradient all-reduces cross pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py sets this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~50 GB/s)
DCN_BW_PER_POD = 25e9           # bytes/s pod-to-pod (cross-pod DP traffic)
