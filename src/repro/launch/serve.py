"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Batched autoregressive decode with the cache machinery; request batches
are WUKONG DAG tasks (retry + concurrency from the engine). See
examples/serve_lm.py for the annotated version; this is the module entry
point the cluster runs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import EngineConfig, FaultConfig, GraphBuilder, WukongEngine
from repro.models import model as M
from repro.runtime.serve import build_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--full-width", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_width:
        cfg = reduced(cfg)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    serve_step = jax.jit(build_serve_step(cfg))
    max_len = args.prompt_len + args.gen_len

    def handle(rid: int):
        key = jax.random.PRNGKey(1000 + rid)
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)
        cache = M.init_cache(cfg, args.batch, max_len)
        tok = prompt[:, 0]
        t0 = time.time()
        n_gen = 0
        for pos in range(max_len - 1):
            logits, cache = serve_step(
                params, cache, {"token": tok, "pos": jnp.int32(pos)})
            if pos + 1 < args.prompt_len:
                tok = prompt[:, pos + 1]
            else:
                tok = jnp.argmax(logits, axis=-1)
                n_gen += 1
        return {"rid": rid, "tps": args.batch * n_gen / (time.time() - t0)}

    g = GraphBuilder()
    reqs = [g.add(lambda r=r: handle(r), name=f"req-{r}")
            for r in range(args.requests)]
    g.add(lambda *rs: float(np.mean([r["tps"] for r in rs])),
          *reqs, name="mean_tps")
    rep = WukongEngine(EngineConfig(
        faults=FaultConfig(task_failure_prob=0.0, max_retries=2),
        job_timeout_s=3600.0)).compute(g.build())
    print(f"arch={cfg.name} requests={args.requests} "
          f"mean decode throughput {rep.results['mean_tps']:.1f} tok/s")


if __name__ == "__main__":
    main()
