"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Wires the full stack together: data pipeline -> jitted train step ->
WUKONG-orchestrated workflow with retries and async checkpoints. On the
real cluster the same module runs per-host with ``--hosts/--host-id``
giving each host its disjoint data shard; in this container it runs the
reduced config on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import EngineConfig, FaultConfig
from repro.data import DataConfig, TokenPipeline
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import checkpoint as ckpt
from repro.runtime.orchestrator import (
    build_training_workflow,
    run_training_workflow,
)
from repro.runtime.train import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--fail-prob", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_width:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers * cfg.pattern_period)

    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, batch_per_host=args.batch,
        n_hosts=args.hosts, host_id=args.host_id, seed=13))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    jstep = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=args.lr, warmup=args.warmup)))

    os.makedirs(args.ckpt_dir, exist_ok=True)
    path = os.path.join(args.ckpt_dir, f"{cfg.name}.npz")
    losses: list[tuple[int, float]] = []

    def init_fn():
        if os.path.exists(path):
            like = jax.eval_shape(lambda: {"params": params, "opt": opt})
            st, step0 = ckpt.restore(path, like)
            print(f"[resume] checkpoint @ step {step0}")
            return (st["params"], st["opt"])
        return (params, opt)

    def data_fn(i: int):
        b = pipe.batch(step=i)  # idempotent under retry
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    def step_fn(state, batch):
        p, o = state
        p, o, m = jstep(p, o, batch)
        losses.append((int(o["count"]), float(m["loss"])))
        return (p, o), {"loss": float(m["loss"])}

    def checkpoint_fn(state, i):
        p, o = state
        ckpt.save(path, {"params": p, "opt": o}, step=i, async_=True)
        return i

    dag, final_key, mk = build_training_workflow(
        n_steps=args.steps, step_fn=step_fn, init_fn=init_fn,
        checkpoint_fn=checkpoint_fn, checkpoint_every=args.ckpt_every,
        data_fn=data_fn)
    t0 = time.time()
    run_training_workflow(
        dag, final_key, mk,
        EngineConfig(faults=FaultConfig(task_failure_prob=args.fail_prob,
                                        max_retries=2),
                     job_timeout_s=24 * 3600.0))
    dt = time.time() - t0
    losses.sort()
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s); "
          f"loss {losses[0][1]:.4f} -> {losses[-1][1]:.4f}")


if __name__ == "__main__":
    main()
