import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds abstract params / optimizer state / cache (ShapeDtypeStruct,
     no allocation),
  2. resolves shardings from the logical-axis rules,
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``
     on the production mesh (single-pod 16x16 and multi-pod 2x16x16),
  4. records memory_analysis, cost_analysis (HLO FLOPs/bytes), and the
     collective-bytes tally parsed from the optimized HLO
     (``compiled.as_text()`` — collectives only exist post-SPMD).

Results go to ``benchmarks/results/dryrun/*.json`` for the roofline
report. Any failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system.

Usage:
  python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--variant fsdp=0,...]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models.config import ModelConfig, SHAPES, applicable_shapes
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import serve as serve_lib
from repro.runtime import train as train_lib
from repro.runtime import sharding as sh

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum the sizes of all typed shapes in an HLO result declaration."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Bytes moved per collective kind, from optimized HLO.

    For each collective instruction we take the result-shape size (for
    all-gather that is the gathered output; for reduce-scatter the
    scattered output; a standard, conservative proxy for wire bytes).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k + "_count": 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # counted at -start
        out[kind] += _shape_bytes(shape_txt)
        counts[kind + "_count"] += 1
    out.update(counts)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Variant:
    """A sharding/step configuration under test (§Perf hillclimb knobs)."""
    fsdp: bool | None = None          # None = auto (>=10B params)
    shard_kv_seq: bool = True         # SP for decode caches
    expert_parallel: bool = True
    n_microbatches: int = 1
    remat: bool | None = None         # None = config default
    unroll_layers: bool = False       # exact HLO cost (roofline runs)
    tensor_parallel: bool = True      # False: replicate weights, go pure DP
    window: int | None = None         # override attention window (SWA)
    shard_logits: bool = False        # keep prefill logits vocab-sharded
    moe_group: int | None = None      # MoE dispatch group size override
    grad_compress: str | None = None  # "bf16": halve grad-reduce bytes
    tag: str = "baseline"


def _fsdp_auto(cfg: ModelConfig) -> bool:
    return cfg.param_counts()["total"] >= 10e9


def build_cell(cfg: ModelConfig, shape_name: str, mesh, variant: Variant):
    """Returns (jitted_fn, example_args, meta) ready to lower."""
    shape = SHAPES[shape_name]
    fsdp = variant.fsdp if variant.fsdp is not None else _fsdp_auto(cfg)
    if variant.remat is not None:
        cfg = dataclasses.replace(cfg, remat=variant.remat)
    if variant.unroll_layers:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if variant.window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=variant.window)
    if variant.moe_group is not None:
        cfg = dataclasses.replace(cfg, moe_group=variant.moe_group)
    rules = sh.rules_for(mesh, fsdp=fsdp,
                         shard_kv_seq=variant.shard_kv_seq,
                         expert_parallel=variant.expert_parallel,
                         tensor_parallel=variant.tensor_parallel)

    aparams = M.abstract_params(cfg)
    specs = M.model_specs(cfg)
    param_sh = sh.tree_shardings(aparams, specs, mesh, rules)

    if shape.kind == "train":
        aopt = jax.eval_shape(adamw_init, aparams)
        opt_specs = {"mu": specs, "nu": specs, "count": ()}
        opt_sh = sh.tree_shardings(aopt, opt_specs, mesh, rules)
        abatch = train_lib.synthetic_batch(
            cfg, shape.global_batch, shape.seq_len, abstract=True)
        batch_sh = jax.tree.map(
            lambda a: sh.batch_sharding(mesh, a.ndim, a.shape[0]), abatch)
        step = train_lib.build_train_step(
            cfg, AdamWConfig(grad_compress=variant.grad_compress),
            n_microbatches=variant.n_microbatches)
        fn = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        args = (aparams, aopt, abatch)
    elif shape.kind == "prefill":
        abatch = train_lib.synthetic_batch(
            cfg, shape.global_batch, shape.seq_len, abstract=True)
        batch_sh = jax.tree.map(
            lambda a: sh.batch_sharding(mesh, a.ndim, a.shape[0]), abatch)

        def prefill(params, batch):
            return M.forward(params, cfg, batch["tokens"],
                             batch.get("enc_embeds"))

        if variant.shard_logits:
            # keep prefill logits vocab-sharded (consumers — sampling,
            # loss — reduce over vocab anyway; gathering the full-vocab
            # logits tensor is a pure waste of interconnect)
            from jax.sharding import NamedSharding, PartitionSpec as P
            out_sh = NamedSharding(
                mesh, P(sh.batch_axes(mesh)
                        if shape.global_batch
                        % sh._axis_size(mesh, sh.batch_axes(mesh)) == 0
                        else None, None, "model"))
        else:
            out_sh = sh.batch_sharding(mesh, 3, shape.global_batch)
        fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                     out_shardings=out_sh)
        args = (aparams, abatch)
    else:  # decode
        acache = M.init_cache(cfg, shape.global_batch, shape.seq_len,
                              abstract=True)
        cache_specs = M.cache_specs(cfg)
        cache_sh = sh.tree_shardings(acache, cache_specs, mesh, rules)
        ainp = serve_lib.decode_inputs(cfg, shape.global_batch,
                                       shape.seq_len, abstract=True)
        inp_sh = {"token": sh.batch_sharding(mesh, 1, shape.global_batch),
                  "pos": sh.replicated(mesh)}
        step = serve_lib.build_serve_step(cfg)
        fn = jax.jit(step, in_shardings=(param_sh, cache_sh, inp_sh),
                     out_shardings=(sh.batch_sharding(
                         mesh, 2, shape.global_batch), cache_sh),
                     donate_argnums=(1,))
        args = (aparams, acache, ainp)
    meta = {"fsdp": fsdp, "variant": dataclasses.asdict(variant)}
    return fn, args, meta


def _cell_metrics(fn, args, mesh) -> dict:
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
    }


def _metric_diff(a: dict, b: dict) -> dict:
    out = {
        "flops": a["flops"] - b["flops"],
        "bytes_accessed": a["bytes_accessed"] - b["bytes_accessed"],
        "collective_bytes": {
            k: a["collective_bytes"][k] - b["collective_bytes"][k]
            for k in a["collective_bytes"]
        },
    }
    return out


def _metric_addmul(base: dict, body: dict, times: float) -> dict:
    return {
        "flops": base["flops"] + times * body["flops"],
        "bytes_accessed": base["bytes_accessed"]
        + times * body["bytes_accessed"],
        "collective_bytes": {
            k: base["collective_bytes"][k] + times * body["collective_bytes"][k]
            for k in base["collective_bytes"]
        },
    }


def depth_probe(cfg: ModelConfig, shape_name: str, mesh,
                variant: Variant) -> dict:
    """Exact per-device HLO cost, derived from compiled artifacts.

    XLA's cost analysis counts a ``while`` (scan) body once, so the
    full scanned model under-reports. We compile UNROLLED models at 1 and
    2 super-block repeats; the difference is the exact per-super-block
    cost and collective footprint, and
        total = outside + n_repeats * body
    reconstructs the full-depth numbers (for enc-dec, a third probe
    separates the encoder body). Inner *sequence* scans (Mamba chunk
    scan, sLSTM time scan) remain rolled here; benchmarks/roofline.py
    applies the documented analytic correction for those.
    """
    period = cfg.pattern_period
    pvariant = dataclasses.replace(variant, unroll_layers=True)

    def metrics_at(r_dec: int, r_enc: int) -> dict:
        c = dataclasses.replace(
            cfg, n_layers=period * r_dec,
            n_enc_layers=(r_enc if cfg.enc_dec else 0))
        fn, args, _ = build_cell(c, shape_name, mesh, pvariant)
        return _cell_metrics(fn, args, mesh)

    m11 = metrics_at(1, 1)
    m21 = metrics_at(2, 1)
    body_dec = _metric_diff(m21, m11)
    derived = _metric_addmul(m11, body_dec, cfg.n_repeats - 1)
    probes = {"r1": m11, "r2": m21, "body": body_dec}
    if cfg.enc_dec:
        m12 = metrics_at(1, 2)
        body_enc = _metric_diff(m12, m11)
        derived = _metric_addmul(derived, body_enc, cfg.n_enc_layers - 1)
        probes["body_enc"] = body_enc
    probes["derived"] = derived
    return probes


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: Variant | None = None, verbose: bool = True,
             save: bool = True, probe: bool = False) -> dict:
    variant = variant or Variant()
    cfg = get_config(arch)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips, "variant": variant.tag,
    }
    try:
        fn, args, meta = build_cell(cfg, shape_name, mesh, variant)
        record.update(meta)
        with mesh:
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        record.update({
            "ok": True,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "flops": float(cost.get("flops", -1)) if cost else -1.0,
            "bytes_accessed": float(cost.get("bytes accessed", -1))
            if cost else -1.0,
            "collective_bytes": coll,
            "memory_analysis": _mem_dict(mem),
            "hlo_bytes": len(hlo),
        })
        if probe:
            record["probe"] = depth_probe(cfg, shape_name, mesh, variant)
        if verbose:
            print(f"[OK] {arch} {shape_name} {record['mesh']} "
                  f"variant={variant.tag} "
                  f"lower {record['lower_s']}s compile {record['compile_s']}s")
            print(f"     memory_analysis: {record['memory_analysis']}")
            print(f"     cost_analysis: flops={record['flops']:.3e} "
                  f"bytes={record['bytes_accessed']:.3e}")
            print(f"     collectives: { {k: v for k, v in coll.items() if v} }")
            if probe:
                d = record["probe"]["derived"]
                print(f"     derived/device: flops={d['flops']:.3e} "
                      f"bytes={d['bytes_accessed']:.3e} "
                      f"coll={d['collective_bytes']['total']:.3e}")
    except Exception as exc:
        record.update({"ok": False, "error": repr(exc),
                       "traceback": traceback.format_exc()})
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {record['mesh']}: {exc!r}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fname = (f"{arch}__{shape_name}__{record['mesh']}"
                 f"__{variant.tag}.json")
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump(record, f, indent=2)
    return record


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def parse_variant(s: str) -> Variant:
    v = Variant(tag=s or "baseline")
    if not s or s == "baseline":
        return v
    kw: dict = {"tag": s}
    for part in s.split(","):
        k, _, val = part.partition("=")
        if k in ("fsdp", "shard_kv_seq", "expert_parallel", "remat",
                 "unroll_layers", "tensor_parallel", "shard_logits"):
            kw[k] = bool(int(val))
        elif k in ("n_microbatches", "window", "moe_group"):
            kw[k] = int(val)
        elif k == "grad_compress":
            kw[k] = val
    return Variant(**kw)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--probe", action="store_true",
                    help="depth-probe for exact per-device HLO cost")
    args = ap.parse_args()

    variant = parse_variant(args.variant)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else applicable_shapes(cfg))
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, variant, probe=args.probe)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
