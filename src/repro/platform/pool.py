"""Warm-container pool: the platform's container-reuse state machine.

An invocation of function F either reuses an idle warm container of F
(no cold start) or provisions a cold one. A container released by a
finishing invocation parks in the idle pool and expires ``keep_alive_s``
simulated seconds later — expiry is evaluated lazily against the engine
clock on the next acquire, so no reaper actor is needed and the pool
stays deterministic under the virtual clock.

Reuse is LIFO (most-recently-released container first), matching
observed FaaS behavior: a steady trickle of traffic keeps one hot
container alive while the rest of the fleet ages out.
"""
from __future__ import annotations

import threading

from repro.core.simclock import BaseClock

from repro.platform.config import PlatformConfig


class ContainerPool:
    """Per-function idle-container stacks keyed on the engine clock."""

    def __init__(self, config: PlatformConfig, clock: BaseClock):
        self.config = config
        self.clock = clock
        self._lock = threading.Lock()
        # function -> stack of (expiry_deadline_ms, container_id); LIFO
        # reuse means the top of the stack has the latest expiry, so
        # expired containers accumulate at the bottom.
        self._idle: dict[str, list[tuple[float, int]]] = {}
        self._next_id = 0
        self.cold_starts = 0
        self.warm_reuses = 0
        self.expired = 0

    def prewarm(self, function: str, n: int) -> None:
        """Provision ``n`` warm containers at the current clock time
        (the paper's §V-A pool warming). Prewarmed containers age out on
        the same keep-alive timer as any other idle container."""
        if n <= 0:
            return
        expiry = self.clock.now_ms() + self.config.keep_alive_s * 1e3
        with self._lock:
            stack = self._idle.setdefault(function, [])
            for _ in range(n):
                self._next_id += 1
                stack.append((expiry, self._next_id))

    def acquire(self, function: str) -> "tuple[int, bool]":
        """Assign a container for one invocation of ``function``.
        Returns ``(container_id, was_cold)``."""
        now = self.clock.now_ms()
        with self._lock:
            stack = self._idle.get(function)
            if stack:
                # Reap from the bottom: oldest releases expire first.
                while stack and stack[0][0] <= now:
                    stack.pop(0)
                    self.expired += 1
            if stack:
                _, cid = stack.pop()
                self.warm_reuses += 1
                return cid, False
            self._next_id += 1
            self.cold_starts += 1
            return self._next_id, True

    def release(self, function: str, container_id: int) -> None:
        """Return a container to the idle pool; it stays warm for
        ``keep_alive_s`` simulated seconds."""
        if self.config.keep_alive_s <= 0:
            return  # immediately reclaimed: every invocation is cold
        expiry = self.clock.now_ms() + self.config.keep_alive_s * 1e3
        with self._lock:
            self._idle.setdefault(function, []).append((expiry, container_id))

    def idle_count(self, function: str) -> int:
        now = self.clock.now_ms()
        with self._lock:
            stack = self._idle.get(function, [])
            return sum(1 for expiry, _ in stack if expiry > now)
