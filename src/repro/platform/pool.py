"""Warm-container pool: the platform's container-reuse state machine.

An invocation of function F either reuses an idle warm container of F
(no cold start) or provisions a cold one. A container released by a
finishing invocation parks in the idle pool and expires ``keep_alive_s``
simulated seconds later — expiry is evaluated lazily against the engine
clock on the next acquire, so no reaper actor is needed and the pool
stays deterministic under the virtual clock.

Reuse is LIFO (most-recently-released container first), matching
observed FaaS behavior: a steady trickle of traffic keeps one hot
container alive while the rest of the fleet ages out.
"""
from __future__ import annotations

import threading
from typing import Callable

from repro.core.simclock import BaseClock
from repro.platform.config import PlatformConfig


class ContainerPool:
    """Per-function idle-container stacks keyed on the engine clock."""

    def __init__(self, config: PlatformConfig, clock: BaseClock):
        self.config = config
        self.clock = clock
        self._lock = threading.Lock()
        # function -> stack of (expiry_deadline_ms, container_id); LIFO
        # reuse means the top of the stack has the latest expiry, so
        # expired containers accumulate at the bottom.
        self._idle: dict[str, list[tuple[float, int]]] = {}
        self._next_id = 0
        self.cold_starts = 0
        self.warm_reuses = 0
        self.expired = 0
        # Notified with (function, container_id) when a container is
        # reclaimed (keep-alive expiry, or zero keep-alive). The
        # platform points this at its cache registry so a container's
        # cache dies with the container.
        self.on_expire: "Callable[[str, int], None] | None" = None

    def prewarm(self, function: str, n: int) -> None:
        """Provision ``n`` warm containers at the current clock time
        (the paper's §V-A pool warming). Prewarmed containers age out on
        the same keep-alive timer as any other idle container."""
        if n <= 0:
            return
        expiry = self.clock.now_ms() + self.config.keep_alive_s * 1e3
        with self._lock:
            stack = self._idle.setdefault(function, [])
            for _ in range(n):
                self._next_id += 1
                stack.append((expiry, self._next_id))

    def acquire(self, function: str,
                score: "Callable[[int], int] | None" = None,
                ) -> "tuple[int, bool]":
        """Assign a container for one invocation of ``function``.
        Returns ``(container_id, was_cold)``.

        ``score`` is the locality hint: a host-side callable rating each
        idle container (e.g. bytes of the invocation's inputs resident
        in its cache). The highest-scoring live container is taken;
        ties keep the LIFO choice, so a zero-information score degrades
        exactly to the default reuse order."""
        now = self.clock.now_ms()
        with self._lock:
            stack = self._idle.get(function)
            if stack:
                # Reap from the bottom: oldest releases expire first.
                while stack and stack[0][0] <= now:
                    _, dead = stack.pop(0)
                    self.expired += 1
                    if self.on_expire is not None:
                        self.on_expire(function, dead)
            if stack:
                idx = len(stack) - 1
                if score is not None and len(stack) > 1:
                    # max() keeps the first maximum; the index tiebreak
                    # makes that the most recently released container.
                    idx = max(range(len(stack)),
                              key=lambda i: (score(stack[i][1]), i))
                _, cid = stack.pop(idx)
                self.warm_reuses += 1
                return cid, False
            self._next_id += 1
            self.cold_starts += 1
            return self._next_id, True

    def release(self, function: str, container_id: int) -> None:
        """Return a container to the idle pool; it stays warm for
        ``keep_alive_s`` simulated seconds."""
        if self.config.keep_alive_s <= 0:
            # Immediately reclaimed: every invocation is cold, and any
            # container-resident state (cache) is reclaimed with it.
            if self.on_expire is not None:
                self.on_expire(function, container_id)
            return
        expiry = self.clock.now_ms() + self.config.keep_alive_s * 1e3
        with self._lock:
            self._idle.setdefault(function, []).append((expiry, container_id))

    def idle_count(self, function: str) -> int:
        now = self.clock.now_ms()
        with self._lock:
            stack = self._idle.get(function, [])
            return sum(1 for expiry, _ in stack if expiry > now)
