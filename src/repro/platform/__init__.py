"""Stateful serverless-platform model (warm pool, throttling, billing).

Replaces the memoryless ``CostModel.warm_fraction`` coin flip with a
platform that has *state*: a warm-container pool with keep-alive expiry
on the engine clock, an account concurrency limit with a burst ramp
(429-style throttling retried with charged exponential backoff), and a
billing meter charging per-request fees plus GB-seconds — with the
memory size doubling as the compute-speed knob, so cost and latency
genuinely trade off (the ServerMix / Lambada economics the paper's
pay-per-use premise rests on).

Enable it by setting ``platform=PlatformConfig(...)`` on an engine
config; ``platform=None`` (the default) keeps the legacy stochastic
draw for cross-checks.
"""
from repro.platform.billing import BillingMeter
from repro.platform.config import PlatformConfig
from repro.platform.model import (
    DEFAULT_FUNCTION,
    ComputeScaledClock,
    FaaSPlatform,
)
from repro.platform.pool import ContainerPool
from repro.platform.throttle import ConcurrencyThrottle

__all__ = [
    "BillingMeter",
    "ComputeScaledClock",
    "ConcurrencyThrottle",
    "ContainerPool",
    "DEFAULT_FUNCTION",
    "FaaSPlatform",
    "PlatformConfig",
]
