"""FaaSPlatform: the stateful platform facade the invocation path uses.

Sits between the invoker lanes and the engine clock and combines the
three sub-models:

- ``ContainerPool``       — warm reuse vs cold provisioning, keep-alive
                            expiry on the engine clock;
- ``ConcurrencyThrottle`` — account cap with burst ramp, 429s retried
                            by the invoker lane with charged backoff;
- ``BillingMeter``        — per-request + GB-second charging of each
                            invocation's simulated execution time.

The invoker lane drives the protocol per invocation:

    while not platform.try_reserve():       # 429 + charged backoff
        clock.charge(platform.backoff_ms(attempt)); attempt += 1
    clock.charge(jittered invoke_ms)        # invoke API round trip
    cid, cold = platform.acquire(fn)        # pool decides cold/warm
    if cold: clock.charge(cold_start_ms)    # provisioning delay
    runtime_pool.submit(platform.wrap(fn, cid, body))

``wrap`` meters the body's simulated charges as billed duration and
releases the container + concurrency slot when the body finishes.

``compute_clock`` scales declared task compute by the memory knob
(CPU share is proportional to memory), which is what makes the
memory sweep a genuine cost-vs-latency trade-off.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.core.kvstore import CostModel
from repro.core.simclock import BaseClock, charge_meter

from repro.platform.billing import BillingMeter
from repro.platform.config import PlatformConfig
from repro.platform.pool import ContainerPool
from repro.platform.throttle import ConcurrencyThrottle

DEFAULT_FUNCTION = "executor"


class ComputeScaledClock:
    """Clock proxy multiplying charges by the memory-derived compute
    scale. Installed as the *task* clock around task-function calls, so
    workload-declared compute (``simulated_compute`` / per-flop costs)
    runs slower on smaller containers; engine-side latencies (KV,
    invoke) are unaffected."""

    def __init__(self, clock: BaseClock, scale: float):
        self._clock = clock
        self._scale = scale

    def charge(self, ms: float) -> None:
        self._clock.charge(ms * self._scale)

    def __getattr__(self, name: str) -> Any:
        return getattr(self._clock, name)


class FaaSPlatform:
    """One platform instance per job: every invoker pool of the job
    (initial + proxy invokers) shares it, so the concurrency cap is
    account-wide and the container pool is function-wide."""

    def __init__(self, config: PlatformConfig, cost: CostModel,
                 clock: BaseClock):
        self.config = config
        self.cost = cost
        self.clock = clock
        self.pool = ContainerPool(config, clock)
        self.throttle = ConcurrencyThrottle(config, clock)
        self.meter = BillingMeter(config)
        if config.prewarm > 0:
            self.pool.prewarm(DEFAULT_FUNCTION, config.prewarm)

    # -- invocation protocol (driven by the invoker lane) -------------------
    def try_reserve(self) -> bool:
        return self.throttle.try_reserve()

    def backoff_ms(self, attempt: int) -> float:
        return self.throttle.backoff_ms(attempt)

    def acquire(self, function: str = DEFAULT_FUNCTION) -> "tuple[int, bool]":
        return self.pool.acquire(function)

    def wrap(self, function: str, container_id: int,
             body: Callable[[], None]) -> Callable[[], None]:
        """Wrap an executor body: meter its simulated charges as billed
        duration, then return the container to the warm pool and free
        the concurrency slot."""

        def invocation() -> None:
            acc = [0.0]
            try:
                with charge_meter(acc):
                    body()
            finally:
                self.meter.add_invocation(acc[0])
                self.pool.release(function, container_id)
                self.throttle.release()

        return invocation

    def cancel(self, function: str, container_id: int) -> None:
        """Undo an acquire whose body never ran (runtime pool already
        shut down): free the slot, return the container unbilled."""
        self.pool.release(function, container_id)
        self.throttle.release()

    # -- compute scaling ----------------------------------------------------
    def compute_clock(self, clock: BaseClock) -> Any:
        scale = self.config.compute_scale
        if scale == 1.0:
            return clock
        return ComputeScaledClock(clock, scale)

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "mode": "pool",
            "memory_mb": self.config.memory_mb,
            "keep_alive_s": self.config.keep_alive_s,
            "cold_starts": self.pool.cold_starts,
            "warm_reuses": self.pool.warm_reuses,
            "containers_expired": self.pool.expired,
            "throttle_events": self.throttle.throttle_events,
            "peak_concurrency": self.throttle.peak_concurrency,
        }
        out.update(self.meter.snapshot())
        return out
