"""FaaSPlatform: the stateful platform facade the invocation path uses.

Sits between the invoker lanes and the engine clock and combines the
three sub-models:

- ``ContainerPool``       — warm reuse vs cold provisioning, keep-alive
                            expiry on the engine clock;
- ``ConcurrencyThrottle`` — account cap with burst ramp, 429s retried
                            by the invoker lane with charged backoff;
- ``BillingMeter``        — per-request + GB-second charging of each
                            invocation's simulated execution time.

The invoker lane drives the protocol per invocation:

    while not platform.try_reserve():       # 429 + charged backoff
        clock.charge(platform.backoff_ms(attempt)); attempt += 1
    clock.charge(jittered invoke_ms)        # invoke API round trip
    cid, cold = platform.acquire(fn)        # pool decides cold/warm
    if cold: clock.charge(cold_start_ms)    # provisioning delay
    runtime_pool.submit(platform.wrap(fn, cid, body))

``wrap`` meters the body's simulated charges as billed duration and
releases the container + concurrency slot when the body finishes.

``compute_clock`` scales declared task compute by the memory knob
(CPU share is proportional to memory), which is what makes the
memory sweep a genuine cost-vs-latency trade-off.
"""
from __future__ import annotations

from types import GeneratorType
from typing import Any, Callable

from repro.core.cache import CacheRegistry
from repro.core.kvstore import CostModel
from repro.core.simclock import BaseClock, charge_meter
from repro.platform.billing import BillingMeter
from repro.platform.config import PlatformConfig
from repro.platform.pool import ContainerPool
from repro.platform.throttle import ConcurrencyThrottle

DEFAULT_FUNCTION = "executor"


class ComputeScaledClock:
    """Clock proxy multiplying charges by the memory-derived compute
    scale. Installed as the *task* clock around task-function calls, so
    workload-declared compute (``simulated_compute`` / per-flop costs)
    runs slower on smaller containers; engine-side latencies (KV,
    invoke) are unaffected."""

    def __init__(self, clock: BaseClock, scale: float):
        self._clock = clock
        self._scale = scale

    def charge(self, ms: float) -> None:
        self._clock.charge(ms * self._scale)

    def __getattr__(self, name: str) -> Any:
        return getattr(self._clock, name)


class FaaSPlatform:
    """One platform instance per job: every invoker pool of the job
    (initial + proxy invokers) shares it, so the concurrency cap is
    account-wide and the container pool is function-wide."""

    def __init__(self, config: PlatformConfig, cost: CostModel,
                 clock: BaseClock):
        self.config = config
        self.cost = cost
        self.clock = clock
        self.pool = ContainerPool(config, clock)
        self.throttle = ConcurrencyThrottle(config, clock)
        self.meter = BillingMeter(config)
        # Container-resident multi-tier caches (repro.core.cache): the
        # pool decides container identity, the registry makes each
        # container's cache follow it — retained across warm reuses,
        # dropped on expiry (the pool's on_expire hook).
        self.caches: "CacheRegistry | None" = (
            CacheRegistry(config.cache) if config.cache is not None else None)
        if self.caches is not None:
            self.pool.on_expire = self.caches.drop
        # Per-function memory overrides (multi-tenant: one function per
        # tenant, each with its own memory size -> its own billing rate
        # and compute speed). Unregistered functions use the account
        # default ``config.memory_mb``.
        self._fn_memory: dict[str, int] = {}
        self._configured: set[str] = set()
        if config.prewarm > 0:
            self.pool.prewarm(DEFAULT_FUNCTION, config.prewarm)

    # -- multi-tenant function registry -------------------------------------
    def configure_function(self, function: str,
                           memory_mb: int | None = None) -> None:
        """Declare a deployed function (a tenant, under the
        orchestrator) with its own memory size. Warm containers are
        already pooled per function name, so tenants share the account
        cap and billing meter but never each other's containers.

        ``config.prewarm`` applies per deployed function: the pool is
        keyed by function name, so warming only the default function
        would leave every tenant's first invocations cold and the knob
        silently ineffective in multi-tenant runs."""
        if memory_mb is not None:
            if memory_mb <= 0:
                raise ValueError("memory_mb must be positive")
            self._fn_memory[function] = int(memory_mb)
        if (self.config.prewarm > 0 and function != DEFAULT_FUNCTION
                and function not in self._configured):
            # once per function: reconfiguring must not re-warm
            self.pool.prewarm(function, self.config.prewarm)
        self._configured.add(function)

    def memory_mb(self, function: str = DEFAULT_FUNCTION) -> int:
        return self._fn_memory.get(function, self.config.memory_mb)

    # -- invocation protocol (driven by the invoker lane) -------------------
    def try_reserve(self) -> bool:
        return self.throttle.try_reserve()

    def backoff_ms(self, attempt: int) -> float:
        return self.throttle.backoff_ms(attempt)

    def acquire(self, function: str = DEFAULT_FUNCTION,
                prefer_keys: "tuple[str, ...]" = ()) -> "tuple[int, bool]":
        """Assign a container. ``prefer_keys`` is the locality hint from
        the invoker: store-qualified keys the invocation will read —
        the pool then prefers the idle container already holding the
        most bytes of them (ties keep LIFO reuse)."""
        if self.caches is not None and prefer_keys:
            caches = self.caches

            def score(cid: int) -> int:
                return caches.resident_bytes(function, cid, prefer_keys)

            return self.pool.acquire(function, score=score)
        return self.pool.acquire(function)

    def wrap(self, function: str, container_id: int,
             body: Callable[[], None],
             job: str | None = None) -> Callable[[], None]:
        """Wrap an executor body: meter its simulated charges as billed
        duration, then return the container to the warm pool and free
        the concurrency slot. ``job`` is the billing-attribution label
        recorded with the invocation."""

        memory_mb = self.memory_mb(function)
        cache = (self.caches.cache_for(function, container_id)
                 if self.caches is not None else None)

        def invocation() -> None:
            acc = [0.0]
            try:
                with charge_meter(acc):
                    # Cache-aware bodies (the executor bodies) take the
                    # container's cache; plain bodies run unchanged.
                    if getattr(body, "accepts_cache", False):
                        body(cache)
                    else:
                        body()
            finally:
                self.meter.add_invocation(acc[0], memory_mb=memory_mb,
                                          key=function, job=job)
                self.pool.release(function, container_id)
                self.throttle.release()

        return invocation

    def wrap_g(self, function: str, container_id: int,
               body: Callable[[], Any],
               job: str | None = None) -> Callable[[], Any]:
        """Effect-protocol sibling of ``wrap``: the returned zero-arg
        callable is a generator function, so it composes with bodies
        that are themselves effect generators (the event substrate's
        executor bodies). Metering and release semantics are identical
        to ``wrap``."""

        memory_mb = self.memory_mb(function)
        cache = (self.caches.cache_for(function, container_id)
                 if self.caches is not None else None)

        def invocation():
            acc = [0.0]
            try:
                with charge_meter(acc):
                    if getattr(body, "accepts_cache", False):
                        r = body(cache)
                    else:
                        r = body()
                    if isinstance(r, GeneratorType):
                        yield from r
            finally:
                self.meter.add_invocation(acc[0], memory_mb=memory_mb,
                                          key=function, job=job)
                self.pool.release(function, container_id)
                self.throttle.release()

        return invocation

    def cancel(self, function: str, container_id: int) -> None:
        """Undo an acquire whose body never ran (runtime pool already
        shut down): free the slot, return the container unbilled."""
        self.pool.release(function, container_id)
        self.throttle.release()

    # -- compute scaling ----------------------------------------------------
    def compute_clock(self, clock: BaseClock,
                      function: str = DEFAULT_FUNCTION) -> Any:
        """Task clock for ``function``: CPU share proportional to ITS
        memory size (per-tenant under the orchestrator)."""
        scale = self.config.baseline_memory_mb / self.memory_mb(function)
        if scale == 1.0:
            return clock
        return ComputeScaledClock(clock, scale)

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        """Current platform counters. CONTRACT: the returned dict (and
        everything nested in it) is freshly built per call — callers
        (JobReports, the orchestrator) may extend or mutate it without
        aliasing any other snapshot or platform internals."""
        out: dict[str, Any] = {
            "mode": "pool",
            "memory_mb": self.config.memory_mb,
            "keep_alive_s": self.config.keep_alive_s,
            "cold_starts": self.pool.cold_starts,
            "warm_reuses": self.pool.warm_reuses,
            "containers_expired": self.pool.expired,
            "throttle_events": self.throttle.throttle_events,
            "peak_concurrency": self.throttle.peak_concurrency,
        }
        out.update(self.meter.snapshot())
        if self.caches is not None:
            # Account-wide locality counters (per-tier hits/misses/
            # evictions + residency), fresh dict per the contract above.
            out["cache"] = self.caches.snapshot()
        if self._fn_memory:
            # Multi-tenant deployments: the account bill broken down by
            # tenant function (fresh nested dicts, same aliasing contract).
            out["billing_by_function"] = self.meter.per_key_snapshot()
        return out
