"""Knobs of the stateful serverless-platform model.

Defaults follow AWS Lambda's published numbers where they exist:

- pricing: $0.20 per 1M requests and $0.0000166667 per GB-second of
  billed duration (x86, us-east-1), billed at 1 ms granularity;
- keep-alive: idle containers are reclaimed after minutes of
  inactivity (observed ~5-10 min for lightly-used functions);
- concurrency: a 1000-concurrent-executions account limit, reached
  from an initial burst allowance that ramps up over time (AWS
  documents a +500/min ramp above the regional burst limit);
- memory/CPU coupling: Lambda allocates CPU *proportionally to
  memory* — 1792 MB buys one full vCPU — so the memory size is also
  the compute-speed knob, which is exactly what makes cost and
  latency genuinely trade off (ServerMix's core observation).

Everything is expressed in *simulated* time/money so the model stays
deterministic under the virtual clock.
"""
from __future__ import annotations

import dataclasses

from repro.core.cache import CacheConfig


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    """Configuration of the stateful FaaS platform model.

    Setting ``platform=PlatformConfig(...)`` on an engine config
    replaces the memoryless ``CostModel.warm_fraction`` coin flip with
    the stateful warm-container pool (the legacy draw remains the
    behavior when ``platform is None``).
    """

    # -- warm-container pool ------------------------------------------------
    keep_alive_s: float = 600.0       # idle container lifetime (simulated s)
    prewarm: int = 0                  # containers warmed before the job
    #                                   (paper §V-A warms a Lambda pool)
    # Executor-local multi-tier cache (repro.core.cache): each container
    # keeps task outputs in modeled memory with disk spill, retained
    # across warm reuses and dropped on keep-alive expiry. None = the
    # cacheless data plane (every cross-executor edge pays the KV store).
    cache: CacheConfig | None = None

    # -- account concurrency + burst ramp -----------------------------------
    account_concurrency: int = 1000   # hard account-wide cap
    burst_concurrency: int = 500      # instantly available at t=0
    burst_ramp_per_min: float = 500.0  # additional slots granted per minute
    # Throttled (429) invocations retry with the charged exponential
    # backoff shared with faults.py (base * 2**attempt, capped).
    throttle_backoff_base_ms: float = 100.0
    throttle_backoff_cap_ms: float = 20_000.0

    # -- billing meter -------------------------------------------------------
    memory_mb: int = 1792             # billed memory size (also CPU share)
    baseline_memory_mb: int = 1792    # memory at which ms_per_flop-style
    #                                   compute declarations are calibrated
    price_per_request_usd: float = 0.20e-6
    price_per_gb_s_usd: float = 16.6667e-6
    billing_granularity_ms: float = 1.0

    def __post_init__(self) -> None:
        if self.memory_mb <= 0 or self.baseline_memory_mb <= 0:
            raise ValueError("memory sizes must be positive")
        if self.account_concurrency < 1 or self.burst_concurrency < 1:
            raise ValueError("concurrency limits must be >= 1")
        if self.billing_granularity_ms <= 0:
            raise ValueError("billing granularity must be positive")
        if self.throttle_backoff_base_ms <= 0:
            # A zero backoff would let a throttled invoker lane spin
            # without ever advancing the clock (virtual-mode livelock).
            raise ValueError("throttle backoff base must be positive")

    @property
    def compute_scale(self) -> float:
        """Multiplier on declared task-compute durations: CPU share is
        proportional to memory (1792 MB = one full vCPU), so half the
        memory runs compute twice as slow."""
        return self.baseline_memory_mb / self.memory_mb

    def billed_ms(self, duration_ms: float) -> float:
        """Round a raw duration up to the billing granularity."""
        gran = self.billing_granularity_ms
        units = -(-duration_ms // gran) if duration_ms > 0 else 0
        return units * gran

    def gb_s(self, billed_ms: float) -> float:
        return (self.memory_mb / 1024.0) * (billed_ms / 1e3)
