"""Account-level concurrency throttling with a burst ramp.

AWS rejects invocations beyond the account's concurrent-execution
limit with a 429 ``TooManyRequestsException``; the SDK retries with
exponential backoff. The *effective* limit is not flat: a fresh
account starts from a burst allowance and gains capacity over time
(documented as +500 concurrent executions per minute) until the
account cap is reached — which is what reshapes mega-fan-outs
(Lambada's observation: provider rate limits bound the usable width
of a serverless scan).

The throttle only admits/rejects; the *retry* (a charged exponential
backoff on the engine clock, shared with faults.py) is driven by the
invoker lane, so a throttled invocation delays the lane exactly like a
slow invoke API call would.
"""
from __future__ import annotations

import threading

from repro.core.faults import exponential_backoff_ms
from repro.core.simclock import BaseClock
from repro.platform.config import PlatformConfig


class ConcurrencyThrottle:
    """Tracks in-flight invocations against the time-ramped limit."""

    def __init__(self, config: PlatformConfig, clock: BaseClock):
        self.config = config
        self.clock = clock
        self._lock = threading.Lock()
        self.active = 0
        self.peak_concurrency = 0
        self.throttle_events = 0

    def limit_now(self) -> int:
        """Concurrency admitted at the current clock time: the burst
        allowance plus the ramp, capped by the account limit."""
        cfg = self.config
        ramped = cfg.burst_concurrency + int(
            cfg.burst_ramp_per_min * self.clock.now_ms() / 60_000.0
        )
        return min(cfg.account_concurrency, ramped)

    def try_reserve(self) -> bool:
        """Admit one invocation, or record a 429 and refuse."""
        limit = self.limit_now()
        with self._lock:
            if self.active >= limit:
                self.throttle_events += 1
                return False
            self.active += 1
            if self.active > self.peak_concurrency:
                self.peak_concurrency = self.active
            return True

    def release(self) -> None:
        with self._lock:
            self.active -= 1

    def backoff_ms(self, attempt: int) -> float:
        """Charged retry delay for the ``attempt``-th consecutive 429 —
        the same exponential schedule Lambda-retry uses in faults.py."""
        return exponential_backoff_ms(
            self.config.throttle_backoff_base_ms,
            attempt,
            cap_ms=self.config.throttle_backoff_cap_ms,
        )
