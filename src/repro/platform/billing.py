"""Billing meter: per-request fees plus GB-seconds of billed duration.

An invocation's billed duration is the simulated time its container
spends executing the function body — measured as the sum of simulated-
latency charges made by the invocation's thread (``charge_meter`` in
repro.core.simclock), NOT as a wall-clock delta. Charge sums are
identical in virtual and real-time clock modes (both modes charge the
same simulated amounts), so a job's billed cost is *bit-identical
across clock modes* — the cross-check tests rely on this. Like AWS,
the cold-start provisioning delay and the invoke API latency are not
billed as duration.

Multi-tenancy: each invocation is recorded against a ``key`` (the
platform function name — one per tenant under the orchestrator) with
that function's memory size, so one shared account meter can answer
"what does tenant T owe" (``per_key_snapshot``) as well as "what does
the account owe" (``snapshot``). Invocations additionally carry an
optional ``job`` label (the orchestrator passes the job's namespace
name), so ``per_job_snapshot`` can answer "what did job J cost" — the
attribution the durable control plane journals at job completion and
the crash-recovery tests audit against the uncrashed baseline.

Snapshots sum per-invocation GB-seconds in sorted record order so the
total is independent of the (thread-racy, in real-time mode) order in
which invocations complete.
"""
from __future__ import annotations

import threading

from repro.platform.config import PlatformConfig


class BillingMeter:
    def __init__(self, config: PlatformConfig):
        self.config = config
        self._lock = threading.Lock()
        # one (key, job, billed_ms, memory_mb) record per invocation
        self._records: list[tuple[str, str, float, int]] = []

    def add_invocation(self, duration_ms: float, memory_mb: int | None = None,
                       key: str = "executor",
                       job: str | None = None) -> float:
        """Record one finished invocation; returns its billed ms.
        ``memory_mb`` defaults to the account-wide config size (the
        platform passes the invoked function's own size); ``job`` is an
        optional attribution label for ``per_job_snapshot``."""
        billed = self.config.billed_ms(duration_ms)
        mem = int(memory_mb) if memory_mb else self.config.memory_mb
        with self._lock:
            self._records.append((key, job or "", billed, mem))
        return billed

    @staticmethod
    def _gb_s(billed_ms: float, memory_mb: int) -> float:
        return (memory_mb / 1024.0) * (billed_ms / 1e3)

    def _totals(self,
                records: "list[tuple[str, str, float, int]]",
                ) -> dict[str, float]:
        cfg = self.config
        total_ms = sum(ms for _, _, ms, _ in records)
        gb_s = sum(self._gb_s(ms, mem) for _, _, ms, mem in records)
        requests = len(records)
        usd = (requests * cfg.price_per_request_usd
               + gb_s * cfg.price_per_gb_s_usd)
        return {
            "billed_requests": requests,
            "billed_duration_ms": total_ms,
            "billed_gb_s": gb_s,
            "billed_usd": usd,
        }

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            records = sorted(self._records)
        return self._totals(records)

    def per_key_snapshot(self) -> "dict[str, dict[str, float]]":
        """Account totals broken down by billing key (tenant function):
        key -> the same block ``snapshot`` returns. Freshly built on
        every call — callers may mutate the result freely."""
        with self._lock:
            records = sorted(self._records)
        by_key: dict[str, list[tuple[str, str, float, int]]] = {}
        for rec in records:
            by_key.setdefault(rec[0], []).append(rec)
        return {key: self._totals(recs) for key, recs in by_key.items()}

    def per_job_snapshot(self) -> "dict[str, dict[str, float]]":
        """Account totals broken down by job label (invocations recorded
        without one are grouped under ``""``). Same freshness contract
        as ``per_key_snapshot``."""
        with self._lock:
            records = sorted(self._records)
        by_job: dict[str, list[tuple[str, str, float, int]]] = {}
        for rec in records:
            by_job.setdefault(rec[1], []).append(rec)
        return {job: self._totals(recs) for job, recs in by_job.items()}

    def job_snapshot(self, job: str) -> dict[str, float]:
        """One job's bill (zeroed block when the job never invoked)."""
        with self._lock:
            records = sorted(r for r in self._records if r[1] == job)
        return self._totals(records)
