"""Billing meter: per-request fees plus GB-seconds of billed duration.

An invocation's billed duration is the simulated time its container
spends executing the function body — measured as the sum of simulated-
latency charges made by the invocation's thread (``charge_meter`` in
repro.core.simclock), NOT as a wall-clock delta. Charge sums are
identical in virtual and real-time clock modes (both modes charge the
same simulated amounts), so a job's billed cost is *bit-identical
across clock modes* — the cross-check tests rely on this. Like AWS,
the cold-start provisioning delay and the invoke API latency are not
billed as duration.

The snapshot sums per-invocation GB-seconds in sorted order so the
total is independent of the (thread-racy, in real-time mode) order in
which invocations complete.
"""
from __future__ import annotations

import threading

from repro.platform.config import PlatformConfig


class BillingMeter:
    def __init__(self, config: PlatformConfig):
        self.config = config
        self._lock = threading.Lock()
        self._billed_ms: list[float] = []  # one entry per invocation

    def add_invocation(self, duration_ms: float) -> float:
        """Record one finished invocation; returns its billed ms."""
        billed = self.config.billed_ms(duration_ms)
        with self._lock:
            self._billed_ms.append(billed)
        return billed

    def snapshot(self) -> dict[str, float]:
        cfg = self.config
        with self._lock:
            billed = sorted(self._billed_ms)
        total_ms = sum(billed)
        gb_s = sum(cfg.gb_s(ms) for ms in billed)
        requests = len(billed)
        usd = (requests * cfg.price_per_request_usd
               + gb_s * cfg.price_per_gb_s_usd)
        return {
            "billed_requests": requests,
            "billed_duration_ms": total_ms,
            "billed_gb_s": gb_s,
            "billed_usd": usd,
        }
