"""SVD workloads (paper §V, Figs. 9 & 10).

SVD1 — tall-and-skinny SVD via the communication-avoiding TSQR algorithm
(the same algorithm Dask uses for ``da.linalg.svd`` on tall matrices):
block the rows, QR each block, reduce the R factors pairwise with stacked
QRs, SVD the final small R, then fan the right factor back out to form U.
The DAG is a reduction tree followed by a wide fan-out: exactly the shape
WUKONG's fan-in counters + proxy are built for.

SVD2 — rank-k randomized SVD of a square n x n matrix (Halko, Martinsson,
Tropp — the paper's citation [18]): Y = A @ Omega, QR(Y), B = Q^T A,
SVD(B). Blocked over row-blocks of A.

``ideal_storage=True`` reproduces the paper's §V-C "ideally-fast
intermediate storage" ablation: every input block is regenerated from its
seed instead of being read back from the KV store, which removes the
large-object KV traffic while keeping the DAG and compute identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import GraphBuilder
from repro.core.dag import DAG


@functools.partial(jax.jit, static_argnums=(2, 3))
def _row_block(seed, i, rows: int, cols: int) -> jax.Array:
    # i is traced: one executable for all row blocks of a given shape
    key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
    return jax.random.normal(key, (rows, cols), dtype=jnp.float32)


@jax.jit
def _qr_r(a: jax.Array) -> jax.Array:
    return jnp.linalg.qr(a, mode="r")


@jax.jit
def _stack_qr_r(r1: jax.Array, r2: jax.Array) -> jax.Array:
    return jnp.linalg.qr(jnp.concatenate([r1, r2], axis=0), mode="r")


@jax.jit
def _singular_values(r: jax.Array) -> jax.Array:
    return jnp.linalg.svd(r, compute_uv=False)


def _costed(fn, flops, sleep_per_flop, ms_per_flop=0.0):
    """Per-task compute cost from analytic FLOPs (see
    repro.apps.costing.flop_costed)."""
    from repro.apps.costing import flop_costed

    return flop_costed(fn, flops, sleep_per_flop, ms_per_flop)


def tsqr_svd_dag(
    rows: int,
    cols: int = 64,
    n_blocks: int = 8,
    seed: int = 3,
    compute_u: bool = True,
    sleep_per_flop: float = 0.0,
    ms_per_flop: float = 0.0,
) -> DAG:
    """SVD1: tall-and-skinny (rows >> cols) SVD via TSQR.

    ``ms_per_flop`` (simulated, clock-charged) / ``sleep_per_flop``
    (legacy real sleep) simulate compute duration per task from analytic
    FLOPs (single-core container; same methodology as TR's delays)."""
    if rows % n_blocks:
        raise ValueError("rows must divide evenly into n_blocks")
    block_rows = rows // n_blocks
    qr_flops = 2.0 * block_rows * cols ** 2
    g = GraphBuilder()

    def leaf(i: int):
        def make() -> jax.Array:
            return _row_block(seed, i, block_rows, cols)

        make.__name__ = "svd_block"
        return make

    blocks = [g.add(leaf(i), name=f"svd1-A-{i}") for i in range(n_blocks)]
    rs = [g.add(_costed(_qr_r, qr_flops, sleep_per_flop, ms_per_flop), blk,
                name=f"svd1-R0-{i}")
          for i, blk in enumerate(blocks)]
    depth = 0
    while len(rs) > 1:
        nxt = []
        for i in range(0, len(rs) - 1, 2):
            nxt.append(g.add(_stack_qr_r, rs[i], rs[i + 1],
                             name=f"svd1-R{depth + 1}-{i // 2}"))
        if len(rs) % 2:
            nxt.append(rs[-1])
        rs, depth = nxt, depth + 1
    final_r = rs[0]
    g.add(_singular_values, final_r, name="svd1-S")

    if compute_u:
        # Fan-out: U_i = A_i @ V @ diag(1/s) — wide fan-out from final R.
        @jax.jit
        def u_block(a_blk: jax.Array, r: jax.Array) -> jax.Array:
            u, s, vt = jnp.linalg.svd(r, full_matrices=False)
            return a_blk @ vt.T / s[None, :]

        for i, blk in enumerate(blocks):
            g.add(_costed(u_block, 2.0 * block_rows * cols ** 2,
                          sleep_per_flop, ms_per_flop),
                  blk, final_r, name=f"svd1-U-{i}")
    return g.build()


def tsqr_singular_values_expected(rows: int, cols: int, n_blocks: int,
                                  seed: int = 3) -> np.ndarray:
    block_rows = rows // n_blocks
    A = np.concatenate(
        [np.asarray(_row_block(seed, i, block_rows, cols))
         for i in range(n_blocks)], axis=0)
    return np.linalg.svd(A, compute_uv=False)


def randomized_svd_dag(
    n: int,
    rank: int = 5,
    oversample: int = 5,
    n_blocks: int = 8,
    seed: int = 4,
    ideal_storage: bool = False,
    sleep_per_flop: float = 0.0,
    ms_per_flop: float = 0.0,
) -> DAG:
    """SVD2: rank-``rank`` randomized SVD of an n x n matrix [Halko et al.].

    The square matrix is blocked by rows. ``ideal_storage`` regenerates
    A-blocks inside consumers instead of passing them through the KV store
    (paper §V-C's ideal-storage ablation — "all array data was randomly
    generated each time it was used").
    """
    if n % n_blocks:
        raise ValueError("n must divide evenly into n_blocks")
    rows = n // n_blocks
    k = rank + oversample
    blk_mm_flops = 2.0 * rows * n * k        # Y_i / B_i block products
    g = GraphBuilder()

    def costed(fn, flops=blk_mm_flops):
        return _costed(fn, flops, sleep_per_flop, ms_per_flop)

    @functools.partial(jax.jit, static_argnums=(0, 1))
    def omega(seed2: int, nn: int) -> jax.Array:
        return jax.random.normal(
            jax.random.PRNGKey(seed2), (nn, k), dtype=jnp.float32)

    def make_omega() -> jax.Array:
        return omega(seed + 1, n)

    make_omega.__name__ = "svd2_omega"
    om = g.add(make_omega, name="svd2-Omega")

    def leaf(i: int):
        def make() -> jax.Array:
            return _row_block(seed, i, rows, n)

        make.__name__ = "svd2_block"
        return make

    if ideal_storage:
        # A-blocks are regenerated in place inside every consumer: zero
        # intermediate-storage traffic for the big objects.
        @jax.jit
        def y_block_ideal(i, om_: jax.Array) -> jax.Array:
            return _row_block(seed, i, rows, n) @ om_

        ys = [g.add(costed(functools.partial(y_block_ideal, jnp.int32(i))),
                    om, name=f"svd2-Y-{i}") for i in range(n_blocks)]
    else:
        blocks = [g.add(leaf(i), name=f"svd2-A-{i}") for i in range(n_blocks)]

        @jax.jit
        def y_block(a_blk: jax.Array, om_: jax.Array) -> jax.Array:
            return a_blk @ om_

        ys = [g.add(costed(y_block), blk, om, name=f"svd2-Y-{i}")
              for i, blk in enumerate(blocks)]

    # TSQR on Y (n x k, tall-skinny) to get Q implicitly via R, then
    # B^T = A^T Q computed blockwise; SVD of B gives the rank-k factors.
    rs = [g.add(_qr_r, y, name=f"svd2-R0-{i}") for i, y in enumerate(ys)]
    depth = 0
    while len(rs) > 1:
        nxt = []
        for i in range(0, len(rs) - 1, 2):
            nxt.append(g.add(_stack_qr_r, rs[i], rs[i + 1],
                             name=f"svd2-R{depth + 1}-{i // 2}"))
        if len(rs) % 2:
            nxt.append(rs[-1])
        rs, depth = nxt, depth + 1
    final_r = rs[0]

    @jax.jit
    def q_block(y: jax.Array, r: jax.Array) -> jax.Array:
        # Q_i = Y_i R^{-1}
        return jax.scipy.linalg.solve_triangular(r.T, y.T, lower=True).T

    qs = [g.add(costed(q_block, 2.0 * rows * k * k), y, final_r,
                name=f"svd2-Q-{i}")
          for i, y in enumerate(ys)]

    if ideal_storage:
        @jax.jit
        def bt_block_ideal(i, q: jax.Array) -> jax.Array:
            return _row_block(seed, i, rows, n).T @ q

        bts = [g.add(costed(functools.partial(bt_block_ideal, jnp.int32(i))),
                     q, name=f"svd2-Bt-{i}") for i, q in enumerate(qs)]
    else:
        @jax.jit
        def bt_block(a_blk: jax.Array, q: jax.Array) -> jax.Array:
            return a_blk.T @ q

        bts = [g.add(costed(bt_block), blk, q, name=f"svd2-Bt-{i}")
               for i, (blk, q) in enumerate(zip(blocks, qs))]

    @jax.jit
    def sum2(a: jax.Array, b: jax.Array) -> jax.Array:
        return a + b

    acc = bts
    depth = 0
    while len(acc) > 1:
        nxt = []
        for i in range(0, len(acc) - 1, 2):
            nxt.append(g.add(sum2, acc[i], acc[i + 1],
                             name=f"svd2-BtSum{depth}-{i // 2}"))
        if len(acc) % 2:
            nxt.append(acc[-1])
        acc, depth = nxt, depth + 1

    @functools.partial(jax.jit, static_argnums=(1,))
    def top_singular_values(bt: jax.Array, r: int) -> jax.Array:
        return jnp.linalg.svd(bt.T, compute_uv=False)[:r]

    g.add(functools.partial(top_singular_values, r=rank), acc[0],
          name="svd2-S")
    return g.build()


def randomized_svd_expected(n: int, rank: int, oversample: int,
                            n_blocks: int, seed: int = 4) -> np.ndarray:
    rows = n // n_blocks
    A = np.concatenate([np.asarray(_row_block(seed, i, rows, n))
                        for i in range(n_blocks)], axis=0)
    Om = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed + 1), (n, rank + oversample),
        dtype=jnp.float32))
    Y = A @ Om
    Q, _ = np.linalg.qr(Y)
    B = Q.T @ A
    return np.linalg.svd(B, compute_uv=False)[:rank]
