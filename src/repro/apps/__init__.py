"""Paper workloads (§V) expressed as blocked-array DAGs with JAX payloads."""
from repro.apps.dynamic import (
    dynamic_tree_reduction_dag,
    dynamic_tree_reduction_expected,
    static_tree_reduction_equivalent,
)
from repro.apps.gemm import gemm_dag
from repro.apps.svc import svc_dag
from repro.apps.svd import tsqr_svd_dag, randomized_svd_dag
from repro.apps.tree_reduction import tree_reduction_dag

__all__ = [
    "tree_reduction_dag",
    "dynamic_tree_reduction_dag",
    "dynamic_tree_reduction_expected",
    "static_tree_reduction_equivalent",
    "gemm_dag",
    "tsqr_svd_dag",
    "randomized_svd_dag",
    "svc_dag",
]
