"""Dynamic (runtime-expanding) tree reduction.

The paper's DAGs are fully known at submit time; Triggerflow-style
workflows are not — a task may discover its fan-out width only after
looking at its inputs. ``dynamic_tree_reduction_dag`` builds the
smallest such workload: a two-leaf seed graph whose ``reduce`` task,
on execution, *returns* an :class:`~repro.core.dag.Expansion` that
fans out into a full pairwise reduction tree over the data it just
received. The engine installs the subgraph mid-job and carries on.

``static_tree_reduction_equivalent`` builds the graph the expansion
produces, statically, key for key (including the synthetic
``reduce/__base1__`` node) — the control arm of the charge-parity
gate: a dynamic run and its static equivalent must produce
bit-identical results AND bit-identical ``charged_ms`` (run both with
``schedule_ship_mbps=inf``; static-schedule shipping is the one cost
that legitimately differs, since the dynamic arm ships pre-expansion
schedules).
"""
from __future__ import annotations

import numpy as np

from repro.core.dag import (
    DAG,
    EXPAND_BASE,
    DynamicDAG,
    Expansion,
    Task,
    TaskRef,
    expansion_base_key,
)
from repro.core.simclock import simulated_compute

EXPAND_KEY = "reduce"


def _charge(compute_ms: float) -> None:
    if compute_ms > 0:
        simulated_compute(compute_ms)


def _make_half(values: np.ndarray, compute_ms: float):
    def dyn_half() -> np.ndarray:
        _charge(compute_ms)
        return values

    dyn_half.__name__ = "dyn_half"
    return dyn_half


def _make_leaf(i: int, compute_ms: float, ballast: int):
    def rx_leaf(arr: np.ndarray) -> np.ndarray:
        _charge(compute_ms)
        out = np.empty(1 + ballast)
        out[0] = arr[2 * i] + arr[2 * i + 1]
        return out

    rx_leaf.__name__ = "rx_leaf"
    return rx_leaf


def _make_combine(compute_ms: float):
    def rx_combine(x: np.ndarray, y: np.ndarray) -> np.ndarray:
        _charge(compute_ms)
        out = np.empty_like(x)
        out[0] = x[0] + y[0]
        return out

    rx_combine.__name__ = "rx_combine"
    return rx_combine


def _subgraph(n: int, base_key: str, compute_ms: float,
              payload_bytes: int) -> "tuple[list[Task], str]":
    """The reduction tree over a length-``n`` base array, every task
    reading its inputs through ``base_key`` refs (``EXPAND_BASE`` in
    the dynamic arm, the synthetic base key in the static one).
    Returns ``(tasks, final_key)`` in the deterministic order both
    arms share."""
    ballast = max(0, payload_bytes) // 8
    tasks: "list[Task]" = []
    level: "list[str]" = []
    for i in range(n // 2):
        key = f"rx-leaf-{i}"
        tasks.append(Task(key, _make_leaf(i, compute_ms, ballast),
                          (TaskRef(base_key),)))
        level.append(key)
    depth = 0
    while len(level) > 1:
        nxt: "list[str]" = []
        for j in range(0, len(level), 2):
            key = f"rx-{depth}-{j // 2}"
            tasks.append(Task(key, _make_combine(compute_ms),
                              (TaskRef(level[j]), TaskRef(level[j + 1]))))
            nxt.append(key)
        level = nxt
        depth += 1
    return tasks, level[0]


def _check_n(n: int) -> None:
    if n < 4 or n & (n - 1):
        raise ValueError("n must be a power of two >= 4")


def dynamic_tree_reduction_dag(
    n: int = 16,
    compute_ms: float = 0.0,
    payload_bytes: int = 0,
    max_expansion_depth: int = 8,
) -> DynamicDAG:
    """Two seed halves feeding a ``reduce`` task that expands, at
    runtime, into the n/2-leaf reduction tree."""
    _check_n(n)
    values = np.arange(n, dtype=np.float64)

    def tr_expand(lo: np.ndarray, hi: np.ndarray) -> Expansion:
        _charge(compute_ms)
        tasks, final = _subgraph(n, EXPAND_BASE, compute_ms, payload_bytes)
        return Expansion(value=np.concatenate([lo, hi]),
                         tasks=tasks, final=final)

    tr_expand.__name__ = "tr_expand"
    return DynamicDAG(
        [
            Task("half-lo", _make_half(values[: n // 2], compute_ms)),
            Task("half-hi", _make_half(values[n // 2:], compute_ms)),
            Task(EXPAND_KEY, tr_expand,
                 (TaskRef("half-lo"), TaskRef("half-hi"))),
        ],
        max_expansion_depth=max_expansion_depth,
    )


def static_tree_reduction_equivalent(
    n: int = 16,
    compute_ms: float = 0.0,
    payload_bytes: int = 0,
) -> DAG:
    """The graph ``dynamic_tree_reduction_dag(n)`` becomes after its
    one expansion, built statically: same keys (synthetic base
    included), same fns, same insertion order — so children lists,
    counters, KV traffic and charges line up edge for edge."""
    _check_n(n)
    values = np.arange(n, dtype=np.float64)
    base = expansion_base_key(EXPAND_KEY, 1)

    def tr_expand(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        _charge(compute_ms)
        return np.concatenate([lo, hi])

    tr_expand.__name__ = "tr_expand"
    tasks = [
        Task("half-lo", _make_half(values[: n // 2], compute_ms)),
        Task("half-hi", _make_half(values[n // 2:], compute_ms)),
        Task(base, tr_expand, (TaskRef("half-lo"), TaskRef("half-hi"))),
    ]
    sub, final = _subgraph(n, base, compute_ms, payload_bytes)
    for t in sub:
        if t.key == final:
            t = Task(EXPAND_KEY, t.fn, t.args, t.kwargs)
        tasks.append(t)
    return DAG(tasks)


def dynamic_tree_reduction_expected(n: int) -> float:
    return float(np.arange(n, dtype=np.float64).sum())
