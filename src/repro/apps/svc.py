"""Support Vector Classification (paper §V, Fig. 11).

The paper runs SVC from the Dask-ML benchmark suite with growing sample
counts. We implement a linear SVM trained by full-batch sub-gradient
descent on the hinge loss, blocked over sample chunks: each iteration is a
wide fan-out (per-block gradients), a fan-in reduction tree, and an update
task that feeds the next iteration — a DAG with the bursty fan-out/fan-in
cadence that characterizes data-parallel ML, unrolled for ``n_iters``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import GraphBuilder
from repro.core.dag import DAG

DIM = 32


@functools.partial(jax.jit, static_argnums=(2,))
def _data_block(seed, i, rows: int) -> tuple[jax.Array, jax.Array]:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (rows, DIM), dtype=jnp.float32)
    w_true = jax.random.normal(jax.random.PRNGKey(seed + 999), (DIM,),
                               dtype=jnp.float32)
    y = jnp.sign(x @ w_true + 0.1)
    return x, y


@jax.jit
def _hinge_grad(block: tuple[jax.Array, jax.Array],
                w: jax.Array) -> jax.Array:
    x, y = block
    margin = y * (x @ w)
    active = (margin < 1.0).astype(jnp.float32)
    return -(x * (y * active)[:, None]).sum(axis=0)


@jax.jit
def _apply_update(w: jax.Array, grad_sum: jax.Array, n: float,
                  lr: float, reg: float) -> jax.Array:
    return (1.0 - lr * reg) * w - lr * grad_sum / n


def svc_dag(
    n_samples: int,
    n_blocks: int = 8,
    n_iters: int = 4,
    lr: float = 0.1,
    reg: float = 1e-3,
    seed: int = 5,
    sleep_per_flop: float = 0.0,
    ms_per_flop: float = 0.0,
) -> DAG:
    if n_samples % n_blocks:
        raise ValueError("n_samples must divide into n_blocks")
    rows = n_samples // n_blocks
    grad_flops = 4.0 * rows * DIM

    def costed(fn):
        from repro.apps.costing import flop_costed

        return flop_costed(fn, grad_flops, sleep_per_flop, ms_per_flop)

    g = GraphBuilder()

    def leaf(i: int):
        def make():
            return _data_block(seed, i, rows)

        make.__name__ = "svc_block"
        return make

    blocks = [g.add(leaf(i), name=f"svc-X-{i}") for i in range(n_blocks)]

    def init_w():
        return jnp.zeros((DIM,), dtype=jnp.float32)

    init_w.__name__ = "svc_init"
    w = g.add(init_w, name="svc-w0")

    for it in range(n_iters):
        grads = [g.add(costed(_hinge_grad), blk, w,
                       name=f"svc-g{it}-{i}")
                 for i, blk in enumerate(blocks)]
        depth = 0
        while len(grads) > 1:
            nxt = []
            for i in range(0, len(grads) - 1, 2):
                nxt.append(g.add(jnp.add, grads[i], grads[i + 1],
                                 name=f"svc-gs{it}-{depth}-{i // 2}"))
            if len(grads) % 2:
                nxt.append(grads[-1])
            grads, depth = nxt, depth + 1
        w = g.add(
            functools.partial(_apply_update, n=float(n_samples), lr=lr,
                              reg=reg),
            w, grads[0], name=f"svc-w{it + 1}",
        )
    return g.build()


def svc_expected(n_samples: int, n_blocks: int = 8, n_iters: int = 4,
                 lr: float = 0.1, reg: float = 1e-3,
                 seed: int = 5) -> np.ndarray:
    rows = n_samples // n_blocks
    w = jnp.zeros((DIM,), dtype=jnp.float32)
    blocks = [_data_block(seed, i, rows) for i in range(n_blocks)]
    for _ in range(n_iters):
        gsum = None
        for blk in blocks:
            gb = _hinge_grad(blk, w)
            gsum = gb if gsum is None else gsum + gb
        w = _apply_update(w, gsum, float(n_samples), lr, reg)
    return np.asarray(w)
