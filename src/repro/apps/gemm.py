"""Blocked General Matrix Multiplication (paper §V, Fig. 8).

C = A @ B with A, B split into a bxb grid of square blocks. Leaf tasks
materialize input blocks (seeded PRNG — the paper's client also does not
ship the matrices through the scheduler), inner tasks compute block
products on the MXU-analog (jitted jnp.dot) and a reduction tree sums the
partial products per output block, giving the large fan-out/fan-in
structure that exercises WUKONG's proxy and dependency counters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import GraphBuilder
from repro.core.dag import DAG


@functools.partial(jax.jit, static_argnums=(3,))
def _block(seed, i, j, bs: int) -> jax.Array:
    # i, j are traced: ONE compiled executable serves every block
    key = jax.random.fold_in(jax.random.PRNGKey(seed), i * 65536 + j)
    return jax.random.normal(key, (bs, bs), dtype=jnp.float32) / np.sqrt(bs)


@jax.jit
def _matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


@jax.jit
def _add(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def gemm_dag(n: int, block_size: int, seed_a: int = 1, seed_b: int = 2,
             sleep_per_flop: float = 0.0, ms_per_flop: float = 0.0) -> DAG:
    """DAG computing C = A @ B for n x n matrices in block_size blocks.

    Roots are the bxb output blocks ``gemm-C-i-j``. ``ms_per_flop`` adds
    a simulated compute duration per task proportional to its analytic
    FLOPs, charged on the engine clock — the knob that emulates the
    paper's compute-heavy regime on a single-core container (same
    methodology as TR's delays, paper Fig. 4). ``sleep_per_flop`` is the
    legacy real-sleep variant (seconds per flop), kept for real-time
    cross-checks.
    """
    from repro.apps.costing import flop_costed

    def costed(fn, flops):
        return flop_costed(fn, flops, sleep_per_flop, ms_per_flop)

    if n % block_size:
        raise ValueError("n must be divisible by block_size")
    b = n // block_size
    mm_flops = 2.0 * block_size ** 3
    add_flops = float(block_size ** 2)
    g = GraphBuilder()

    def leaf(seed: int, i: int, j: int, tag: str):
        def make() -> jax.Array:
            return _block(seed, i, j, block_size)

        make.__name__ = f"gemm_block_{tag}"
        return make

    A = {(i, k): g.add(leaf(seed_a, i, k, "A"), name=f"gemm-A-{i}-{k}")
         for i in range(b) for k in range(b)}
    B = {(k, j): g.add(leaf(seed_b, k, j, "B"), name=f"gemm-B-{k}-{j}")
         for k in range(b) for j in range(b)}

    for i in range(b):
        for j in range(b):
            partials = [
                g.add(costed(_matmul, mm_flops), A[(i, k)], B[(k, j)],
                      name=f"gemm-P-{i}-{j}-{k}")
                for k in range(b)
            ]
            # pairwise reduction tree over k
            depth = 0
            while len(partials) > 1:
                nxt = []
                for s in range(0, len(partials) - 1, 2):
                    nxt.append(
                        g.add(costed(_add, add_flops),
                              partials[s], partials[s + 1],
                              name=f"gemm-S-{i}-{j}-{depth}-{s // 2}")
                    )
                if len(partials) % 2:
                    nxt.append(partials[-1])
                partials, depth = nxt, depth + 1
            final = partials[0]
            # alias the root with a stable name
            g.add(lambda x: x, final, name=f"gemm-C-{i}-{j}")
    return g.build()


def gemm_expected(n: int, block_size: int, seed_a: int = 1,
                  seed_b: int = 2) -> np.ndarray:
    b = n // block_size
    A = np.block([[np.asarray(_block(seed_a, i, k, block_size))
                   for k in range(b)] for i in range(b)])
    B = np.block([[np.asarray(_block(seed_b, k, j, block_size))
                   for j in range(b)] for k in range(b)])
    return A @ B
