"""Tree Reduction (TR) microbenchmark (paper §V, Figs. 4 & 7).

TR sums the elements of an array by repeatedly adding adjacent elements
until one remains. An initial array of n numbers yields n/2 leaf tasks at
the bottom of the DAG (paper Fig. 4 caption). A per-task delay simulates
a compute task with controllable duration — exactly the paper's
methodology for sweeping task granularity. ``compute_ms`` declares the
delay in *simulated* ms charged on the engine clock (free wall-clock
under the virtual clock, scaled real sleep in real-time mode);
``sleep_s`` is the seed's real-sleep knob, kept for cross-checks.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.api import GraphBuilder
from repro.core.dag import DAG
from repro.core.simclock import simulated_compute


def tree_reduction_dag(
    n: int = 1024,
    sleep_s: float = 0.0,
    chunk: np.ndarray | None = None,
    payload_bytes: int = 0,
    compute_ms: float = 0.0,
) -> DAG:
    """Build the TR DAG for an array of ``n`` numbers (n/2 leaf tasks).

    ``compute_ms``    — per-task simulated compute duration in ms, charged
                        on the engine clock (the paper's task-granularity
                        knob).
    ``sleep_s``       — per-task REAL sleep seconds (legacy real-time
                        knob; prefer ``compute_ms``).
    ``payload_bytes`` — optional ballast carried through every edge so the
                        communication-bound regime (paper: "dominated by
                        the communication overhead of transferring the
                        array") can be reproduced at will.
    """
    if n < 2 or n & (n - 1):
        raise ValueError("n must be a power of two >= 2")
    values = chunk if chunk is not None else np.arange(n, dtype=np.float64)
    ballast = max(0, payload_bytes) // 8

    def charge() -> None:
        if compute_ms > 0:
            simulated_compute(compute_ms)
        if sleep_s > 0:
            time.sleep(sleep_s)  # lint: allow(REPRO001) — opt-in real-sleep knob, off by default

    def make_add(a: float, b: float):
        def leaf_add() -> np.ndarray:
            charge()
            out = np.empty(1 + ballast)
            out[0] = a + b
            return out

        leaf_add.__name__ = "tr_leaf"
        return leaf_add

    def combine(x: np.ndarray, y: np.ndarray) -> np.ndarray:
        charge()
        out = np.empty_like(x)
        out[0] = x[0] + y[0]
        return out

    g = GraphBuilder()
    level = [
        g.add(make_add(values[2 * i], values[2 * i + 1]), name=f"tr-leaf-{i}")
        for i in range(n // 2)
    ]
    depth = 0
    while len(level) > 1:
        level = [
            g.add(combine, level[i], level[i + 1],
                  name=f"tr-{depth}-{i // 2}")
            for i in range(0, len(level), 2)
        ]
        depth += 1
    return g.build()


def tree_reduction_expected(n: int) -> float:
    return float(np.arange(n, dtype=np.float64).sum())
