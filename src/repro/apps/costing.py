"""Shared per-task compute costing for the workload DAG builders.

Workloads price a task's compute from its analytic FLOPs (the paper's
task-granularity methodology, Fig. 4): ``ms_per_flop`` charges simulated
ms on the engine clock via ``simulated_compute`` (free wall-clock under
the virtual clock, scaled real sleep in real-time mode);
``sleep_per_flop`` is the seed's real-sleep knob (seconds per flop),
kept for real-time cross-checks.
"""
from __future__ import annotations

import time
from typing import Any, Callable

from repro.core.simclock import simulated_compute


def flop_costed(fn: Callable[..., Any], flops: float,
                sleep_per_flop: float = 0.0,
                ms_per_flop: float = 0.0) -> Callable[..., Any]:
    """Wrap ``fn`` to charge ``flops`` worth of simulated compute (and/or
    legacy real sleep) before running. Returns ``fn`` unwrapped when both
    knobs are off."""
    if sleep_per_flop <= 0 and ms_per_flop <= 0:
        return fn

    def wrapped(*a: Any, **kw: Any) -> Any:
        if ms_per_flop > 0:
            simulated_compute(flops * ms_per_flop)
        if sleep_per_flop > 0:
            time.sleep(flops * sleep_per_flop)  # lint: allow(REPRO001) — opt-in real-sleep knob, off by default
        return fn(*a, **kw)

    wrapped.__name__ = getattr(fn, "__name__", "task")
    return wrapped
