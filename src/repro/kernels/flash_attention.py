"""Pallas TPU flash attention (causal / sliding-window, GQA).

Online-softmax attention tiled for VMEM: grid = (B, H, num_q_blocks,
num_kv_blocks) with the kv axis marked ``arbitrary`` (sequential) so the
running (max, sum, acc) state lives in VMEM scratch across kv steps.
Block shapes default to (128, 128) — MXU-aligned (multiples of the
128-lane systolic dimension) and small enough that q/k/v tiles + fp32
accumulator fit comfortably in the ~16 MB of VMEM:
  qb·hd(bf16) + 2·kb·hd(bf16) + qb·kb(fp32) + qb·hd(fp32) ≈ 260 KB.

Causal and sliding-window masks are applied per-tile from absolute row /
column indices; fully-masked kv tiles are skipped with ``@pl.when`` (the
TPU grid is executed in order, so for causal attention the skipped tail
costs only the (empty) grid step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,        # (bq, hd), (bk, hd), (bk, hd)
    o_ref,                      # (bq, hd)
    m_scratch, l_scratch, acc_scratch,
    *, causal: bool, window: int | None, sm_scale: float,
    block_q: int, block_k: int, kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile visibility test (causal: skip tiles strictly above the diagonal;
    # windowed: also skip tiles entirely older than the window)
    run = True
    if causal:
        run = jnp.asarray(k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window)

    @pl.when(run if not isinstance(run, bool) else True)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = cols < kv_len
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                                # (bq, bk)
        l_new = alpha * l_scratch[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[...] = (acc_scratch[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                 # (B, S, H, hd)
    k: jax.Array,                 # (B, S, K, hd)
    v: jax.Array,                 # (B, S, K, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,       # CPU container: interpret; False on TPU
) -> jax.Array:
    B, S, H, hd = q.shape
    K = k.shape[2]
    assert H % K == 0
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    sm_scale = hd ** -0.5

    # layout: one (b, h) pair per grid row; kv head = h // G
    qt = q.transpose(0, 2, 1, 3)              # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)              # (B, K, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, S // block_q, S // block_k)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, kv_len=S,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),   # fp32 accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
