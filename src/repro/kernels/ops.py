"""Jitted public wrappers for the Pallas kernels.

On the TPU target these run compiled (``interpret=False``); in this CPU
container they run in interpret mode, validated against ``ref.py``. The
wrappers pad ragged shapes up to block multiples and handle layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import linear_attention as _la

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_k=128):
    S = q.shape[1]
    bq, bk = min(block_q, S), min(block_k, S)
    pad = (-S) % bq
    if pad:
        cfg = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(t, cfg) for t in (q, k, v))
    out = _fa.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk,
                              interpret=not _ON_TPU)
    return out[:, :S] if pad else out


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k=512):
    return _dec.decode_attention(q, k_cache, v_cache, kv_len,
                                 block_k=min(block_k, k_cache.shape[1]),
                                 interpret=not _ON_TPU)


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_chunk(q, k, v, log_f, i_gate, *, chunk=64):
    return _la.mlstm_chunk(q, k, v, log_f, i_gate,
                           chunk=min(chunk, q.shape[1]),
                           interpret=not _ON_TPU)
