"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,            # (B, S, H, hd)
    k: jax.Array,            # (B, S, K, hd)
    v: jax.Array,            # (B, S, K, hd)
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def decode_attention_ref(
    q: jax.Array,            # (B, H, hd) — one new token per sequence
    k_cache: jax.Array,      # (B, S, K, hd)
    v_cache: jax.Array,      # (B, S, K, hd)
    kv_len: jax.Array,       # (B,) int32 — valid prefix length
) -> jax.Array:
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    mask = jnp.arange(S)[None, :] < kv_len[:, None]        # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def mlstm_chunk_ref(
    q: jax.Array,            # (B, S, H, hd) fp32
    k: jax.Array,
    v: jax.Array,
    log_f: jax.Array,        # (B, S, H) log forget gates (<= 0)
    i_gate: jax.Array,       # (B, S, H) input gates in (0, 1]
    chunk: int = 64,
) -> jax.Array:
    """Chunkwise mLSTM / gated-linear-attention oracle (matches
    repro.models.ssm.mlstm's inner math, zero initial state)."""
    B, S, H, hd = q.shape
    assert S % chunk == 0
    n = S // chunk

    def rc(t, extra):
        return t.reshape((B, n, chunk) + extra).swapaxes(0, 1)

    qs, ks, vs = rc(q, (H, hd)), rc(k, (H, hd)), rc(v, (H, hd))
    fs, is_ = rc(log_f, (H,)), rc(i_gate, (H,))
    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)

    def step(carry, inp):
        C, nv = carry
        qc, kc, vc, fc, ic = inp
        fcum = jnp.cumsum(fc, axis=1)
        ftot = fcum[:, -1]
        decay_q = jnp.exp(fcum)
        y_inter = jnp.einsum("bshk,bhkv->bshv", qc * decay_q[..., None], C)
        n_inter = jnp.einsum("bshk,bhk->bsh", qc * decay_q[..., None], nv)
        rel = fcum[:, :, None, :] - fcum[:, None, :, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        D = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
        D = D * ic[:, None, :, :]
        scores = jnp.einsum("bshk,bthk->bsth", qc, kc) * D
        y = y_inter + jnp.einsum("bsth,bthv->bshv", scores, vc)
        nrm = n_inter + jnp.einsum("bsth->bsh", scores)
        y = y / jnp.maximum(jnp.abs(nrm)[..., None], 1.0)
        decay_k = jnp.exp(ftot[:, None, :] - fcum)
        kv = jnp.einsum("bshk,bshv->bhkv", kc * (ic * decay_k)[..., None], vc)
        ksum = jnp.einsum("bshk->bhk", kc * (ic * decay_k)[..., None])
        return (jnp.exp(ftot)[..., None, None] * C + kv,
                jnp.exp(ftot)[..., None] * nv + ksum), y

    _, ys = jax.lax.scan(step, (C0, n0), (qs, ks, vs, fs, is_))
    return ys.swapaxes(0, 1).reshape(B, S, H, hd)
