"""Pallas TPU chunkwise gated linear attention (the mLSTM inner kernel).

The xLSTM matrix-memory recurrence C_t = f_t C_{t-1} + i_t k_t v_t^T is
computed in its chunkwise-parallel form: per (batch, head), chunks are
processed sequentially (grid axis ``arbitrary``) carrying the (hd, hd)
state matrix and the (hd,) normalizer in VMEM scratch; within a chunk the
intra-chunk term is a decay-masked (chunk x chunk) attention — two MXU
matmuls — and the inter-chunk term is one (chunk, hd) x (hd, hd) matmul.
This is the TPU adaptation of the CUDA chunked-scan kernels (FlashLinear-
Attention / mLSTM): HBM traffic is O(S·hd) instead of the O(S·hd²) a
naive recurrence materialization would need, and all heavy math lands on
the MXU.

Matches ``repro.kernels.ref.mlstm_chunk_ref`` (zero initial state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _mlstm_kernel(
    q_ref, k_ref, v_ref,        # (c, hd)
    f_ref, i_ref,               # (c, 1) log-forget, input gate
    o_ref,                      # (c, hd)
    C_scratch, n_scratch,       # (hd, hd), (1, hd)
    *, chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_scratch[...] = jnp.zeros_like(C_scratch)
        n_scratch[...] = jnp.zeros_like(n_scratch)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)[:, 0]       # (c,)
    ig = i_ref[...].astype(jnp.float32)[:, 0]

    fcum = jnp.cumsum(f)                           # (c,)
    ftot = fcum[-1]
    decay_q = jnp.exp(fcum)[:, None]               # (c, 1)

    C = C_scratch[...]
    nvec = n_scratch[...]                          # (1, hd)
    y_inter = jax.lax.dot_general(
        q * decay_q, C, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (c, hd)
    n_inter = jax.lax.dot_general(
        q * decay_q, nvec.T, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (c, 1)

    rel = fcum[:, None] - fcum[None, :]            # (c, c)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(mask, jnp.exp(rel), 0.0) * ig[None, :]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * D    # (c, c)
    y = y_inter + jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    nrm = n_inter[:, 0] + jnp.sum(scores, axis=1)
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)[:, None]
    o_ref[...] = y.astype(o_ref.dtype)

    decay_k = (ig * jnp.exp(ftot - fcum))[:, None]  # (c, 1)
    kd = k * decay_k
    C_scratch[...] = jnp.exp(ftot) * C + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (hd, hd)
    n_scratch[...] = jnp.exp(ftot) * nvec + jnp.sum(kd, axis=0)[None, :]


def mlstm_chunk(
    q: jax.Array,               # (B, S, H, hd) fp32
    k: jax.Array,
    v: jax.Array,
    log_f: jax.Array,           # (B, S, H)
    i_gate: jax.Array,          # (B, S, H)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    assert S % chunk == 0
    qt = q.transpose(0, 2, 1, 3)                   # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    ft = log_f.transpose(0, 2, 1)[..., None]       # (B, H, S, 1)
    it = i_gate.transpose(0, 2, 1)[..., None]

    grid = (B, H, S // chunk)
    spec_seq = pl.BlockSpec((None, None, chunk, hd),
                            lambda b, h, ci: (b, h, ci, 0))
    spec_gate = pl.BlockSpec((None, None, chunk, 1),
                             lambda b, h, ci: (b, h, ci, 0))
    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec_seq, spec_seq, spec_seq, spec_gate, spec_gate],
        out_specs=spec_seq,
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, ft, it)
    return out.transpose(0, 2, 1, 3)
