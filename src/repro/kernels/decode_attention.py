"""Pallas TPU decode attention: one query token vs. a long KV cache.

Decode attention is memory-bound (every cache byte is read once per
step), so the kernel's job is to stream K/V tiles through VMEM at full
HBM bandwidth while keeping the flash accumulator in registers/VMEM.
Grid = (B, K_heads, num_kv_blocks) with the kv axis sequential; the G =
H/K query heads of a kv group are processed together as a (G, hd) tile —
MXU-friendly and it amortizes each K/V tile read across the whole group
(the GQA rationale).

``kv_len`` masks the unwritten cache tail, so the same kernel serves any
prefix length (the decode_32k / long_500k shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(
    kvlen_ref,                   # scalar prefetch: (B,) int32
    q_ref,                       # (G, hd)
    k_ref, v_ref,                # (bk, hd)
    o_ref,                       # (G, hd)
    m_scratch, l_scratch, acc_scratch,
    *, sm_scale: float, block_k: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    kv_len = kvlen_ref[b]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[...].astype(jnp.float32)                 # (G, hd)
        k = k_ref[...].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (G, bk)
        cols = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_prev = m_scratch[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scratch[...] = alpha * l_scratch[...] + jnp.sum(
            p, axis=1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[...] = (acc_scratch[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,                # (B, H, hd)
    k_cache: jax.Array,          # (B, S, K, hd)
    v_cache: jax.Array,          # (B, S, K, hd)
    kv_len: jax.Array,           # (B,) int32
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    assert H % K == 0
    G = H // K
    block_k = min(block_k, S)
    assert S % block_k == 0
    sm_scale = hd ** -0.5

    qg = q.reshape(B, K, G, hd)
    kt = k_cache.transpose(0, 2, 1, 3)     # (B, K, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    grid = (B, K, S // block_k)
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, None, G, hd),
                             lambda b, h, ki, *_: (b, h, 0, 0)),
                pl.BlockSpec((None, None, block_k, hd),
                             lambda b, h, ki, *_: (b, h, ki, 0)),
                pl.BlockSpec((None, None, block_k, hd),
                             lambda b, h, ki, *_: (b, h, ki, 0)),
            ],
            out_specs=pl.BlockSpec((None, None, G, hd),
                                   lambda b, h, ki, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, H, hd)
