"""Pallas API compatibility across jax versions.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
the kernels target the new name, so alias it on older jaxlib.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
