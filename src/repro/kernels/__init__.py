"""Pallas TPU kernels for the LM payload hot-spots.

Each kernel ships three files: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jitted wrapper), ref.py (pure-jnp oracle). The paper itself has
no kernel-level contribution (it is a scheduling paper); these kernels
serve the assigned-architecture payloads (DESIGN.md §2).
"""
