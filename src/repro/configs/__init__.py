"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published configuration;
``reduced(cfg)`` shrinks it (same family/topology) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "xlstm_350m",
    "llama3_405b",
    "smollm_360m",
    "nemotron_4_340b",
    "qwen2_72b",
    "jamba_1_5_large_398b",
    "mixtral_8x7b",
    "mixtral_8x22b",
    "chameleon_34b",
    "whisper_large_v3",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


def reduced(cfg: ModelConfig, seq_len: int = 64) -> ModelConfig:
    """Smoke-test shrink: same family, topology, and pattern; tiny dims."""
    period = cfg.pattern_period
    n_heads = min(cfg.n_heads, 4)
    # keep GQA ratio >= 1, kv | heads
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(4, moe.n_experts),
                                  top_k=min(2, moe.top_k))
    return dataclasses.replace(
        cfg,
        n_layers=2 * period,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        moe=moe,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window
        else None,
        n_enc_layers=2 if cfg.enc_dec else 0,
        enc_frames=8 if cfg.enc_dec else cfg.enc_frames,
        ssm_state_dim=4,
        moe_capacity_factor=8.0,  # drop-free so decode == forward exactly
        dtype="float32",
        remat=False,
    )
