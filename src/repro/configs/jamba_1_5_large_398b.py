"""Jamba-1.5-Large [arXiv:2403.19887]: Mamba+attention 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536. Period-8 pattern:
one attention layer per 7 Mamba layers; MoE MLP every other layer
(Jamba places MoE on alternate layers; dense d_ff elsewhere).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    block_pattern=(
        "mamba+dense", "mamba+moe", "mamba+dense", "mamba+moe",
        "attn+dense", "mamba+moe", "mamba+dense", "mamba+moe",
    ),
    moe=MoEConfig(n_experts=16, top_k=2),
    activation="swiglu",
    ssm_state_dim=16,
    rope_theta=10000.0,
)
