"""Mixtral-8x7B [arXiv:2401.04088]: 8 experts top-2, sliding-window attn.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, SWA window 4096.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    block_pattern=("attn+moe",),
    moe=MoEConfig(n_experts=8, top_k=2),
    sliding_window=4096,
    activation="swiglu",
    rope_theta=1000000.0,
)
