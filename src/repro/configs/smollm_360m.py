"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M]: llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152. Tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    block_pattern=("attn+dense",),
    activation="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
)
