"""Whisper-large-v3 [arXiv:2212.04356]: encoder-decoder, conv frontend stub.

32L (decoder) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866; 32
encoder layers over 1500 audio frames. The conv frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, 1500, 1280).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    block_pattern=("attn+dense",),
    activation="gelu",
    enc_dec=True,
    n_enc_layers=32,
    enc_frames=1500,
    frontend="audio_stub",
)
