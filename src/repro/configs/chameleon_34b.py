"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM, VQ image tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The vision
frontend is a STUB per the assignment: images arrive as VQ token ids that
live in the same 65536 vocab, so the backbone is a dense GQA decoder.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    block_pattern=("attn+dense",),
    activation="swiglu",
    frontend="vision_stub",
    rope_theta=10000.0,
)
