"""Mixtral-8x22B [arXiv:2401.04088]: 8 experts top-2, sliding-window attn.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, SWA window 4096.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    block_pattern=("attn+moe",),
    moe=MoEConfig(n_experts=8, top_k=2),
    sliding_window=4096,
    activation="swiglu",
    rope_theta=1000000.0,
)
