"""xLSTM-350M: alternating mLSTM / sLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304. ``d_ff=0``: xLSTM
blocks carry their own up/down projections (mLSTM proj-factor 2) and have
no separate FFN. Pattern alternates matrix-memory (mLSTM, parallelizable)
and scalar-memory (sLSTM, sequential) cells.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    activation="gelu",
    mlstm_proj_factor=2.0,
    tie_embeddings=True,
)
