"""Static analysis + determinism sanitizer for the simulation substrate.

Three passes guard the effect-protocol contract (see README
"Determinism contract & static analysis"):

- :mod:`repro.analysis.effects`    — AST lint encoding the contract as
  rules (wall-clock, unseeded randomness, ``*_g`` generator
  discipline, key hygiene).
- :mod:`repro.analysis.dagcheck`   — unified DAG / expansion / schedule
  validation, invoked by ``DAG.__init__`` / ``DynamicDAG`` /
  ``compile_dag`` and callable standalone.
- :mod:`repro.analysis.divergence` — opt-in runtime effect tracing plus
  ``diff_traces`` pinpointing the first divergent event between runs.

``python -m repro.analysis --check src`` runs the static lint with the
checked-in baseline and exits non-zero on new findings (the CI
``static-analysis`` job).

This package is a *leaf*: it imports nothing from ``repro.core``
(``dagcheck`` duck-types graphs), which is what lets the core modules
route their validation through it without an import cycle.
"""
from repro.analysis.dagcheck import (
    ConsistencyError,
    CycleError,
    ExpansionError,
    check_compiled,
    check_expansion,
    check_fan_in_counters,
    check_schedule_set,
    verify_dag,
)
from repro.analysis.divergence import Divergence, TraceEvent, Tracer, diff_traces
from repro.analysis.effects import ALL_RULES, lint_file, lint_source, lint_tree
from repro.analysis.findings import Finding, load_baseline, new_findings, write_baseline

__all__ = [
    "ALL_RULES",
    "ConsistencyError",
    "CycleError",
    "Divergence",
    "ExpansionError",
    "Finding",
    "TraceEvent",
    "Tracer",
    "check_compiled",
    "check_expansion",
    "check_fan_in_counters",
    "check_schedule_set",
    "diff_traces",
    "lint_file",
    "lint_source",
    "lint_tree",
    "load_baseline",
    "new_findings",
    "verify_dag",
    "write_baseline",
]
