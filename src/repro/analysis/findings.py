"""Findings and the grandfathering baseline for ``repro.analysis``.

A :class:`Finding` is one rule violation at one source location. The
baseline file (checked in, JSON) lists findings that predate the rule
and are tolerated; ``python -m repro.analysis`` only fails on findings
NOT in the baseline, so a new rule can land before every historical
violation is fixed.

Baseline matching is keyed on ``(rule, path, snippet)`` — the stripped
source line text rather than the line *number* — so unrelated edits
above a grandfathered site don't resurrect it as "new".
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    ``path`` is repo-relative POSIX (stable across machines and CI);
    ``snippet`` is the stripped source line, the drift-tolerant half of
    the baseline key.
    """

    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: dict) -> "Finding":
        return cls(
            rule=obj["rule"],
            path=obj["path"],
            line=int(obj.get("line", 0)),
            col=int(obj.get("col", 0)),
            message=obj.get("message", ""),
            snippet=obj.get("snippet", ""),
        )

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def load_baseline(path: "str | Path | None") -> set[tuple[str, str, str]]:
    """Baseline keys from a JSON file; a missing path is an empty
    baseline (the shipped tree aims for zero grandfathered findings)."""
    if path is None:
        return set()
    p = Path(path)
    if not p.exists():
        return set()
    data = json.loads(p.read_text())
    return {Finding.from_json(f).key() for f in data.get("findings", ())}


def write_baseline(findings: Iterable[Finding], path: "str | Path") -> None:
    payload = {"findings": [f.to_json() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.rule))]}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def new_findings(findings: Iterable[Finding],
                 baseline: set[tuple[str, str, str]]) -> list[Finding]:
    """Findings not grandfathered by ``baseline``."""
    return [f for f in findings if f.key() not in baseline]
