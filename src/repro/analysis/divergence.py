"""Runtime determinism sanitizer: effect-trace journaling + diffing.

The static lint (``repro.analysis.effects``) catches the *sources* of
nondeterminism it can see syntactically; this module catches the ones
it can't, at runtime. In trace mode every effect an actor yields —
``("charge", ms)``, ``("get", q, t)``, … — is journaled as a
:class:`TraceEvent` ``(actor, seq, effect, charge, src)`` tuple, and
:func:`diff_traces` compares two journals (two runs of the same job, or
an EventClock run against a VirtualClock cross-check) and reports the
FIRST divergent event with the actor and source line that produced it
— turning "charged_ms differs in the 9th decimal" into "frame
invoker#12, kvstore.py:431, charged 3.07 vs 3.11".

Usage::

    clock = EventClock()
    clock.tracer = Tracer()          # opt-in: None (the default) is free
    engine.compute(dag, ...)
    trace_a = clock.tracer.events

    # ... second run, second tracer ...
    div = diff_traces(trace_a, trace_b)
    assert div is None, div.describe()

The hook is duck-typed: the substrates call ``tracer.record(actor,
effect, gen)`` on every freshly generated effect (replayed/deferred
effects are not re-recorded), so ``repro.core.simclock`` never imports
this module. Event order is deterministic on both virtual substrates
(FIFO ready queues, (deadline, seq) timers), so two traced runs of a
deterministic job produce identical journals; the thread substrate
additionally interleaves *unrelated* actors' records under the OS
scheduler, which is what :func:`diff_traces`'s ``by_actor`` mode is
for — per-actor effect sequences are deterministic even when the
global interleaving is not.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Iterable, Sequence

__all__ = ["Divergence", "TraceEvent", "Tracer", "diff_traces"]


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One journaled effect.

    ``seq``    — global position in this trace (append order).
    ``actor``  — the frame/actor label that yielded the effect
                 (``name#seq`` — substrate-assigned, deterministic).
    ``effect`` — the effect kind ("charge", "get", "acquire", "wait",
                 "flush", "sleep").
    ``charge`` — the simulated ms for "charge"/"sleep" effects, None
                 otherwise.
    ``src``    — ``file.py:line`` of the innermost generator's yield
                 (the actual source line, through any ``yield from``
                 chain).
    """

    seq: int
    actor: str
    effect: str
    charge: "float | None"
    src: str

    def signature(self) -> tuple[str, "float | None", str]:
        """The substrate-independent projection compared by
        :func:`diff_traces` (actor labels differ across substrates)."""
        return (self.effect, self.charge, self.src)


def _source_of(gen: Any) -> str:
    """``file.py:line`` of the suspended yield, following the
    ``yield from`` delegation chain to the innermost generator."""
    seen = 0
    while seen < 64:  # defensive bound; real chains are a few deep
        sub = getattr(gen, "gi_yieldfrom", None)
        if sub is None or not hasattr(sub, "gi_frame"):
            break
        gen = sub
        seen += 1
    frame = getattr(gen, "gi_frame", None)
    if frame is None:
        return "?"
    fname = frame.f_code.co_filename.rsplit("/", 1)[-1]
    return f"{fname}:{frame.f_lineno}"


class Tracer:
    """Collects :class:`TraceEvent` records; attach as ``clock.tracer``.

    Thread-safe: on the thread substrate multiple actor threads record
    concurrently (the lock keeps ``seq`` consistent with list order)."""

    def __init__(self) -> None:
        self.events: list[TraceEvent] = []
        self._lock = threading.Lock()

    def record(self, actor: str, effect: tuple, gen: Any) -> None:
        """Substrate hook: journal one freshly generated effect."""
        kind = effect[0]
        charge = float(effect[1]) if kind in ("charge", "sleep") else None
        src = _source_of(gen)
        with self._lock:
            self.events.append(TraceEvent(
                seq=len(self.events), actor=actor, effect=kind,
                charge=charge, src=src))

    def __len__(self) -> int:
        return len(self.events)


@dataclasses.dataclass(frozen=True)
class Divergence:
    """The first point two traces disagree.

    ``index`` is the position within the compared sequence (global, or
    per-actor in ``by_actor`` mode — ``actor`` then names which
    actor's sequence split). ``left``/``right`` are the events at that
    position (None when one trace ended early)."""

    index: int
    left: "TraceEvent | None"
    right: "TraceEvent | None"
    actor: "str | None" = None

    def describe(self) -> str:
        where = (f"actor {self.actor!r} event {self.index}"
                 if self.actor is not None else f"event {self.index}")

        def side(e: "TraceEvent | None") -> str:
            if e is None:
                return "<trace ended>"
            charge = "" if e.charge is None else f" {e.charge:g}ms"
            return f"{e.effect}{charge} @ {e.src} [{e.actor}]"

        return (f"traces diverge at {where}: "
                f"{side(self.left)}  !=  {side(self.right)}")


def _events(trace: "Tracer | Iterable[TraceEvent]") -> Sequence[TraceEvent]:
    if isinstance(trace, Tracer):
        return trace.events
    return list(trace)


def _first_diff(a: Sequence[TraceEvent], b: Sequence[TraceEvent],
                actor: "str | None" = None) -> "Divergence | None":
    for i, (ea, eb) in enumerate(zip(a, b)):
        if ea.signature() != eb.signature():
            return Divergence(index=i, left=ea, right=eb, actor=actor)
    if len(a) != len(b):
        i = min(len(a), len(b))
        return Divergence(
            index=i,
            left=a[i] if i < len(a) else None,
            right=b[i] if i < len(b) else None,
            actor=actor)
    return None


def diff_traces(a: "Tracer | Iterable[TraceEvent]",
                b: "Tracer | Iterable[TraceEvent]",
                by_actor: bool = False) -> "Divergence | None":
    """First divergence between two effect traces, or None.

    Events compare by ``(effect, charge, src)`` — actor labels are
    reported, not compared, so an EventClock trace diffs cleanly
    against a VirtualClock one. Default mode compares the global
    journal order (exact for the deterministic substrates); ``by_actor``
    compares each actor's own effect sequence instead, pairing the
    k-th distinct actor of one trace with the k-th of the other (spawn
    order is deterministic even where thread interleaving is not) and
    reporting the divergence of the earliest-spawned actor that has
    one.
    """
    ea, eb = _events(a), _events(b)
    if not by_actor:
        return _first_diff(ea, eb)
    grouped_a = _by_actor(ea)
    grouped_b = _by_actor(eb)
    for (actor_a, seq_a), (actor_b, seq_b) in zip(grouped_a, grouped_b):
        label = actor_a if actor_a == actor_b else f"{actor_a}|{actor_b}"
        div = _first_diff(seq_a, seq_b, actor=label)
        if div is not None:
            return div
    if len(grouped_a) != len(grouped_b):
        longer = grouped_a if len(grouped_a) > len(grouped_b) else grouped_b
        actor, seq = longer[min(len(grouped_a), len(grouped_b))]
        return Divergence(
            index=0,
            left=seq[0] if longer is grouped_a else None,
            right=seq[0] if longer is grouped_b else None,
            actor=actor)
    return None


def _by_actor(events: Sequence[TraceEvent]) \
        -> list[tuple[str, list[TraceEvent]]]:
    """Per-actor sequences in first-appearance (spawn) order."""
    order: list[str] = []
    groups: dict[str, list[TraceEvent]] = {}
    for e in events:
        if e.actor not in groups:
            groups[e.actor] = []
            order.append(e.actor)
        groups[e.actor].append(e)
    return [(actor, groups[actor]) for actor in order]
