"""Unified DAG / schedule validation (the pre-compile pass).

One home for every graph-integrity rule that used to be scattered
across ``repro.core.dag`` (duplicate keys, missing deps, cycles),
``DynamicDAG.apply_expansion`` (the runtime-expansion rules: collision,
orphan, self-containment, depth cap) and ``repro.core.schedule``
(fan-in counter widths). ``DAG.__init__`` / ``DynamicDAG`` /
``compile_dag`` all route through these functions, and every check is
callable standalone — tests and debugging tools re-validate a live
(possibly runtime-expanded) graph with :func:`verify_dag` without
rebuilding it.

Layering: this module depends on nothing inside ``repro.core`` (it
duck-types tasks via ``.key`` / ``.dependencies()``), which is what
lets ``dag.py`` import it at module load. The exception types and the
``EXPAND_BASE`` placeholder are therefore *defined* here and
re-exported by ``repro.core.dag`` — the import path every caller and
test already uses.

Construction-time checks raise the same exception types with the same
messages as the pre-unification code (:class:`CycleError`,
:class:`ExpansionError`, ``ValueError``); invariant *re*-checks on an
already-built graph raise :class:`ConsistencyError` so a corruption
found after construction is distinguishable from a bad input.
"""
from __future__ import annotations

from typing import Any, Iterable, Mapping

__all__ = [
    "EXPAND_BASE",
    "ConsistencyError",
    "CycleError",
    "ExpansionError",
    "build_graph",
    "check_compiled",
    "check_expansion",
    "check_fan_in_counters",
    "check_schedule_set",
    "fan_in_counter_id",
    "toposort",
    "verify_dag",
]


class CycleError(ValueError):
    pass


class ExpansionError(ValueError):
    """An invalid runtime expansion (bad subgraph, depth exceeded)."""


class ConsistencyError(ValueError):
    """A built graph / schedule set violates a structural invariant."""


# Placeholder dependency key inside an Expansion's subgraph: rewritten
# at apply time to the synthetic base node that holds the expanding
# task's own output value. (Re-exported by repro.core.dag.)
EXPAND_BASE = "__expand_base__"

# Fan-in dependency counters are registered under this prefix (shared
# with repro.core.schedule, which builds the registration batch).
_FANIN_PREFIX = "__fanin__/"


def fan_in_counter_id(key: str) -> str:
    return f"{_FANIN_PREFIX}{key}"


# ---------------------------------------------------------------------------
# Construction-time checks (the DAG.__init__ path)
# ---------------------------------------------------------------------------


def build_graph(tasks: Iterable[Any]) -> tuple[
        dict[str, Any], dict[str, tuple[str, ...]], dict[str, list[str]]]:
    """Validated ``(tasks, deps, children)`` maps from a task iterable.

    Raises ``ValueError`` on a duplicate task key or a dependency on a
    missing key — the two input errors a graph can contain before
    acyclicity is even a question.
    """
    task_map: dict[str, Any] = {}
    for t in tasks:
        if t.key in task_map:
            raise ValueError(f"duplicate task key {t.key!r}")
        task_map[t.key] = t
    deps: dict[str, tuple[str, ...]] = {}
    children: dict[str, list[str]] = {k: [] for k in task_map}
    for k, t in task_map.items():
        d = t.dependencies()
        missing = [x for x in d if x not in task_map]
        if missing:
            raise ValueError(f"task {k!r} depends on missing keys {missing}")
        deps[k] = d
        for x in d:
            children[x].append(k)
    return task_map, deps, children


def toposort(tasks: Mapping[str, Any], deps: Mapping[str, tuple[str, ...]],
             children: Mapping[str, list[str]]) -> tuple[str, ...]:
    """Full topological order; raises :class:`CycleError` if none exists.

    The order doubles as the acyclicity certificate — callers cache it
    so host-side hot paths (compiler passes, schedule generation,
    critical-path metrics) pay O(V+E) once per graph.
    """
    indeg = {k: len(deps[k]) for k in tasks}
    stack = [k for k in tasks if indeg[k] == 0]
    out: list[str] = []
    while stack:
        k = stack.pop()
        out.append(k)
        for c in children[k]:
            indeg[c] -= 1
            if indeg[c] == 0:
                stack.append(c)
    if len(out) != len(tasks):
        raise CycleError("task graph contains a cycle")
    return tuple(out)


# ---------------------------------------------------------------------------
# Runtime-expansion checks (the DynamicDAG.apply_expansion path)
# ---------------------------------------------------------------------------


def check_expansion(tasks: Mapping[str, Any], key: str, expansion: Any,
                    base: str, depth: int, max_depth: int) \
        -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Validate ``expansion`` at ``key`` against the live graph.

    Returns ``(keys, order)``: the subgraph keys in declaration order
    and the local topological order ``[base, ...subgraph...]`` the
    installer and the incremental scheduler consume. Raises
    :class:`ExpansionError` on any violation, in the same order (and
    with the same messages) as the pre-unification inline checks.
    """
    if depth > max_depth:
        raise ExpansionError(
            f"expansion depth {depth} at {key!r} exceeds "
            f"max_expansion_depth={max_depth}")
    sub_tasks = expansion.tasks
    if not sub_tasks:
        raise ExpansionError("empty expansion")
    keys = [t.key for t in sub_tasks]
    if len(set(keys)) != len(keys):
        raise ExpansionError(f"duplicate keys in expansion: {keys}")
    if expansion.final not in set(keys):
        raise ExpansionError(
            f"final {expansion.final!r} not among expansion tasks")
    collisions = [k for k in keys if k in tasks or k == EXPAND_BASE]
    if collisions:
        raise ExpansionError(
            f"expansion keys collide with existing tasks: {collisions}")
    if base in tasks:
        raise ExpansionError(f"base key {base!r} already exists")
    allowed = set(keys) | {EXPAND_BASE}
    sub_deps: dict[str, tuple[str, ...]] = {}
    uses_base = False
    for t in sub_tasks:
        deps = t.dependencies()
        bad = [d for d in deps if d not in allowed]
        if bad:
            raise ExpansionError(
                f"expansion task {t.key!r} depends on {bad}; only "
                f"EXPAND_BASE and sibling expansion tasks are allowed "
                f"(self-contained expansions)")
        if expansion.final in deps:
            raise ExpansionError(
                f"expansion task {t.key!r} depends on the final task "
                f"{expansion.final!r}")
        if not deps:
            raise ExpansionError(
                f"expansion task {t.key!r} has no dependencies and "
                f"would never be triggered")
        if EXPAND_BASE in deps:
            uses_base = True
        sub_deps[t.key] = deps
    if not uses_base:
        raise ExpansionError(
            "no expansion task depends on EXPAND_BASE — the subgraph "
            "has no entry point")
    # Local topological order over {base} + subgraph — also the delta
    # acyclicity check.
    order = [base]
    indeg = {k: sum(1 for d in sub_deps[k] if d != EXPAND_BASE)
             for k in keys}
    stack = [k for k in keys if indeg[k] == 0]
    rchildren: dict[str, list[str]] = {k: [] for k in keys}
    for k in keys:
        for d in sub_deps[k]:
            if d != EXPAND_BASE:
                rchildren[d].append(k)
    while stack:
        k = stack.pop()
        order.append(k)
        for c in rchildren[k]:
            indeg[c] -= 1
            if indeg[c] == 0:
                stack.append(c)
    if len(order) != len(keys) + 1:
        raise ExpansionError("expansion subgraph contains a cycle")
    return tuple(keys), tuple(order)


# ---------------------------------------------------------------------------
# Standalone invariant re-checks (live graphs, schedule sets, compiled DAGs)
# ---------------------------------------------------------------------------


def verify_dag(dag: Any) -> tuple[str, ...]:
    """Re-validate a built (possibly runtime-expanded) DAG's structural
    invariants; returns a fresh topological order.

    Checks: deps match each task's declared dependencies, deps/children
    mirror each other edge-for-edge, ``leaves``/``roots`` are exactly
    the in-degree-0 / out-degree-0 sets, every node is reachable from a
    leaf, and the graph is acyclic. Raises :class:`ConsistencyError`
    (or :class:`CycleError`) on violation — a live graph failing this
    was corrupted *after* construction, e.g. by a concurrent expansion
    bug.
    """
    tasks, deps, children = dag.tasks, dag.deps, dag.children
    for m, name in ((deps, "deps"), (children, "children")):
        extra = set(m) - set(tasks)
        missing = set(tasks) - set(m)
        if extra or missing:
            raise ConsistencyError(
                f"{name} keys diverge from tasks "
                f"(extra={sorted(extra)}, missing={sorted(missing)})")
    edges: set[tuple[str, str]] = set()
    for k, t in tasks.items():
        declared = t.dependencies()
        if tuple(deps[k]) != tuple(declared):
            raise ConsistencyError(
                f"task {k!r} declares deps {list(declared)} but the graph "
                f"records {list(deps[k])}")
        for d in deps[k]:
            edges.add((d, k))
    for d, cs in children.items():
        if len(cs) != len(set(cs)):
            raise ConsistencyError(
                f"task {d!r} lists duplicate children {cs}")
        for c in cs:
            if (d, c) not in edges:
                raise ConsistencyError(
                    f"children edge {d!r}->{c!r} has no matching dep")
            edges.discard((d, c))
    if edges:
        raise ConsistencyError(
            f"dep edges missing from children lists: {sorted(edges)}")
    leaf_set = {k for k in tasks if not deps[k]}
    if set(dag.leaves) != leaf_set:
        raise ConsistencyError(
            f"leaves {sorted(dag.leaves)} != in-degree-0 set "
            f"{sorted(leaf_set)}")
    root_set = {k for k in tasks if not children[k]}
    if set(dag.roots) != root_set:
        raise ConsistencyError(
            f"roots {sorted(dag.roots)} != out-degree-0 set "
            f"{sorted(root_set)}")
    order = toposort(tasks, deps, children)
    # Acyclic + every node topo-sorted implies leaf-reachability; an
    # unreachable node would need an in-edge cycle, caught above.
    return order


def check_fan_in_counters(dag: Any, counters: Mapping[str, int]) -> None:
    """Verify a registered counter map against the graph: exactly one
    counter per true fan-in node (in-degree > 1), each with width equal
    to the node's in-degree. This is the invariant the executor's
    increment-and-check protocol relies on — a stale width deadlocks
    (too wide) or double-fires (too narrow) the fan-in."""
    expected = {fan_in_counter_id(k): len(dag.deps[k])
                for k in dag.tasks if len(dag.deps[k]) > 1}
    for cid, width in expected.items():
        got = counters.get(cid)
        if got is None:
            raise ConsistencyError(
                f"fan-in counter {cid!r} (width {width}) missing from "
                f"the registered set")
        if got != width:
            raise ConsistencyError(
                f"fan-in counter {cid!r} registered with width {got} "
                f"but the task has in-degree {width}")
    extra = [cid for cid in counters
             if cid.startswith(_FANIN_PREFIX) and cid not in expected]
    if extra:
        raise ConsistencyError(
            f"registered fan-in counters for non-fan-in tasks: "
            f"{sorted(extra)}")


def check_schedule_set(schedule_set: Any) -> None:
    """Verify a generated :class:`~repro.core.schedule.ScheduleSet`
    against its DAG: the initial-invocation batches cover every leaf
    exactly once, every batch's schedule covers all its start keys, and
    the fan-in counter registry is consistent (width == in-degree)."""
    dag = schedule_set.dag
    seen: dict[str, int] = {}
    for start_keys, sched in schedule_set.batches:
        for k in start_keys:
            seen[k] = seen.get(k, 0) + 1
            if k not in dag.tasks:
                raise ConsistencyError(
                    f"batch start key {k!r} is not a task")
            if not sched.covers(k):
                raise ConsistencyError(
                    f"batch schedule (leaf {sched.leaf!r}) does not cover "
                    f"its start key {k!r}")
    for leaf in dag.leaves:
        n = seen.get(leaf, 0)
        if n != 1:
            raise ConsistencyError(
                f"leaf {leaf!r} covered by {n} initial batches "
                f"(must be exactly 1)")
    extra = set(seen) - set(dag.leaves)
    if extra:
        raise ConsistencyError(
            f"batches start non-leaf tasks: {sorted(extra)}")
    check_fan_in_counters(dag, schedule_set.fan_in_counters())


def check_compiled(dag: Any) -> None:
    """Verify a :class:`~repro.core.optimize.CompiledDAG`'s annotations
    against its own graph (``compile_dag`` runs this on every result):
    cluster ids map member tasks to member tasks, delayed fan-ins are
    true fan-in nodes, and ``leaf_batches`` partition the leaves."""
    tasks = dag.tasks
    for k, cid in dag.clusters.items():
        if k not in tasks or cid not in tasks:
            raise ConsistencyError(
                f"cluster annotation {k!r}->{cid!r} references a "
                f"non-task key")
    for k in dag.delayed_fanins:
        if k not in tasks:
            raise ConsistencyError(
                f"delayed fan-in {k!r} is not a task")
        if len(dag.deps[k]) <= 1:
            raise ConsistencyError(
                f"delayed fan-in {k!r} has in-degree {len(dag.deps[k])} "
                f"(must be > 1)")
    seen: set[str] = set()
    for batch in dag.leaf_batches:
        for k in batch:
            if k in seen:
                raise ConsistencyError(
                    f"leaf {k!r} appears in multiple leaf batches")
            seen.add(k)
    if seen != set(dag.leaves):
        raise ConsistencyError(
            f"leaf batches cover {sorted(seen)} but the leaves are "
            f"{sorted(dag.leaves)}")
