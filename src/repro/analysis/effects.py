"""Effect-protocol static analysis: the determinism contract as lint rules.

The simulation's core claim — bit-identical ``charged_ms`` / billed USD
across the EventClock and VirtualClock substrates and across runs —
rests on discipline that used to be enforced only by review:

- **No wall clock in actor code** (``REPRO001``): every duration and
  deadline goes through the engine clock. A ``time.time()`` in a cost
  path silently couples the simulation to host speed.
- **No unseeded randomness** (``REPRO002``): all stochastic draws come
  from ``random.Random(zlib.crc32(token))``-style seeded generators;
  the module-level ``random.*`` functions share mutable global state
  and make two runs diverge.
- **Generator discipline** for ``*_g`` effect generators:
  shared host-state mutation after the first yield without holding the
  protecting lock (``REPRO010`` — another frame may interleave at
  every yield; applies to classes that own a ``threading.Lock``, which
  is how the codebase marks cross-actor state — frame-confined objects
  like a per-invocation ``TaskExecutor`` mutate freely), a
  threading lock held across a yield (``REPRO011`` — the frame parks
  while an OS lock stays taken: deadlock on the event substrate),
  blocking KV wrappers called inside a generator frame (``REPRO012`` —
  ``kv.get`` is ``run_effects(clock, kv.get_g(...))``, which raises
  ``RuntimeError`` inside a frame; compose with ``yield from`` instead),
  and a ``task_clock`` block not followed by ``yield ("flush",)``
  (``REPRO013`` — compute charged inside the task function is deferred
  on the event substrate; reading ``now_ms`` before flushing skews the
  recorded compute/write split).
- **Key hygiene** (``REPRO020``/``REPRO021``): ``::`` is the KV
  namespace separator — a bare key literal containing it bypasses
  prefix stripping and changes shard placement; builtin ``hash()`` is a
  per-process PYTHONHASHSEED lottery (the PR-2 bug class), placement
  and fault seeds must hash with ``zlib.crc32``.

Scope: the determinism rules (001/002/01x) apply to *actor code paths*
— ``core/``, ``platform/``, ``apps/`` under the ``repro`` package (and
any tree with no ``repro`` ancestor, so test fixtures exercise every
rule). The jax-side training/serving dirs (``runtime/``, ``launch/``,
``models/``, ``kernels/``, ``optim/``, ``data/``, ``configs/``) run
outside the simulation substrate and are exempt. Key-hygiene rules
apply everywhere.

Suppression: ``ALLOW`` grandfathers whole files that ARE the substrate
(``core/simclock.py`` implements the clocks out of ``time.*`` — that is
its job). Individual legitimate sites carry a line pragma instead::

    time.sleep(s)  # lint: allow(REPRO001) — real-sleep knob, off by default

so the rest of the file stays covered.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable

from repro.analysis.findings import Finding

__all__ = ["ALL_RULES", "lint_file", "lint_source", "lint_tree"]

# rule id -> one-line description (the CLI's --explain output)
ALL_RULES: dict[str, str] = {
    "REPRO001": "wall-clock call in actor code (use the engine clock)",
    "REPRO002": "unseeded randomness in actor code (seed via zlib.crc32)",
    "REPRO010": "lock-protected host state mutated after a yield, lockless",
    "REPRO011": "threading lock held across a yield (frame parks locked)",
    "REPRO012": "blocking KV wrapper called inside a generator frame",
    "REPRO013": "task_clock block not followed by yield (\"flush\",)",
    "REPRO020": "bare key literal contains '::' (KV namespace separator)",
    "REPRO021": "builtin hash() on a key/seed (PYTHONHASHSEED lottery)",
}

# Whole-file grandfathering: path suffix (POSIX) -> exempted rules.
# Only for files that *implement* the substrate or the analysis itself.
ALLOW: dict[str, frozenset[str]] = {
    # The clock implementations are made of time.*/threading — that is
    # the one place wall-clock belongs.
    "core/simclock.py": frozenset({"REPRO001"}),
    # kvstore.py owns NAMESPACE_SEP and the '::' composition helpers.
    "core/kvstore.py": frozenset({"REPRO020"}),
    # The linter talks about the patterns it detects.
    "analysis/effects.py": frozenset(ALL_RULES),
}

# Directories (relative to the repro package root) inside the
# determinism boundary. Everything else only gets the key-hygiene rules.
ACTOR_DIRS = ("core", "platform", "apps", "analysis")

_DETERMINISM_RULES = frozenset(
    {"REPRO001", "REPRO002", "REPRO010", "REPRO011", "REPRO012", "REPRO013"})

_PRAGMA = re.compile(r"#\s*lint:\s*allow\(([\w\s,*]+)\)")

_WALLCLOCK_TIME_FNS = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns", "perf_counter",
    "perf_counter_ns", "sleep", "thread_time", "process_time",
})
_WALLCLOCK_DATETIME_FNS = frozenset({"now", "utcnow", "today"})

# random-module functions drawing from the shared global generator.
_GLOBAL_RANDOM_FNS = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "vonmisesvariate", "paretovariate",
    "weibullvariate", "lognormvariate", "getrandbits", "randbytes",
    "seed",
})

# Blocking wrappers on the sharded KV store: each is
# ``run_effects(clock, <name>_g(...))`` and must never run inside a
# generator frame (the frame-side effect primitives raise RuntimeError).
_BLOCKING_KV_METHODS = frozenset({
    "put", "get", "mget", "publish", "put_if_absent",
    "increment_dependency", "deposit_and_increment", "register_counter",
    "register_counters", "journal_append", "journal_scan",
})
# Receivers the blocking-wrapper rule believes are KV stores: a bare
# name or terminal attribute exactly matching one of these.
_KV_RECEIVER_NAMES = frozenset({"kv", "kvstore", "store"})

# "lock"/"mutex" suffix, but not "clock"/"block" (task_clock is a
# charge context manager, not a lock).
_LOCKISH = re.compile(r"(?<![cb])(lock|mutex)s?$", re.IGNORECASE)

# Threading synchronisation constructors: a class assigning one of these
# to a self attribute declares its state *shared across actors/threads*,
# which is what brings its ``*_g`` methods under REPRO010. Effect lanes
# (``clock.lock()``) are not in this set — lane discipline is tracked
# separately via ``yield ("acquire", ...)`` / ``.release()``.
_LOCK_CTORS = frozenset(
    {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"})


def _class_owns_threading_lock(cls: ast.ClassDef) -> bool:
    """Does this class assign a threading lock to an instance attribute?"""
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _terminal_name(node.value.func) in _LOCK_CTORS:
            if any(isinstance(t, ast.Attribute) for t in node.targets):
                return True
    return False


def _terminal_name(node: ast.AST) -> str:
    """The rightmost identifier of a Name/Attribute chain ('' otherwise)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _is_lockish(node: ast.AST) -> bool:
    """Heuristic: does this expression denote a threading lock?"""
    name = _terminal_name(node)
    if name:
        return bool(_LOCKISH.search(name))
    if isinstance(node, ast.Call):
        return _is_lockish(node.func)
    return False


def _contains_yield(node: ast.AST) -> bool:
    """Yield/YieldFrom anywhere under ``node``, not crossing into nested
    function/class definitions."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
            continue
        if isinstance(child, (ast.Yield, ast.YieldFrom)):
            return True
        if _contains_yield(child):
            return True
    return False


def _is_flush_yield(stmt: ast.stmt) -> bool:
    """``yield ("flush",)`` as a bare expression statement."""
    if not isinstance(stmt, ast.Expr) or not isinstance(stmt.value, ast.Yield):
        return False
    val = stmt.value.value
    return (isinstance(val, ast.Tuple) and val.elts
            and isinstance(val.elts[0], ast.Constant)
            and val.elts[0].value == "flush")


def _is_acquire_yield(stmt: ast.stmt) -> bool:
    """``yield ("acquire", lane)`` as a bare expression statement."""
    if not isinstance(stmt, ast.Expr) or not isinstance(stmt.value, ast.Yield):
        return False
    val = stmt.value.value
    return (isinstance(val, ast.Tuple) and val.elts
            and isinstance(val.elts[0], ast.Constant)
            and val.elts[0].value == "acquire")


def _is_release_call(stmt: ast.stmt) -> bool:
    """``<lane>.release()`` as a statement."""
    return (isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call)
            and isinstance(stmt.value.func, ast.Attribute)
            and stmt.value.func.attr == "release")


def _self_mutation_target(stmt: ast.stmt, self_name: str) -> "ast.AST | None":
    """The ``self.attr`` / ``self.attr[...]`` target this statement
    mutates, if any."""
    targets: list[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target] if stmt.target is not None else []
    for t in targets:
        node = t
        while isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == self_name:
            return t
    return None


class _ModuleLint(ast.NodeVisitor):
    """One pass over one module: expression-level rules + the
    statement-ordered generator-discipline walk per function."""

    def __init__(self, rel: str, rules: frozenset[str]):
        self.rel = rel
        self.rules = rules
        self.findings: list[Finding] = []
        # local alias -> module ("time" / "datetime" / "random")
        self.module_aliases: dict[str, str] = {}
        # local name -> (module, original function name) for from-imports
        self.from_imports: dict[str, tuple[str, str]] = {}
        self._doc_strings: set[int] = set()  # lineno of bare string stmts
        # enclosing-class stack: True where the class owns a threading
        # lock (its instances are shared, so REPRO010 applies).
        self._class_locks: list[bool] = []

    # -- plumbing -----------------------------------------------------------
    def report(self, rule: str, node: ast.AST, message: str) -> None:
        if rule in self.rules:
            self.findings.append(Finding(
                rule=rule, path=self.rel,
                line=getattr(node, "lineno", 0),
                col=getattr(node, "col_offset", 0) + 1,
                message=message))

    # -- imports ------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            root = alias.name.split(".")[0]
            if root in ("time", "datetime", "random"):
                self.module_aliases[alias.asname or root] = root
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module in ("time", "datetime", "random"):
            for alias in node.names:
                self.from_imports[alias.asname or alias.name] = (
                    node.module, alias.name)
        self.generic_visit(node)

    # -- expression-level rules ---------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        self._check_wallclock(node)
        self._check_random(node)
        if isinstance(node.func, ast.Name) and node.func.id == "hash" \
                and node.args:
            self.report(
                "REPRO021", node,
                "builtin hash() is salted per process (PYTHONHASHSEED); "
                "hash placement/fault seeds with zlib.crc32 instead")
        self.generic_visit(node)

    def _check_wallclock(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            base = fn.value
            # time.<fn>() via "import time"
            if isinstance(base, ast.Name) and \
                    self.module_aliases.get(base.id) == "time" and \
                    fn.attr in _WALLCLOCK_TIME_FNS:
                self.report(
                    "REPRO001", node,
                    f"time.{fn.attr}() in actor code; durations and "
                    f"deadlines must come from the engine clock")
                return
            # datetime.datetime.now() / datetime.date.today()
            if fn.attr in _WALLCLOCK_DATETIME_FNS:
                if isinstance(base, ast.Attribute) and \
                        isinstance(base.value, ast.Name) and \
                        self.module_aliases.get(base.value.id) == "datetime":
                    self.report(
                        "REPRO001", node,
                        f"datetime wall-clock read ({fn.attr}) in actor "
                        f"code; use clock.now_ms()")
                    return
                # "from datetime import datetime" -> datetime.now()
                if isinstance(base, ast.Name) and \
                        self.from_imports.get(base.id, ("", ""))[0] == \
                        "datetime":
                    self.report(
                        "REPRO001", node,
                        f"datetime wall-clock read ({fn.attr}) in actor "
                        f"code; use clock.now_ms()")
                    return
        elif isinstance(fn, ast.Name):
            mod, orig = self.from_imports.get(fn.id, ("", ""))
            if mod == "time" and orig in _WALLCLOCK_TIME_FNS:
                self.report(
                    "REPRO001", node,
                    f"time.{orig}() in actor code; durations and deadlines "
                    f"must come from the engine clock")

    def _check_random(self, node: ast.Call) -> None:
        fn = node.func
        unseeded_ctor = False
        global_fn = ""
        if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name) \
                and self.module_aliases.get(fn.value.id) == "random":
            if fn.attr in _GLOBAL_RANDOM_FNS:
                global_fn = fn.attr
            elif fn.attr in ("Random", "SystemRandom") and not node.args:
                unseeded_ctor = True
        elif isinstance(fn, ast.Name):
            mod, orig = self.from_imports.get(fn.id, ("", ""))
            if mod == "random":
                if orig in _GLOBAL_RANDOM_FNS:
                    global_fn = orig
                elif orig in ("Random", "SystemRandom") and not node.args:
                    unseeded_ctor = True
        if global_fn:
            self.report(
                "REPRO002", node,
                f"random.{global_fn}() draws from the shared unseeded "
                f"global generator; use random.Random(zlib.crc32(token))")
        elif unseeded_ctor:
            self.report(
                "REPRO002", node,
                "random.Random() without a seed is nondeterministic "
                "across runs; seed it with zlib.crc32(token)")

    def visit_Expr(self, node: ast.Expr) -> None:
        # Bare string statements are documentation: exempt from the
        # '::' key-hygiene rule (RST uses '::' constantly).
        if isinstance(node.value, ast.Constant) and \
                isinstance(node.value.value, str):
            self._doc_strings.add(node.value.lineno)
        self.generic_visit(node)

    def visit_Constant(self, node: ast.Constant) -> None:
        if isinstance(node.value, str) and "::" in node.value and \
                node.lineno not in self._doc_strings:
            self.report(
                "REPRO020", node,
                "bare key literal contains '::' (the KV namespace "
                "separator); compose namespaced keys with NAMESPACE_SEP "
                "via kvstore helpers, or the key's shard placement will "
                "silently change")
        self.generic_visit(node)

    # -- generator discipline ------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_locks.append(_class_owns_threading_lock(node))
        self.generic_visit(node)
        self._class_locks.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_generator(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def _check_generator(self, fn: ast.FunctionDef) -> None:
        is_gen = _contains_yield(fn)
        if not is_gen:
            return
        self_name = fn.args.args[0].arg if fn.args.args else ""
        # REPRO010 only bites where interleaving frames can actually
        # race: methods of classes that declare shared state by owning a
        # threading lock. Frame-confined hosts (one actor drives every
        # generator of the instance) mutate freely at any point.
        shared_host = bool(self._class_locks and self._class_locks[-1])
        effect_gen = fn.name.endswith("_g") and shared_host
        state = _GenState()
        self._walk_statements(fn.body, fn, state, self_name, effect_gen,
                              lock_depth=0)

    def _walk_statements(self, body: list[ast.stmt], fn: ast.FunctionDef,
                         state: "_GenState", self_name: str,
                         effect_gen: bool, lock_depth: int) -> None:
        for i, stmt in enumerate(body):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # nested defs are linted on their own visit

            # REPRO010: self-state mutation after the first yield in a
            # *_g effect generator, with no lock held (neither a with-
            # lock nor an effect-lane acquired via yield ("acquire",)).
            if effect_gen and state.yielded and lock_depth == 0 \
                    and not state.effect_lock_held:
                target = _self_mutation_target(stmt, self_name)
                if target is not None:
                    self.report(
                        "REPRO010", stmt,
                        f"{fn.name} mutates host state "
                        f"({ast.unparse(target)}) after its first yield "
                        f"without holding a lock; another frame may "
                        f"interleave at every yield — mutate before the "
                        f"first yield or under a lock")

            # REPRO012: blocking KV wrapper inside a generator frame.
            for call in self._calls_in(stmt):
                cfn = call.func
                if isinstance(cfn, ast.Attribute) and \
                        cfn.attr in _BLOCKING_KV_METHODS and \
                        _terminal_name(cfn.value) in _KV_RECEIVER_NAMES:
                    self.report(
                        "REPRO012", call,
                        f"blocking kv.{cfn.attr}(...) inside generator "
                        f"{fn.name}; it re-enters run_effects (RuntimeError "
                        f"inside an event frame) — use "
                        f"'yield from kv.{cfn.attr}_g(...)'")

            if isinstance(stmt, ast.With):
                lockish = any(_is_lockish(item.context_expr)
                              for item in stmt.items)
                task_clockish = any(
                    isinstance(item.context_expr, ast.Call)
                    and _terminal_name(item.context_expr.func) == "task_clock"
                    for item in stmt.items)
                if lockish and _contains_yield(stmt):
                    # REPRO011: the frame would suspend holding an OS
                    # lock; on the event substrate every other frame
                    # shares this driver thread — deadlock.
                    self.report(
                        "REPRO011", stmt,
                        f"lock held across a yield in {fn.name}; a parked "
                        f"frame keeps the OS lock taken — use the clock's "
                        f"effect lock (yield (\"acquire\", lane) / "
                        f"lane.release()) instead")
                if task_clockish:
                    # REPRO013: the statement after the task_clock block
                    # must flush deferred compute charges.
                    nxt = body[i + 1] if i + 1 < len(body) else None
                    if nxt is None or not _is_flush_yield(nxt):
                        self.report(
                            "REPRO013", stmt,
                            f"task_clock block in {fn.name} not followed "
                            f"by yield (\"flush\",); compute charged "
                            f"inside the task is deferred on the event "
                            f"substrate and must be flushed before "
                            f"reading the clock")
                self._walk_statements(
                    stmt.body, fn, state, self_name, effect_gen,
                    lock_depth + (1 if lockish else 0))
                if _contains_yield(stmt):
                    state.yielded = True
                continue

            if _is_acquire_yield(stmt):
                state.effect_lock_held = True
                state.yielded = True
                continue
            if _is_release_call(stmt):
                state.effect_lock_held = False
                continue

            # Recurse into compound statements, threading the yielded
            # flag: a yield anywhere in a loop body makes every
            # statement of that body "after a yield" (second iteration).
            for sub in self._sub_bodies(stmt):
                if isinstance(stmt, (ast.For, ast.While)) and \
                        _contains_yield(stmt):
                    state.yielded = True
                self._walk_statements(sub, fn, state, self_name,
                                      effect_gen, lock_depth)
            if _contains_yield(stmt):
                state.yielded = True

    @staticmethod
    def _sub_bodies(stmt: ast.stmt) -> list[list[ast.stmt]]:
        bodies: list[list[ast.stmt]] = []
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub:
                bodies.append(sub)
        for handler in getattr(stmt, "handlers", ()):
            bodies.append(handler.body)
        return bodies

    @staticmethod
    def _calls_in(stmt: ast.stmt) -> Iterable[ast.Call]:
        """Calls in this statement's OWN expressions — compound
        statements contribute only their headers (their nested bodies
        are walked by the statement loop itself, which would otherwise
        double-report)."""
        if isinstance(stmt, (ast.If, ast.While)):
            exprs: list[ast.AST] = [stmt.test]
        elif isinstance(stmt, ast.For):
            exprs = [stmt.iter]
        elif isinstance(stmt, ast.With):
            exprs = [item.context_expr for item in stmt.items]
        elif isinstance(stmt, ast.Try):
            exprs = []
        else:
            exprs = [stmt]
        for e in exprs:
            for node in ast.walk(e):
                if isinstance(node, ast.Call):
                    yield node


class _GenState:
    __slots__ = ("yielded", "effect_lock_held")

    def __init__(self) -> None:
        self.yielded = False
        self.effect_lock_held = False


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


# Top-level dirs of the repro package, for resolving lint roots that
# point inside it (``--check src/repro`` yields paths like "core/dag.py"
# with no "repro" component to anchor on).
_REPRO_TOP_DIRS = frozenset({
    "core", "platform", "apps", "analysis", "runtime", "launch", "models",
    "kernels", "optim", "data", "configs",
})


def _rules_for(rel: str) -> frozenset[str]:
    """The rule set applying to ``rel`` (repo-relative POSIX path)."""
    parts = rel.split("/")
    if "repro" in parts:
        sub = parts[parts.index("repro") + 1:]
    elif parts and parts[0] in _REPRO_TOP_DIRS:
        sub = parts
    else:
        sub = None  # unknown tree (e.g. test fixtures): every rule applies
    rules = frozenset(ALL_RULES)
    if sub is not None and (not sub or sub[0] not in ACTOR_DIRS):
        # Outside the simulation substrate: key hygiene only.
        rules = rules - _DETERMINISM_RULES
    for suffix, exempt in ALLOW.items():
        if rel.endswith(suffix):
            rules = rules - exempt
    return rules


def lint_source(source: str, rel: str,
                rules: "frozenset[str] | None" = None) -> list[Finding]:
    """Lint one module's source text; ``rel`` is its repo-relative path
    (drives rule scoping and finding locations)."""
    if rules is None:
        rules = _rules_for(rel)
    if not rules:
        return []
    tree = ast.parse(source, filename=rel)
    lint = _ModuleLint(rel, rules)
    lint.visit(tree)
    lines = source.splitlines()
    out: list[Finding] = []
    for f in lint.findings:
        snippet = lines[f.line - 1].strip() if 0 < f.line <= len(lines) else ""
        m = _PRAGMA.search(snippet)
        if m:
            allowed = {r.strip() for r in m.group(1).split(",")}
            if "*" in allowed or f.rule in allowed:
                continue
        out.append(Finding(rule=f.rule, path=f.path, line=f.line, col=f.col,
                           message=f.message, snippet=snippet))
    return out


def lint_file(path: "str | Path", root: "str | Path | None" = None) \
        -> list[Finding]:
    p = Path(path)
    rel = p.relative_to(root).as_posix() if root is not None else p.as_posix()
    return lint_source(p.read_text(), rel)


def lint_tree(root: "str | Path") -> list[Finding]:
    """Lint every ``*.py`` under ``root`` (deterministic order)."""
    rootp = Path(root)
    findings: list[Finding] = []
    for p in sorted(rootp.rglob("*.py")):
        findings.extend(lint_file(p, rootp))
    return findings
