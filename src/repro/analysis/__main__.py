"""CLI: ``python -m repro.analysis --check <path> [...]``.

Runs the effect-protocol lint over every ``*.py`` under the given
paths (default: the installed ``repro`` package sources), emits the
findings as JSON on stdout, and exits non-zero if any finding is not
grandfathered by the baseline.

Baseline workflow::

    python -m repro.analysis --check src                  # gate (CI)
    python -m repro.analysis --check src --write-baseline # grandfather
    python -m repro.analysis --explain                    # rule list

The baseline default is ``analysis-baseline.json`` in the current
directory (the repo checks in an empty one: the shipped tree has zero
grandfathered findings, and the file documents the workflow).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.effects import ALL_RULES, lint_file, lint_tree
from repro.analysis.findings import load_baseline, new_findings, write_baseline

DEFAULT_BASELINE = "analysis-baseline.json"


def main(argv: "list[str] | None" = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Determinism / effect-protocol static analysis.")
    parser.add_argument(
        "--check", nargs="+", metavar="PATH", default=None,
        help="files or directories to lint (default: the repro package "
             "sources)")
    parser.add_argument(
        "--baseline", default=DEFAULT_BASELINE, metavar="FILE",
        help=f"grandfathered-findings file (default: {DEFAULT_BASELINE}; "
             f"a missing file is an empty baseline)")
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write the current findings to the baseline file and exit 0")
    parser.add_argument(
        "--explain", action="store_true",
        help="list the rules and exit")
    args = parser.parse_args(argv)

    if args.explain:
        for rule, desc in sorted(ALL_RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    if args.check is None:
        import repro

        roots = [Path(repro.__file__).parent]
    else:
        roots = [Path(p) for p in args.check]

    findings = []
    checked = 0
    for root in roots:
        if root.is_dir():
            findings.extend(lint_tree(root))
            checked += sum(1 for _ in root.rglob("*.py"))
        elif root.exists():
            findings.extend(lint_file(root, root.parent))
            checked += 1
        else:
            print(f"error: no such path {root}", file=sys.stderr)
            return 2

    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}",
              file=sys.stderr)
        return 0

    baseline = load_baseline(args.baseline)
    new = new_findings(findings, baseline)
    json.dump(
        {
            "checked_files": checked,
            "findings": [f.to_json() for f in findings],
            "new": [f.to_json() for f in new],
            "grandfathered": len(findings) - len(new),
        },
        sys.stdout, indent=2)
    print()
    for f in new:
        print(str(f), file=sys.stderr)
    if new:
        print(f"{len(new)} new finding(s) not in baseline ({args.baseline})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
