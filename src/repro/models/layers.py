"""Transformer building blocks: norm, RoPE, GQA attention, dense/MoE MLP.

Everything is functional: ``init_*`` returns ``(params, specs)`` where
``specs`` mirrors the params pytree with tuples of *logical axis names*
(resolved to mesh axes by ``repro.runtime.sharding``). Layer ``apply``
functions are pure and jit/scan/shard_map friendly.

Attention dispatches to the Pallas flash kernel when
``cfg.use_pallas=True`` (TPU target); the default pure-jnp path is the
oracle and the CPU/dry-run path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = dict[str, Any]

# Logical axis names (see runtime/sharding.py for the mesh mapping)
VOCAB, EMBED, HEADS, KV, HD, FF, EXPERTS, LAYERS, INNER, STATE = (
    "vocab", "embed", "heads", "kv_heads", "head_dim", "ff", "experts",
    "layers", "inner", "state",
)


def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(cfg: ModelConfig) -> tuple[Params, Params]:
    p = {"scale": jnp.ones((cfg.d_model,), dtype=jnp.float32)}
    s = {"scale": (EMBED,)}
    return p, s


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False
                   ) -> tuple[Params, Params]:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p: Params = {
        "wq": _init(ks[0], (d, H * hd), scale, dt),
        "wk": _init(ks[1], (d, K * hd), scale, dt),
        "wv": _init(ks[2], (d, K * hd), scale, dt),
        "wo": _init(ks[3], (H * hd, d), (H * hd) ** -0.5, dt),
    }
    s: Params = {
        "wq": (EMBED, HEADS),
        "wk": (EMBED, KV),
        "wv": (EMBED, KV),
        "wo": (HEADS, EMBED),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype=dt)
        p["bk"] = jnp.zeros((K * hd,), dtype=dt)
        p["bv"] = jnp.zeros((K * hd,), dtype=dt)
        s["bq"], s["bk"], s["bv"] = (HEADS,), (KV,), (KV,)
    return p, s


def _project_qkv(p: Params, xq: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(B, Sq, H, hd),
        k.reshape(B, Skv, K, hd),
        v.reshape(B, Skv, K, hd),
    )


def sdpa(
    q: jax.Array,                # (B, Sq, H, hd)
    k: jax.Array,                # (B, Skv, K, hd)
    v: jax.Array,                # (B, Skv, K, hd)
    *,
    causal: bool,
    window: int | None = None,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,   # valid prefix length (decode)
) -> jax.Array:
    """Grouped-query scaled-dot-product attention, pure-jnp oracle path.

    Computes in fp32 for the softmax, returns q.dtype. ``q_offset`` is the
    absolute position of q[0] (decode/prefill continuation). ``kv_len``
    masks the KV tail (preallocated decode caches).
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)

    qpos = jnp.arange(Sq) + q_offset            # (Sq,)
    kpos = jnp.arange(Skv)                      # (Skv,)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def attention(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    positions: jax.Array | None = None,
    xkv: jax.Array | None = None,     # cross attention source
    use_rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    src = x if xkv is None else xkv
    q, k, v = _project_qkv(p, x, src, cfg)
    if use_rope and xkv is None:
        pos = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q, k, v, causal=causal and xkv is None,
            window=cfg.sliding_window if xkv is None else None)
    else:
        out = sdpa(q, k, v, causal=causal and xkv is None,
                   window=cfg.sliding_window if xkv is None else None)
    return out.reshape(B, S, -1) @ p["wo"]


def attention_decode(
    p: Params,
    x: jax.Array,                # (B, 1, d)
    cache_k: jax.Array,          # (B, Smax, K, hd)
    cache_v: jax.Array,
    pos: jax.Array,              # scalar int32: index of the new token
    cfg: ModelConfig,
    *,
    use_rope: bool = True,
    rotating: bool = False,      # sliding-window rotating cache
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode step against a preallocated KV cache."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, x, cfg)
    if use_rope:
        posv = jnp.full((1,), pos)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    Smax = cache_k.shape[1]
    slot = jnp.where(jnp.asarray(rotating), pos % Smax, jnp.minimum(pos, Smax - 1))
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)
    if rotating:
        kv_len = jnp.minimum(pos + 1, Smax)
        out = sdpa(q, cache_k, cache_v, causal=False, kv_len=kv_len)
    else:
        out = sdpa(q, cache_k, cache_v, causal=False, kv_len=pos + 1)
    return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v


def attention_cross_decode(
    p: Params, x: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
) -> jax.Array:
    """Cross-attention during decode: static encoder KV cache."""
    B = x.shape[0]
    H, K, hd = x.shape, None, None  # silence linters
    q = (x @ p["wq"]).reshape(B, 1, -1, cache_k.shape[-1])
    out = sdpa(q, cache_k, cache_v, causal=False)
    return out.reshape(B, 1, -1) @ p["wo"]


# --------------------------------------------------------------------------
# Dense MLP (SwiGLU / squared-ReLU / GELU)
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> tuple[Params, Params]:
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        p = {
            "w_gate": _init(ks[0], (d, f), d ** -0.5, dt),
            "w_up": _init(ks[1], (d, f), d ** -0.5, dt),
            "w_down": _init(ks[2], (f, d), f ** -0.5, dt),
        }
        s = {"w_gate": (EMBED, FF), "w_up": (EMBED, FF), "w_down": (FF, EMBED)}
    else:
        p = {
            "w_up": _init(ks[0], (d, f), d ** -0.5, dt),
            "w_down": _init(ks[1], (f, d), f ** -0.5, dt),
        }
        s = {"w_up": (EMBED, FF), "w_down": (FF, EMBED)}
    return p, s


def mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-based GShard-style dispatch)
# --------------------------------------------------------------------------

MOE_GROUP = 2048          # tokens per dispatch group (bounds dispatch FLOPs)


def init_moe(key, cfg: ModelConfig) -> tuple[Params, Params]:
    assert cfg.moe is not None
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": _init(ks[0], (d, E), d ** -0.5, jnp.float32),
        "w_gate": _init(ks[1], (E, d, f), d ** -0.5, dt),
        "w_up": _init(ks[2], (E, d, f), d ** -0.5, dt),
        "w_down": _init(ks[3], (E, f, d), f ** -0.5, dt),
    }
    s = {
        "router": (EMBED, None),
        "w_gate": (EXPERTS, EMBED, FF),
        "w_up": (EXPERTS, EMBED, FF),
        "w_down": (EXPERTS, FF, EMBED),
    }
    return p, s


def moe_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k MoE with capacity-based dispatch (GShard/Switch style).

    Tokens are processed in groups of MOE_GROUP so the one-hot dispatch
    einsum stays O(S·group·d) instead of O(S²·d). Overflow tokens beyond
    expert capacity are dropped (standard TPU practice; capacity factor
    1.25).
    """
    assert cfg.moe is not None
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    B, S, d = x.shape
    g = min(cfg.moe_group, S)
    assert S % g == 0, (S, g)
    n_groups = S // g
    xg = x.reshape(B * n_groups, g, d)
    cap = max(1, int(k * g * cfg.moe_capacity_factor / E))

    logits = (xg.astype(jnp.float32) @ p["router"])        # (G, g, E)
    weights, chosen = jax.lax.top_k(logits, k)             # (G, g, k)
    weights = jax.nn.softmax(weights, axis=-1)

    onehot = jax.nn.one_hot(chosen, E, dtype=jnp.float32)  # (G, g, k, E)
    # position of each assignment within its expert's queue, counted over
    # the flattened (token, slot) order so no two assignments share a slot
    G_ = onehot.shape[0]
    flat = onehot.reshape(G_, g * k, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat
    pos_in_expert = jnp.einsum("gske,gske->gsk",
                               pos_flat.reshape(G_, g, k, E), onehot)
    keep = pos_in_expert < cap                              # (G, g, k)
    weights = weights * keep.astype(weights.dtype)

    cap_onehot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), cap,
                                dtype=jnp.float32)          # (G, g, k, C)
    # dispatch: (G, g, k, E) x (G, g, k, C) -> (G, g, E, C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot,
                          cap_onehot * keep[..., None].astype(jnp.float32))
    combine = jnp.einsum("gsk,gske,gskc->gsec", weights, onehot, cap_onehot)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)  # (G,E,C,d)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("gecd,edf->gecf", xe, p["w_up"])))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])       # (G,E,C,d)
    yg = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    return yg.reshape(B, S, d)
