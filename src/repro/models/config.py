"""Unified model configuration covering all assigned architecture families.

A model is a cycle of block *patterns*. Each pattern entry names a mixer
and an MLP type, e.g. ``"attn+moe"`` (Mixtral), ``"mamba+dense"`` (Jamba),
``"mlstm"`` (xLSTM — no separate FFN). Layers are stacked per pattern
position so ``jax.lax.scan`` can run the repeated super-block with one
lowered copy of the layer HLO (critical for compile time and HLO size at
126 layers).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block_pattern: tuple[str, ...] = ("attn+dense",)
    head_dim: int | None = None
    moe: MoEConfig | None = None
    sliding_window: int | None = None
    qkv_bias: bool = False
    activation: str = "swiglu"  # swiglu | squared_relu | gelu
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500      # whisper encoder positions (stub frontend)
    frontend: str | None = None  # None | "audio_stub" | "vision_stub"
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # SSM / recurrent dims
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    mlstm_proj_factor: float = 2.0
    moe_capacity_factor: float = 1.25
    moe_group: int = 2048       # tokens per MoE dispatch group
    # training
    remat: bool = True
    scan_layers: bool = True    # False: unroll (exact HLO cost analysis)
    use_pallas: bool = False    # Pallas kernels on TPU; pure-jnp oracle off

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % self.pattern_period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {self.pattern_period}"
        )
        return self.n_layers // self.pattern_period

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    def mixer_of(self, entry: str) -> str:
        return entry.split("+")[0]

    def mlp_of(self, entry: str) -> str | None:
        parts = entry.split("+")
        return parts[1] if len(parts) > 1 else None

    # ---- parameter counts (for roofline MODEL_FLOPS = 6·N·D) -------------
    def param_counts(self) -> dict[str, float]:
        """Returns {"total": N, "active": N_active} (embeddings included in
        total, excluded from active FLOPs accounting which uses 6·N·D with
        N = non-embedding params, the standard convention)."""
        d, hd = self.d_model, self.hd
        per_pattern_total = 0.0
        per_pattern_active = 0.0
        for entry in self.block_pattern:
            mixer, mlp = self.mixer_of(entry), self.mlp_of(entry)
            p = 0.0
            if mixer == "attn":
                p += d * (self.n_heads * hd)            # q
                p += 2 * d * (self.n_kv_heads * hd)     # k, v
                p += (self.n_heads * hd) * d            # o
                if self.qkv_bias:
                    p += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif mixer == "mamba":
                di, n = self.d_inner, self.ssm_state_dim
                p += d * 2 * di          # in_proj (x, gate)
                p += di * self.ssm_conv_width
                p += di * (2 * n + 1) + di  # B,C,dt projections + dt bias
                p += di * n              # A
                p += di * d              # out_proj
            elif mixer == "mlstm":
                dk = int(self.mlstm_proj_factor * d)
                p += 3 * d * dk + dk * d  # q,k,v,o
                p += 2 * d * self.n_heads  # gates (i, f per head)
            elif mixer == "slstm":
                p += 4 * d * d + 4 * d * d // self.n_heads  # gates (block-diag recurrent)
            p += d  # norm
            mlp_total = mlp_active = 0.0
            if mlp == "dense":
                mult = 3 if self.activation == "swiglu" else 2
                mlp_total = mlp_active = mult * d * self.d_ff + d
            elif mlp == "moe":
                assert self.moe is not None
                mult = 3 if self.activation == "swiglu" else 2
                per_expert = mult * d * self.d_ff
                mlp_total = self.moe.n_experts * per_expert + d * self.moe.n_experts + d
                mlp_active = self.moe.top_k * per_expert + d * self.moe.n_experts + d
            per_pattern_total += p + mlp_total
            per_pattern_active += p + mlp_active
        total = per_pattern_total * self.n_repeats
        active = per_pattern_active * self.n_repeats
        if self.enc_dec:
            # encoder: full-attn + dense mlp, plus decoder cross-attn
            enc_block = (2 * d * (self.n_heads * hd) * 2) / 2  # q,k,v,o approx
            enc_block = d * self.n_heads * hd * 2 + 2 * d * self.n_kv_heads * hd
            mult = 2  # gelu
            enc_block += mult * d * self.d_ff + 2 * d
            total += enc_block * self.n_enc_layers
            active += enc_block * self.n_enc_layers
            cross = 2 * d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + d
            total += cross * self.n_layers
            active += cross * self.n_layers
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return {"total": total + embed, "active": active,
                "embed": float(embed)}

    def model_flops_per_token(self) -> float:
        """6·N_active per token (the §Roofline MODEL_FLOPS convention)."""
        return 6.0 * self.param_counts()["active"]


def human(n: float) -> str:
    for unit in ["", "K", "M", "B", "T"]:
        if abs(n) < 1000:
            return f"{n:.1f}{unit}"
        n /= 1000
    return f"{n:.1f}P"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""

    name: str               # train_4k | prefill_32k | decode_32k | long_500k
    kind: str               # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def sub_quadratic(cfg: ModelConfig) -> bool:
    """True if the arch can decode at 500k tokens with bounded state:
    SSM/linear-recurrent state, or sliding-window attention, or a hybrid
    with only windowed/sparse attention layers."""
    if cfg.enc_dec:
        return False
    mixers = {cfg.mixer_of(e) for e in cfg.block_pattern}
    if "attn" not in mixers:
        return True
    return cfg.sliding_window is not None or cfg.family in ("hybrid",)


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    out = ["train_4k", "prefill_32k"]
    out.append("decode_32k")  # all assigned archs have a decoder step
    if sub_quadratic(cfg):
        out.append("long_500k")
    return out
