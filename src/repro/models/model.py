"""Unified LM: init / forward / loss / decode for every assigned family.

Layer stacking: parameters for each *pattern position* are stacked over
``n_repeats`` along a leading "layers" axis and the repeated super-block
runs under ``jax.lax.scan`` — one lowered copy of the block HLO regardless
of depth (126-layer llama3-405b lowers as fast as 2 layers), and remat
applies per scan step.

Decode carries an explicit cache pytree (KV pages for attention, conv/ssm
state for Mamba, matrix state for mLSTM, scalar state for sLSTM), scanned
with the same stacking.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    EMBED,
    HEADS,
    INNER,
    KV,
    LAYERS,
    STATE,
    VOCAB,
    Params,
    attention,
    attention_decode,
    dtype_of,
    init_attention,
    init_mlp,
    init_moe,
    init_rmsnorm,
    mlp,
    moe_mlp,
    rmsnorm,
    sdpa,
)

MAX_ABS_POS = 32768  # learned-position table for enc-dec (whisper decoder)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_mixer(key, mixer: str, cfg: ModelConfig) -> tuple[Params, Params]:
    if mixer == "attn":
        return init_attention(key, cfg)
    if mixer == "mamba":
        return ssm.init_mamba(key, cfg)
    if mixer == "mlstm":
        return ssm.init_mlstm(key, cfg)
    if mixer == "slstm":
        return ssm.init_slstm(key, cfg)
    raise ValueError(mixer)


def _init_block(key, entry: str, cfg: ModelConfig,
                cross: bool) -> tuple[Params, Params]:
    mixer, mlp_kind = cfg.mixer_of(entry), cfg.mlp_of(entry)
    ks = jax.random.split(key, 4)
    p: Params = {}
    s: Params = {}
    p["norm1"], s["norm1"] = init_rmsnorm(cfg)
    p["mixer"], s["mixer"] = _init_mixer(ks[0], mixer, cfg)
    if mlp_kind == "dense":
        p["norm2"], s["norm2"] = init_rmsnorm(cfg)
        p["mlp"], s["mlp"] = init_mlp(ks[1], cfg)
    elif mlp_kind == "moe":
        p["norm2"], s["norm2"] = init_rmsnorm(cfg)
        p["mlp"], s["mlp"] = init_moe(ks[1], cfg)
    if cross:
        p["cross_norm"], s["cross_norm"] = init_rmsnorm(cfg)
        p["cross"], s["cross"] = init_attention(ks[2], cfg, cross=True)
    return p, s


def _stack(trees: list[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def _stack_specs(spec: Any) -> Any:
    """Prepend the layers axis to every leaf spec (leaf specs are tuples)."""
    return jax.tree.map(
        lambda s: (LAYERS,) + s,
        spec,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            isinstance(e, (str, type(None))) for e in s),
    )


def init_model(key, cfg: ModelConfig) -> tuple[Params, Params]:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, cfg.n_layers + cfg.n_enc_layers + 8)
    ki = iter(range(len(ks)))
    p: Params = {}
    s: Params = {}

    p["embed"] = (jax.random.normal(ks[next(ki)], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt)
    s["embed"] = (VOCAB, EMBED)

    # decoder blocks, stacked per pattern position
    blocks_p, blocks_s = [], []
    for r in range(cfg.n_repeats):
        row_p = []
        for entry in cfg.block_pattern:
            bp, bs = _init_block(ks[next(ki)], entry, cfg, cross=cfg.enc_dec)
            row_p.append(bp)
            if r == 0:
                blocks_s.append(_stack_specs(bs))
        blocks_p.append(row_p)
    p["blocks"] = [
        _stack([blocks_p[r][pos] for r in range(cfg.n_repeats)])
        for pos in range(cfg.pattern_period)
    ]
    s["blocks"] = blocks_s

    if cfg.enc_dec:
        enc_p = []
        for r in range(cfg.n_enc_layers):
            bp, bs = _init_block(ks[next(ki)], "attn+dense", cfg, cross=False)
            enc_p.append(bp)
            if r == 0:
                s["enc_blocks"] = _stack_specs(bs)
        p["enc_blocks"] = _stack(enc_p)
        p["enc_norm"], s["enc_norm"] = init_rmsnorm(cfg)
        p["dec_pos"] = (jax.random.normal(
            ks[next(ki)], (MAX_ABS_POS, cfg.d_model)) * 0.02).astype(dt)
        s["dec_pos"] = (None, EMBED)

    p["final_norm"], s["final_norm"] = init_rmsnorm(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(
            ks[next(ki)], (cfg.d_model, cfg.vocab)) * 0.02).astype(dt)
        s["lm_head"] = (EMBED, VOCAB)
    return p, s


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_fwd(bp: Params, x: jax.Array, entry: str, cfg: ModelConfig,
               enc_out: jax.Array | None = None) -> jax.Array:
    mixer, mlp_kind = cfg.mixer_of(entry), cfg.mlp_of(entry)
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        use_rope = not cfg.enc_dec
        y = attention(bp["mixer"], h, cfg, causal=True, use_rope=use_rope)
    elif mixer == "mamba":
        y, _ = ssm.mamba(bp["mixer"], h, cfg)
    elif mixer == "mlstm":
        y, _ = ssm.mlstm(bp["mixer"], h, cfg)
    elif mixer == "slstm":
        y, _ = ssm.slstm(bp["mixer"], h, cfg)
    else:
        raise ValueError(mixer)
    x = x + y
    if enc_out is not None:
        h = rmsnorm(bp["cross_norm"], x, cfg.norm_eps)
        x = x + attention(bp["cross"], h, cfg, causal=False, xkv=enc_out,
                          use_rope=False)
    if mlp_kind is not None:
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        y = (moe_mlp(bp["mlp"], h, cfg) if mlp_kind == "moe"
             else mlp(bp["mlp"], h, cfg))
        x = x + y
    return x


def _enc_block_fwd(bp: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    x = x + attention(bp["mixer"], h, cfg, causal=False, use_rope=False)
    h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
    return x + mlp(bp["mlp"], h, cfg)


def _scan_blocks(params_stacked: Any, x: jax.Array, fwd) -> jax.Array:
    """scan a stacked block; ``fwd(block_params, x) -> x``."""

    def step(carry, bp):
        out = fwd(bp, carry)
        return out, None

    x, _ = jax.lax.scan(step, x, params_stacked)
    return x


def _scan_superblocks(p: Params, cfg: ModelConfig, x: jax.Array,
                      enc_out: jax.Array | None) -> jax.Array:
    """scan over n_repeats; each step applies the whole block pattern in
    order (preserves e.g. Jamba's 1:7 mamba:attn interleave)."""

    def superblock(carry, bps):
        h = carry
        for pos, entry in enumerate(cfg.block_pattern):
            h = _block_fwd(bps[pos], h, entry, cfg, enc_out)
        return h, None

    f = jax.checkpoint(superblock) if cfg.remat else superblock
    if cfg.scan_layers:
        x, _ = jax.lax.scan(f, x, tuple(p["blocks"]))
    else:  # unrolled: exact HLO-level cost analysis (dry-run roofline)
        for r in range(cfg.n_repeats):
            bps = jax.tree.map(lambda t: t[r], tuple(p["blocks"]))
            x, _ = f(x, bps)
    return x


def encode(p: Params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed (stub) frame embeddings."""
    fwd = _enc_block_fwd
    if cfg.remat:
        fwd = jax.checkpoint(fwd, static_argnums=(2,))
    if cfg.scan_layers:
        x = _scan_blocks(p["enc_blocks"], enc_embeds,
                         lambda bp, h: fwd(bp, h, cfg))
    else:
        x = enc_embeds
        for r in range(cfg.n_enc_layers):
            bp = jax.tree.map(lambda t: t[r], p["enc_blocks"])
            x = fwd(bp, x, cfg)
    return rmsnorm(p["enc_norm"], x, cfg.norm_eps)


def forward(
    p: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                    # (B, S) int32
    enc_embeds: jax.Array | None = None,  # (B, F, d) stub frontend
) -> jax.Array:
    """Token logits for training / prefill. Returns (B, S, vocab)."""
    x = p["embed"][tokens].astype(dtype_of(cfg))
    enc_out = None
    if cfg.enc_dec:
        assert enc_embeds is not None
        enc_out = encode(p, cfg, enc_embeds.astype(dtype_of(cfg)))
        S = tokens.shape[1]
        x = x + p["dec_pos"][:S][None]

    x = _scan_superblocks(p, cfg, x, enc_out)

    x = rmsnorm(p["final_norm"], x, cfg.norm_eps)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return (x @ head).astype(jnp.float32)


def loss_fn(
    p: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    enc_embeds: jax.Array | None = None,
) -> jax.Array:
    logits = forward(p, cfg, tokens, enc_embeds)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    ce = (logz - gold).mean()
    zloss = 1e-4 * jnp.square(logz).mean()   # logit drift regularizer
    return ce + zloss


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               abstract: bool = False) -> Any:
    """Decode-state pytree. One entry per pattern position, leaves stacked
    over n_repeats. ``abstract=True`` returns ShapeDtypeStructs (dry-run)."""
    R = cfg.n_repeats
    K, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype=dtype)

    cache: list[dict[str, Any]] = []
    for entry in cfg.block_pattern:
        mixer = cfg.mixer_of(entry)
        c: dict[str, Any] = {}
        if mixer == "attn":
            S = _attn_cache_len(cfg, seq_len)
            c["k"] = mk((R, batch, S, K, hd), dt)
            c["v"] = mk((R, batch, S, K, hd), dt)
        elif mixer == "mamba":
            c["conv"] = mk((R, batch, cfg.ssm_conv_width - 1, cfg.d_inner), dt)
            c["ssm"] = mk((R, batch, cfg.d_inner, cfg.ssm_state_dim),
                          jnp.float32)
        elif mixer == "mlstm":
            dk = int(cfg.mlstm_proj_factor * cfg.d_model)
            hdm = dk // cfg.n_heads
            c["C"] = mk((R, batch, cfg.n_heads, hdm, hdm), jnp.float32)
            c["n"] = mk((R, batch, cfg.n_heads, hdm), jnp.float32)
        elif mixer == "slstm":
            c["c"] = mk((R, batch, cfg.d_model), jnp.float32)
            c["h"] = mk((R, batch, cfg.d_model), jnp.float32)
        if cfg.enc_dec:
            c["cross_k"] = mk((R, batch, cfg.enc_frames, K, hd), dt)
            c["cross_v"] = mk((R, batch, cfg.enc_frames, K, hd), dt)
        cache.append(c)
    return cache


def cache_specs(cfg: ModelConfig) -> list[dict[str, Any]]:
    """Logical-axis specs paralleling init_cache output."""
    specs: list[dict[str, Any]] = []
    for entry in cfg.block_pattern:
        mixer = cfg.mixer_of(entry)
        c: dict[str, Any] = {}
        if mixer == "attn":
            c["k"] = (LAYERS, "batch", "kv_seq", KV, None)
            c["v"] = (LAYERS, "batch", "kv_seq", KV, None)
        elif mixer == "mamba":
            c["conv"] = (LAYERS, "batch", None, INNER)
            c["ssm"] = (LAYERS, "batch", INNER, STATE)
        elif mixer == "mlstm":
            c["C"] = (LAYERS, "batch", HEADS, None, None)
            c["n"] = (LAYERS, "batch", HEADS, None)
        elif mixer == "slstm":
            c["c"] = (LAYERS, "batch", EMBED)
            c["h"] = (LAYERS, "batch", EMBED)
        if cfg.enc_dec:
            c["cross_k"] = (LAYERS, "batch", None, KV, None)
            c["cross_v"] = (LAYERS, "batch", None, KV, None)
        specs.append(c)
    return specs


def _block_decode(bp: Params, c: dict[str, Any], x: jax.Array,
                  pos: jax.Array, entry: str, cfg: ModelConfig
                  ) -> tuple[jax.Array, dict[str, Any]]:
    mixer, mlp_kind = cfg.mixer_of(entry), cfg.mlp_of(entry)
    newc = dict(c)
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        rotating = cfg.sliding_window is not None and \
            c["k"].shape[1] <= cfg.sliding_window
        y, k, v = attention_decode(
            bp["mixer"], h, c["k"], c["v"], pos, cfg,
            use_rope=not cfg.enc_dec, rotating=rotating)
        newc["k"], newc["v"] = k, v
    elif mixer == "mamba":
        y, (conv, st) = ssm.mamba(bp["mixer"], h, cfg,
                                  state=(c["conv"], c["ssm"]))
        newc["conv"], newc["ssm"] = conv, st
    elif mixer == "mlstm":
        y, (C, n) = ssm.mlstm_decode_step(bp["mixer"], h, cfg,
                                          (c["C"], c["n"]))
        newc["C"], newc["n"] = C, n
    elif mixer == "slstm":
        y, (cc, hh) = ssm.slstm(bp["mixer"], h, cfg, state=(c["c"], c["h"]))
        newc["c"], newc["h"] = cc, hh
    else:
        raise ValueError(mixer)
    x = x + y
    if cfg.enc_dec:
        h = rmsnorm(bp["cross_norm"], x, cfg.norm_eps)
        y = sdpa((h @ bp["cross"]["wq"]).reshape(
            x.shape[0], 1, cfg.n_heads, cfg.hd),
            c["cross_k"], c["cross_v"], causal=False)
        x = x + y.reshape(x.shape[0], 1, -1) @ bp["cross"]["wo"]
    if mlp_kind is not None:
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        y = (moe_mlp(bp["mlp"], h, cfg) if mlp_kind == "moe"
             else mlp(bp["mlp"], h, cfg))
        x = x + y
    return x, newc


def decode_step(
    p: Params,
    cfg: ModelConfig,
    cache: Any,
    token: jax.Array,          # (B,) int32 — the newest token
    pos: jax.Array,            # scalar int32 — its position
) -> tuple[jax.Array, Any]:
    """One serving step: append token at ``pos``, return next-token logits
    (B, vocab) and the updated cache."""
    x = p["embed"][token][:, None, :].astype(dtype_of(cfg))  # (B,1,d)
    if cfg.enc_dec:
        x = x + p["dec_pos"][pos][None, None, :]

    def superblock(carry, inp):
        h = carry
        bps, cs = inp
        newcs = []
        for posn, entry in enumerate(cfg.block_pattern):
            h, nc = _block_decode(bps[posn], cs[posn], h, pos, entry, cfg)
            newcs.append(nc)
        return h, tuple(newcs)

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(
            superblock, x, (tuple(p["blocks"]), tuple(cache)))
        new_cache = list(new_cache)
    else:
        ys = []
        for r in range(cfg.n_repeats):
            inp = jax.tree.map(lambda t: t[r],
                               (tuple(p["blocks"]), tuple(cache)))
            x, nc = superblock(x, inp)
            ys.append(nc)
        new_cache = list(jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ys))

    x = rmsnorm(p["final_norm"], x, cfg.norm_eps)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (x[:, 0, :] @ head).astype(jnp.float32)
    return logits, new_cache


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree of the parameters (no allocation; dry-run)."""
    return jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg)[0])


def model_specs(cfg: ModelConfig) -> Params:
    """Logical-axis spec tree paralleling ``abstract_params`` — built under
    ``eval_shape`` so no parameter memory is ever allocated."""
    cell: dict[str, Any] = {}

    def build():
        p, s = init_model(jax.random.PRNGKey(0), cfg)
        cell["specs"] = s
        return p

    jax.eval_shape(build)
    return cell["specs"]
