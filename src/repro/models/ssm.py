"""Recurrent mixers: Mamba (Jamba's SSM), mLSTM and sLSTM (xLSTM).

Design notes (hardware adaptation, see DESIGN.md):
- Mamba's selective scan is computed *chunked*: ``lax.scan`` over chunks
  of the sequence with a ``jax.lax.associative_scan`` inside each chunk.
  This bounds the materialized state history to (B, chunk, d_inner, N)
  — the TPU-friendly equivalent of the CUDA kernel's SRAM blocking.
- mLSTM uses the chunkwise-parallel form (intra-chunk decay-masked
  attention + inter-chunk carried matrix state), which maps onto the MXU
  as dense matmuls; this is also the form the Pallas linear-attention
  kernel implements.
- sLSTM has a true sequential dependency (block-diagonal recurrent gates)
  and is computed with ``lax.scan`` over time — inherently latency-bound;
  noted in DESIGN.md as the one layer that cannot be parallelized over
  sequence.

All functions carry explicit recurrent state so the same code serves
training (state=zeros, full sequence) and decode (state threaded through
steps).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import EMBED, HEADS, INNER, STATE, _init, dtype_of

Params = dict[str, Any]

MAMBA_CHUNK = 256
MLSTM_CHUNK = 256


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — Jamba's mixer
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig) -> tuple[Params, Params]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    dt_rank = max(1, d // 16)
    w = cfg.ssm_conv_width
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": _init(ks[0], (d, 2 * di), d ** -0.5, dt),
        "conv_w": _init(ks[1], (w, di), w ** -0.5, dt),
        "conv_b": jnp.zeros((di,), dtype=dt),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * n), di ** -0.5, dt),
        "dt_proj": _init(ks[3], (dt_rank, di), dt_rank ** -0.5, dt),
        "dt_bias": jnp.full((di,), -4.6, dtype=jnp.float32),  # softplus≈0.01
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), dtype=jnp.float32),
        "out_proj": _init(ks[4], (di, d), di ** -0.5, dt),
    }
    s = {
        "in_proj": (EMBED, INNER),
        "conv_w": (None, INNER),
        "conv_b": (INNER,),
        "x_proj": (INNER, None),
        "dt_proj": (None, INNER),
        "dt_bias": (INNER,),
        "A_log": (INNER, STATE),
        "D": (INNER,),
        "out_proj": (INNER, EMBED),
    }
    return p, s


def _mamba_scan_chunked(deltaA, deltaBu, h0):
    """h_t = deltaA_t * h_{t-1} + deltaBu_t, scanned over axis 1 (seq).

    deltaA, deltaBu: (B, S, di, N); h0: (B, di, N). Returns (hs, h_last).
    Chunked: lax.scan over S/chunk steps, associative_scan inside.
    """
    B, S, di, N = deltaA.shape
    chunk = min(MAMBA_CHUNK, S)
    assert S % chunk == 0, (S, chunk)
    nchunks = S // chunk
    dA = deltaA.reshape(B, nchunks, chunk, di, N).swapaxes(0, 1)
    dBu = deltaBu.reshape(B, nchunks, chunk, di, N).swapaxes(0, 1)

    def step(h, x):
        a, b = x  # (B, chunk, di, N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_cum * h[:, None] + b_cum      # (B, chunk, di, N)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(step, h0, (dA, dBu))
    hs = hs.swapaxes(0, 1).reshape(B, S, di, N)
    return hs, h_last


def mamba(
    p: Params,
    x: jax.Array,                       # (B, S, d)
    cfg: ModelConfig,
    state: tuple[jax.Array, jax.Array] | None = None,
    # state = (conv_state (B, w-1, di), ssm_state (B, di, N))
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    B, S, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state_dim
    w = cfg.ssm_conv_width
    dt_rank = max(1, d // 16)

    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)   # (B, S, di) each

    if state is None:
        conv_state = jnp.zeros((B, w - 1, di), dtype=xin.dtype)
        ssm_state = jnp.zeros((B, di, n), dtype=jnp.float32)
    else:
        conv_state, ssm_state = state

    # causal depthwise conv, width w
    xpad = jnp.concatenate([conv_state, xin], axis=1)   # (B, S+w-1, di)
    conv = sum(
        xpad[:, i:i + S, :] * p["conv_w"][i][None, None, :]
        for i in range(w)
    ) + p["conv_b"]
    new_conv_state = xpad[:, -(w - 1):, :]
    u = jax.nn.silu(conv)                                # (B, S, di)

    proj = u @ p["x_proj"]                               # (B,S,dt_rank+2n)
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        (dt_in @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                             # (di, N)
    deltaA = jnp.exp(delta[..., None] * A[None, None])   # (B,S,di,N)
    deltaBu = (delta * u.astype(jnp.float32))[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]            # (B,S,di,N)

    hs, h_last = _mamba_scan_chunked(deltaA, deltaBu, ssm_state)
    y = jnp.einsum("bsdn,bsn->bsd", hs,
                   Cm.astype(jnp.float32))               # (B,S,di)
    y = y + u.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return y @ p["out_proj"], (new_conv_state, h_last)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell) — chunkwise parallel form
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> tuple[Params, Params]:
    d = cfg.d_model
    dk = int(cfg.mlstm_proj_factor * d)
    H = cfg.n_heads
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "wq": _init(ks[0], (d, dk), d ** -0.5, dt),
        "wk": _init(ks[1], (d, dk), d ** -0.5, dt),
        "wv": _init(ks[2], (d, dk), d ** -0.5, dt),
        "wi": _init(ks[3], (d, H), d ** -0.5, jnp.float32),  # input gate
        "wf": _init(ks[4], (d, H), d ** -0.5, jnp.float32),  # forget gate
        "wo": _init(ks[5], (dk, d), dk ** -0.5, dt),
    }
    s = {
        "wq": (EMBED, HEADS), "wk": (EMBED, HEADS), "wv": (EMBED, HEADS),
        "wi": (EMBED, None), "wf": (EMBED, None), "wo": (HEADS, EMBED),
    }
    return p, s


def mlstm(
    p: Params,
    x: jax.Array,                      # (B, S, d)
    cfg: ModelConfig,
    state: tuple[jax.Array, jax.Array] | None = None,
    # state = (C (B,H,hd,hd) fp32, n (B,H,hd) fp32)
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Chunkwise mLSTM with sigmoid forget gates (GLA-style stabilized
    simplification of xLSTM's exponential gating; DESIGN.md §2)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dk = int(cfg.mlstm_proj_factor * d)
    hd = dk // H
    chunk = min(MLSTM_CHUNK, S)
    assert S % chunk == 0
    nchunks = S // chunk

    def heads(t):
        return t.reshape(B, S, H, hd)

    q = heads(x @ p["wq"]).astype(jnp.float32) * (hd ** -0.5)
    k = heads(x @ p["wk"]).astype(jnp.float32)
    v = heads(x @ p["wv"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid((x.astype(jnp.float32) @ p["wf"]))  # (B,S,H)
    i_gate = jnp.exp(jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wi"]))

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), dtype=jnp.float32)
        n0 = jnp.zeros((B, H, hd), dtype=jnp.float32)
    else:
        C0, n0 = state

    def rc(t, extra):  # reshape to chunks, put chunk axis first
        return t.reshape((B, nchunks, chunk) + extra).swapaxes(0, 1)

    qs, ks_, vs = rc(q, (H, hd)), rc(k, (H, hd)), rc(v, (H, hd))
    fs, is_ = rc(logf, (H,)), rc(i_gate, (H,))

    def step(carry, inp):
        C, n = carry
        qc, kc, vc, fc, ic = inp   # (B, chunk, H, ...)
        fcum = jnp.cumsum(fc, axis=1)               # (B,chunk,H)
        ftot = fcum[:, -1]                          # (B,H)
        # inter-chunk: contribution of carried state
        decay_q = jnp.exp(fcum)                     # (B,chunk,H)
        y_inter = jnp.einsum("bshk,bhkv->bshv", qc * decay_q[..., None], C)
        n_inter = jnp.einsum("bshk,bhk->bsh", qc * decay_q[..., None], n)
        # intra-chunk: decay-masked attention
        # D[s,t] = exp(fcum_s - fcum_t) * i_t   for t <= s
        rel = fcum[:, :, None, :] - fcum[:, None, :, :]   # (B,s,t,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        D = jnp.where(mask[None, :, :, None], jnp.exp(rel), 0.0)
        D = D * ic[:, None, :, :]                   # apply i_t
        scores = jnp.einsum("bshk,bthk->bsth", qc, kc) * D
        y_intra = jnp.einsum("bsth,bthv->bshv", scores, vc)
        n_intra = jnp.einsum("bsth->bsh", scores)
        y = y_inter + y_intra
        nrm = n_inter + n_intra
        y = y / jnp.maximum(jnp.abs(nrm)[..., None], 1.0)
        # state update
        decay_k = jnp.exp(ftot[:, None, :] - fcum)  # (B,chunk,H)
        kv = jnp.einsum("bshk,bshv->bhkv",
                        kc * (ic * decay_k)[..., None], vc)
        ksum = jnp.einsum("bshk->bhk", kc * (ic * decay_k)[..., None])
        C_new = jnp.exp(ftot)[..., None, None] * C + kv
        n_new = jnp.exp(ftot)[..., None] * n + ksum
        return (C_new, n_new), y

    (C_f, n_f), ys = jax.lax.scan(step, (C0, n0), (qs, ks_, vs, fs, is_))
    y = ys.swapaxes(0, 1).reshape(B, S, H, hd).reshape(B, S, dk)
    return y.astype(x.dtype) @ p["wo"], (C_f, n_f)


def mlstm_decode_step(
    p: Params, x: jax.Array, cfg: ModelConfig,
    state: tuple[jax.Array, jax.Array],
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Single-token mLSTM recurrence (decode)."""
    B, S, d = x.shape
    assert S == 1
    H = cfg.n_heads
    dk = int(cfg.mlstm_proj_factor * d)
    hd = dk // H
    C, n = state
    q = (x @ p["wq"]).reshape(B, H, hd).astype(jnp.float32) * (hd ** -0.5)
    k = (x @ p["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    xf = x[:, 0].astype(jnp.float32)
    f = jnp.exp(jax.nn.log_sigmoid(xf @ p["wf"]))       # (B,H)
    i = jnp.exp(jax.nn.log_sigmoid(xf @ p["wi"]))
    C = f[..., None, None] * C + i[..., None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k, v)
    n = f[..., None] * n + i[..., None] * k
    y = jnp.einsum("bhk,bhkv->bhv", q, C)
    nrm = jnp.einsum("bhk,bhk->bh", q, n)
    y = y / jnp.maximum(jnp.abs(nrm)[..., None], 1.0)
    y = y.reshape(B, 1, dk).astype(x.dtype)
    return y @ p["wo"], (C, n)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell) — sequential scan
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> tuple[Params, Params]:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {
        # gates i,f,z,o stacked: input weights (d, 4d)
        "w_gates": _init(ks[0], (d, 4 * d), d ** -0.5, dt),
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "r_gates": _init(ks[1], (H, hd, 4 * hd), hd ** -0.5, jnp.float32),
        "b_gates": jnp.zeros((4 * d,), dtype=jnp.float32),
        "w_out": _init(ks[2], (d, d), d ** -0.5, dt),
    }
    s = {
        "w_gates": (EMBED, None),
        "r_gates": (HEADS, None, None),
        "b_gates": (None,),
        "w_out": (EMBED, EMBED),
    }
    return p, s


def slstm(
    p: Params,
    x: jax.Array,                      # (B, S, d)
    cfg: ModelConfig,
    state: tuple[jax.Array, jax.Array] | None = None,
    # state = (c (B,d), h (B,d)) fp32
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    pre = (x @ p["w_gates"]).astype(jnp.float32) + p["b_gates"]  # (B,S,4d)
    if state is None:
        c0 = jnp.zeros((B, d), dtype=jnp.float32)
        h0 = jnp.zeros((B, d), dtype=jnp.float32)
    else:
        c0, h0 = state

    def step(carry, pre_t):
        c, h = carry                              # (B, d)
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("bhk,hkg->bhg", hh, p["r_gates"])  # (B,H,4hd)
        z_all = pre_t + rec.reshape(B, 4 * d)
        i, f, z, o = jnp.split(z_all, 4, axis=-1)
        i = jnp.exp(jax.nn.log_sigmoid(i))
        f = jax.nn.sigmoid(f)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        c_new = f * c + i * z
        h_new = o * jnp.tanh(c_new)
        return (c_new, h_new), h_new

    (c_f, h_f), hs = jax.lax.scan(step, (c0, h0), pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)          # (B,S,d)
    return y @ p["w_out"], (c_f, h_f)
