"""DAG engines: WUKONG + every design iteration the paper compares against.

Engines (paper §III's "journey from the serverful to the serverless"):

- ``ServerfulEngine``  — the Dask-distributed stand-in: a centralized
  scheduler with W long-lived workers and direct worker-to-worker data
  transfer (no KV hop). "Dask (EC2)" is W large; "Dask (Laptop)" is W=4.
- ``StrawmanEngine``   — centralized; one Lambda per task; completion ACK
  over a per-Lambda TCP connection handled serially by the scheduler
  (Fig. 1).
- ``PubSubEngine``     — strawman + Redis pub/sub completion notifications
  (Fig. 2).
- ``ParallelInvokerEngine`` — pub/sub + a pool of dedicated invoker
  processes (Fig. 3).
- ``WukongEngine``     — decentralized static/dynamic scheduling (Fig. 5):
  per-leaf static schedules, executor-local data locality, fan-in
  dependency counters, become/invoke fan-outs, proxy for large fan-outs.

All engines consume the same ``DAG`` (the paper could only compare against
Dask because both shared a representation — §V-D; we keep that property
for every baseline) and the same simulated FaaS cost model.

Time never comes from ``time.*`` here: every wait, deadline, and
timestamp goes through the engine clock (repro.core.simclock). Under the
default virtual clock (``CostModel.time_scale == 0``) idle waiting costs
zero wall time, ``job_timeout_s`` means *simulated* seconds, and
``JobReport.wall_s`` is the deterministic simulated makespan; with
``time_scale > 0`` the seed real-time behavior is preserved for
cross-checks.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import TYPE_CHECKING, Any

from repro.core.dag import DAG, DynamicDAG, TaskRef
from repro.core.executor import (
    RESULTS_CHANNEL,
    ExecutorContext,
    TaskExecutor,
    TaskMetrics,
)
from repro.core.faults import (
    FaultConfig,
    FaultInjector,
    FaultStats,
    HeartbeatRegistry,
)
from repro.core.invoker import FanoutProxy, InvokerPool
from repro.core.kvstore import PURGED, CostModel, ShardedKVStore, sizeof
from repro.core.optimize import OptimizeConfig, PassStats, ensure_compiled
from repro.core.schedule import generate_static_schedules
from repro.core.simclock import run_effects, task_clock

if TYPE_CHECKING:  # import cycle: repro.platform imports repro.core
    from repro.platform import FaaSPlatform, PlatformConfig


def _make_platform(config: "PlatformConfig | None", cost: CostModel,
                   clock) -> "FaaSPlatform | None":
    """Instantiate the stateful platform lazily: a module-level import
    of repro.platform here would close an import cycle (repro.platform
    -> repro.core.kvstore -> repro.core.__init__ -> engine) and crash
    any process that imports repro.platform first."""
    if config is None:
        return None
    from repro.platform import FaaSPlatform

    return FaaSPlatform(config, cost, clock)


class JobError(RuntimeError):
    pass


@dataclasses.dataclass
class JobSubstrate:
    """An injected execution substrate for ONE job on a shared platform.

    By default every ``compute()`` builds a private KV store (and with
    it a private clock) plus a private platform — fine for one-job
    benchmarks, useless for studying contention. The orchestrator
    (repro.core.orchestrator) instead builds the substrate ONCE and
    passes each job a ``JobSubstrate``:

    ``kv``        — the job's view of the shared store (normally a
                    ``ShardedKVStore.namespace(job_id)`` so keys,
                    counters, and channels don't collide across jobs);
                    supplies the shared clock via ``kv.clock``.
    ``platform``  — the SHARED stateful FaaS platform, so concurrent
                    jobs compete for warm containers and the account
                    concurrency cap and billing is account-wide. None
                    keeps the legacy stochastic cold-start draw.
    ``function``  — the platform function identity this job invokes
                    (the orchestrator uses one function per *tenant*:
                    warm containers pool per function, so tenants share
                    the account but never each other's containers, and
                    billing is attributable per tenant).

    ``job``       — billing attribution label: invocations run for this
                    substrate are tagged with it in the platform's
                    billing meter, so per-JOB billed USD survives an
                    orchestrator crash (the journal records it) and is
                    auditable on a shared account.
    ``resume``    — crash recovery: executors probe the store for a
                    durable task output before executing and reuse it,
                    so a re-admitted job never re-executes (or re-bills
                    the compute of) journaled-complete work.

    When a substrate is injected the engine creates none of the above
    and ignores ``EngineConfig.platform``; everything else (invoker
    pools, runtime pool, schedules, monitors) stays per-job.
    """

    kv: Any
    platform: "FaaSPlatform | None" = None
    function: str = "executor"
    job: "str | None" = None
    resume: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    n_kv_shards: int = 10
    colocate_kv_shards: bool = False      # §V-B factor: shards share one VM
    counter_mode: str = "edge_set"         # or "paper" (plain INCR)
    num_initial_invokers: int = 20         # scheduler-side leaf invokers
    num_proxy_invokers: int = 20           # KV-proxy fan-out invokers
    proxy_threshold: int = 8               # max_task_fanout
    use_proxy: bool = True                 # §V-B factor
    inline_fanout_args: bool = False       # beyond-paper locality opt
    # Data-plane factor (Lambada-style batching): executors gather their
    # inputs with one pipelined mget (one kv_base_ms per shard batch)
    # instead of one round trip per key. Striping, the other data-plane
    # factor, is configured on the CostModel (stripe_threshold_bytes /
    # max_stripes) since it is a property of the storage substrate.
    batch_kv_round_trips: bool = True
    # Simulated Lambda concurrency (runtime-pool cap). Workers are
    # created lazily in both clock modes, so the cap can be raised to
    # AWS-scale (the virtual clock sweeps 8k-64k-task DAGs without the
    # wall-clock cost that used to bind this to 512).
    max_concurrency: int = 4096
    speculative_poll_s: float = 0.01       # simulated s under VirtualClock
    job_timeout_s: float = 600.0           # simulated s under VirtualClock
    # DAG compiler pipeline run before scheduling (repro.core.optimize);
    # None = run the graph verbatim (the seed behavior). Each pass is
    # independently switchable for §V-B-style factor ablations.
    optimize: OptimizeConfig | None = None
    # Stateful FaaS platform model (repro.platform): warm-container pool
    # with keep-alive expiry, account concurrency throttling with burst
    # ramp, and a billing meter. None = the legacy memoryless
    # ``warm_fraction`` draw (kept for cross-checks).
    platform: PlatformConfig | None = None
    # Per-task metrics records cost ~2.5 dicts/task of memory; million-task
    # scaling runs switch them off (charged_ms/kv_stats are unaffected).
    record_metrics: bool = True


@dataclasses.dataclass
class JobReport:
    results: dict[str, Any]
    wall_s: float  # simulated makespan (virtual) / real elapsed (realtime)
    tasks: int
    executors_invoked: int
    kv_stats: dict[str, int]
    metrics: list[dict[str, Any]]
    charged_ms: float
    optimizer: tuple[PassStats, ...] = ()  # compiler pass report
    # Provider-model counters: cold/warm starts, throttle events, peak
    # concurrency, billed USD (pool mode); invoker cold-start counts in
    # every mode (the InvokerPool counter was previously dropped).
    platform_stats: dict[str, Any] = dataclasses.field(default_factory=dict)
    # Fault/retry observability (faults.FaultStats snapshot + the invoker
    # pools' 429-retry tally): task attempts, injected failures, retries,
    # speculative duplicates, throttle retries, resumed tasks.
    fault_stats: dict[str, int] = dataclasses.field(default_factory=dict)
    # Locality observability (repro.core.cache): THIS job's per-tier
    # hits/misses/evictions/spills and bytes served locally vs remotely.
    # Empty unless the platform runs with a container cache configured.
    cache_stats: dict[str, int] = dataclasses.field(default_factory=dict)


def _platform_stats(platform: "FaaSPlatform | None",
                    pools: "list[InvokerPool]") -> dict[str, Any]:
    """The JobReport provider-model block. With the stateful platform:
    its full snapshot (pool / throttle / billing counters). Without it:
    the legacy stochastic-draw counters — surfacing the per-pool
    ``cold_starts`` tally that was previously incremented but never
    reported.

    The block is rebuilt defensively (top level AND nested dicts):
    ``snapshot()`` promises fresh structures, but on a shared platform
    two JobReports must never alias one counters dict even if that
    contract regresses — we mutate the block right below, and callers
    mutate it after us (benchmarks annotate rows in place)."""
    if platform is not None:
        stats = {k: (dict(v) if isinstance(v, dict) else v)
                 for k, v in platform.snapshot().items()}
    else:
        stats = {"mode": "legacy",
                 "cold_starts": sum(p.cold_starts for p in pools)}
    stats["invocations"] = sum(p.invocations for p in pools)
    return stats


def _cache_stats_block(ctx: ExecutorContext,
                       kv_stats: "dict[str, int]") -> "dict[str, int]":
    """The JobReport locality block: this job's cache-tier counters plus
    bytes served remotely (the KV bytes it actually read — everything a
    cache hit did NOT turn into local service). Empty when no container
    cache ran, so cacheless reports are unchanged."""
    snap = ctx.cache_stats.snapshot()
    if not any(snap.values()):
        return {}
    snap["bytes_remote"] = kv_stats.get("bytes_read", 0)
    return snap


class _ResultWaiter:
    """Collects root results from the results channel, dedupes duplicates
    (speculative executors may publish a root twice).

    Event-driven on the engine clock: the waiter blocks on its
    subscription until a message or the job deadline — no polling, so
    idle waiting costs zero wall time under the virtual clock and
    ``timeout_s`` means clock (simulated) seconds."""

    def __init__(self, kv: ShardedKVStore, roots: tuple[str, ...],
                 dag: "DAG | None" = None):
        self.kv = kv
        self.roots = set(roots)
        # Dynamic completion detection: on a DynamicDAG the total task
        # count — and the root set — is not known at submit time (an
        # expansion may add parentless sinks). The waiter re-reads the
        # live root set each iteration instead of trusting the snapshot.
        self._dag = dag
        self.sub = kv.subscribe(RESULTS_CHANNEL)

    def _live_roots(self) -> set[str]:
        if self._dag is not None:
            self.roots = set(self._dag.roots)
        return self.roots

    def close(self) -> None:
        """Release the results subscription. Without this every job
        leaked its queue into the store's ``_channels`` — invisible when
        the store died with the job, a real accumulation (and publish
        fan-out slowdown) once the substrate outlives jobs."""
        self.kv.unsubscribe(RESULTS_CHANNEL, self.sub)

    def wait_g(self, timeout_s: float):
        clock = self.kv.clock
        done: set[str] = set()
        deadline = clock.now_ms() + timeout_s * 1e3
        while done != self._live_roots():
            remaining_ms = deadline - clock.now_ms()
            if remaining_ms <= 0:
                raise JobError(
                    f"job timed out; missing roots: {sorted(self.roots - done)}"
                )
            try:
                msg = yield ("get", self.sub, remaining_ms / 1e3)
            except queue.Empty:
                continue
            if msg is PURGED:
                raise JobError("job namespace purged while awaiting results")
            if msg["type"] == "error":
                raise JobError(f"task {msg['key']!r} failed: {msg['error']}")
            if msg["key"] in self._live_roots():
                done.add(msg["key"])
        results: dict[str, Any] = {}
        for k in sorted(self.roots):
            results[k] = yield from self.kv.get_g(k)
        return results

    def wait(self, timeout_s: float) -> dict[str, Any]:
        return run_effects(self.kv.clock, self.wait_g(timeout_s))


class WukongEngine:
    """The decentralized engine (paper §IV)."""

    def __init__(self, config: EngineConfig | None = None):
        self.config = config or EngineConfig()

    def compute(self, dag: DAG,
                substrate: JobSubstrate | None = None) -> JobReport:
        """Run the job to completion on the engine clock.

        The job body is an effect generator (``compute_g``); the clock's
        ``run`` drives it — as the root continuation of the event loop on
        the event substrate, or inline on the calling (actor) thread on
        the thread/realtime substrates."""
        cfg = self.config
        # DAG compiler: rewrite/annotate before any schedule is generated.
        # Host-side work (compilation, schedule generation) happens before
        # the clock starts: it is scheduler prep, not simulated time.
        dag = ensure_compiled(dag, cfg.optimize)
        if substrate is None:
            kv: Any = ShardedKVStore(
                n_shards=cfg.n_kv_shards,
                cost=cfg.cost,
                colocate_shards=cfg.colocate_kv_shards,
                counter_mode=cfg.counter_mode,
            )
        else:
            kv = substrate.kv
        return kv.clock.run(self._compute_g(dag, kv, substrate))

    def compute_g(self, dag: DAG, substrate: JobSubstrate):
        """The job as an effect generator, for composition inside an
        already-running substrate (the orchestrator's job runners do
        ``yield from engine.compute_g(dag, substrate)``)."""
        dag = ensure_compiled(dag, self.config.optimize)
        return (yield from self._compute_g(dag, substrate.kv, substrate))

    def _compute_g(self, dag: DAG, kv: Any, substrate: JobSubstrate | None):
        cfg = self.config
        function = substrate.function if substrate is not None else "executor"
        clock = kv.clock
        schedule_set = generate_static_schedules(dag)
        # On a shared substrate the clock's cumulative charge counter
        # does not restart per job: report the delta. (With jobs from
        # OTHER tenants charging the same clock concurrently, the
        # per-job delta includes their charges too — per-tenant money
        # accounting goes through the platform's billing meter, which
        # meters per invocation body and is exact.)
        charged0 = clock.charged_ms
        # Storage Manager registers the fan-in counters at workflow
        # start — in ONE batched round trip (Lambada-style request
        # batching), or one per counter when the factor is ablated.
        counters = schedule_set.fan_in_counters()
        if cfg.batch_kv_round_trips:
            yield from kv.register_counters_g(counters)
        else:
            for cid, width in counters.items():
                yield from kv.register_counter_g(cid, width)

        metrics = TaskMetrics(clock, enabled=cfg.record_metrics)
        heartbeats = HeartbeatRegistry()
        faults = FaultInjector(cfg.faults)
        fault_stats = FaultStats()
        pool = clock.pool(cfg.max_concurrency)
        # Self-contained: one platform instance per job (initial and
        # proxy invokers share the cap and container pool). Injected:
        # the SHARED platform — this job contends with every other
        # job on the substrate.
        if substrate is not None:
            platform = substrate.platform
        else:
            platform = _make_platform(cfg.platform, cfg.cost, clock)
            caches = getattr(platform, "caches", None)
            if caches is not None and hasattr(kv, "add_purge_listener"):
                # Namespace reclamation must reach container caches too
                # (idempotent registration). On a shared substrate the
                # orchestrator registers its shared platform instead.
                kv.add_purge_listener(caches.invalidate_prefix)
        job = substrate.job if substrate is not None else None
        initial_invokers = InvokerPool(
            cfg.num_initial_invokers, cfg.cost, clock, pool, name="init",
            platform=platform, function=function, job=job,
        )
        proxy_invokers = InvokerPool(
            cfg.num_proxy_invokers, cfg.cost, clock, pool, name="proxy",
            platform=platform, function=function, job=job,
        )
        proxy = FanoutProxy(kv, proxy_invokers) if cfg.use_proxy else None
        # Per-job stop signal: set at teardown (success OR failure)
        # and checked by executors at task boundaries and by spawn
        # below, so an abandoned job's in-flight work winds down
        # instead of consuming shared capacity.
        stop_job = clock.event()

        ctx: ExecutorContext | None = None

        def spawn(start_key, seed_cache, schedule, width, attempt=0,
                  parent=None, hint_keys=()):
            # Effect generator: spawn charges nothing itself, but the
            # proxy path publishes (a charged KV operation).
            assert ctx is not None
            if stop_job.is_set():
                return  # dead job: drop late retries/speculation
            ship_ms = schedule.code_size_bytes / (
                cfg.cost.schedule_ship_mbps * 1e6
            ) * 1e3
            body = _executor_body(ctx, schedule, start_key, seed_cache,
                                  attempt, parent, hint_keys=hint_keys)
            if proxy is not None and width >= cfg.proxy_threshold:
                # Large fan-out: one pub/sub message offloads all the
                # invocations to the proxy's parallel invoker pool.
                yield from kv.publish_g(FanoutProxy.CHANNEL,
                                        {"spawns": [body]})
            else:
                initial_invokers.submit(body, extra_ms=ship_ms)

        ctx = ExecutorContext(
            dag=dag,
            kv=kv,
            spawn=spawn,
            faults=faults,
            heartbeats=heartbeats,
            metrics=metrics,
            inline_fanout_args=cfg.inline_fanout_args,
            coalesce_batch=getattr(dag, "coalesce_batch", 0),
            batch_kv_round_trips=cfg.batch_kv_round_trips,
            compute_clock=(platform.compute_clock(clock, function)
                           if platform is not None else None),
            stop=stop_job,
            resume=substrate.resume if substrate is not None else False,
            fault_stats=fault_stats,
            schedule_set=schedule_set,
        )

        waiter = _ResultWaiter(
            kv, dag.roots,
            dag=dag if isinstance(dag, DynamicDAG) else None)
        t0_ms = clock.now_ms()
        # Metric stamps are relative to the job's t0 (the clock is
        # shared and does not restart per job).
        metrics.origin_ms = t0_ms
        # Initial Task Executor Invokers: one executor per start batch
        # — one batch per static schedule (paper §IV-C), or fewer when
        # the coalescing pass grouped sibling leaves.
        for keys, sched in schedule_set.batches:
            yield from spawn(keys, {}, sched, width=1)

        stop_monitor = clock.event()
        clock.spawn(
            lambda: _speculative_monitor(
                ctx, stop_monitor, cfg, schedule_set, clock),
            name="spec-monitor",
        )
        try:
            results = yield from waiter.wait_g(cfg.job_timeout_s)
        finally:
            stop_job.set()
            stop_monitor.set()
            initial_invokers.close()
            proxy_invokers.close()
            if proxy is not None:
                yield from proxy.close_g()
            waiter.close()
            # Platform mode: queued-but-unstarted bodies are WRAPPED
            # invocations already holding a concurrency slot and a
            # container (reserved by the invoker lane); cancelling
            # them would leak both into the shared account forever.
            # They must run — the stop signal makes each return at
            # its first task boundary, and the wrapper's finally
            # releases the reservation. Without a platform nothing
            # is reserved, so queued bodies are safely dropped.
            pool.shutdown(wait=False, cancel_futures=platform is None)
        wall = (clock.now_ms() - t0_ms) / 1e3
        # Snapshot every counter while still inside the job generator:
        # the substrate serializes this read against any still-draining
        # leftover work (late retries/speculative duplicates), so the
        # report is deterministic.
        kv_snapshot = kv.stats.snapshot()
        report = JobReport(
            results=results,
            wall_s=wall,
            tasks=len(dag),
            executors_invoked=initial_invokers.invocations
            + proxy_invokers.invocations,
            kv_stats=kv_snapshot,
            metrics=list(metrics.records),
            charged_ms=clock.charged_ms - charged0,
            optimizer=getattr(dag, "pass_stats", ()),
            platform_stats=_platform_stats(
                platform, [initial_invokers, proxy_invokers]),
            fault_stats=_merge_fault_stats(
                fault_stats, [initial_invokers, proxy_invokers]),
            cache_stats=_cache_stats_block(ctx, kv_snapshot),
        )
        return report


def _merge_fault_stats(fault_stats: FaultStats,
                       pools: "list[InvokerPool]") -> dict[str, int]:
    """The JobReport fault/retry block: executor-side counters plus the
    invoker pools' 429-throttle retry tally (counted at the invoker lane,
    where the retry loop lives)."""
    stats = fault_stats.snapshot()
    stats["throttle_retries"] += sum(p.throttle_retries for p in pools)
    return stats


def _executor_body(ctx, schedule, start_key, seed_cache, attempt, parent=None,
                   hint_keys=()):
    def body(container_cache=None):
        return TaskExecutor(ctx, schedule, start_key, seed_cache, attempt,
                            parent=parent,
                            container_cache=container_cache).run_g()

    # Platform handshake: ``accepts_cache`` tells wrap_g to pass the
    # container's multi-tier cache in; ``hint_keys`` (store-qualified
    # input keys) lets the invoker bias placement toward a warm
    # container already holding them. Attributes — not parameters — so
    # the invoker/proxy submit path stays body-shape-agnostic.
    body.accepts_cache = True
    body.hint_keys = tuple(hint_keys)
    return body


def _speculative_monitor(ctx, stop, cfg, schedule_set, clock):
    """Re-invoke executors whose current task exceeds the straggler
    threshold (beyond-paper straggler mitigation; safe via idempotence).

    Heartbeat ages come from the engine clock: under the virtual clock
    they ARE simulated ms; in real-time mode they are real ms scaled back
    to simulated by ``time_scale`` (the seed behavior)."""
    threshold_ms = cfg.faults.speculative_threshold_ms
    if threshold_ms == float("inf"):
        return
    respawned: set[int] = set()
    while True:
        flag = yield ("wait", stop, cfg.speculative_poll_s)
        if flag:
            return
        now_ms = clock.now_ms()
        for hb in ctx.heartbeats.inflight():
            age_ms = now_ms - hb.started_at
            scale = 1.0 if clock.virtual else (cfg.cost.time_scale or 1.0)
            if age_ms / scale > threshold_ms and hb.executor_id not in respawned:
                respawned.add(hb.executor_id)
                # Duplicate every member of a coalesced batch, each with
                # its own covering schedule (a sibling leaf's schedule
                # need not cover the others' reachable sets). The schedule
                # set's covering index makes this O(1) per respawn instead
                # of a linear scan over every schedule.
                for key in hb.start_keys or (hb.start_key,):
                    sched = schedule_set.covering_schedule(key)
                    if sched is not None:
                        ctx.fault_stats.bump("speculative_duplicates")
                        yield from ctx.spawn(key, {}, sched, width=1,
                                             attempt=1, parent=hb.parent)


# ---------------------------------------------------------------------------
# Centralized design iterations (paper §III, Figs. 1-3) and the serverful
# baseline. They share a single implementation parameterized by the
# completion-notification transport and the invoker parallelism.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CentralizedConfig:
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    n_kv_shards: int = 10
    colocate_kv_shards: bool = False
    notification: str = "tcp"      # "tcp" (strawman) | "pubsub"
    num_invokers: int = 1          # >1 = parallel-invoker version
    max_concurrency: int = 4096    # lazily-created runtime workers
    job_timeout_s: float = 600.0   # simulated s under VirtualClock
    # DAG compiler pipeline (chain fusion shrinks the one-Lambda-per-task
    # graph; the executor-level passes are no-ops here). None = verbatim.
    optimize: OptimizeConfig | None = None
    # Stateful FaaS platform model; None = legacy stochastic draw.
    platform: PlatformConfig | None = None
    record_metrics: bool = True    # off for million-task scaling runs


class _CentralizedEngine:
    """Centralized scheduler: tracks readiness, dispatches one Lambda per
    task; Lambdas read inputs from / write outputs to the KV store and
    notify the scheduler, which resolves dependents (Figs. 1-3)."""

    name = "centralized"

    def __init__(self, config: CentralizedConfig | None = None):
        self.config = config or CentralizedConfig()

    def compute(self, dag: DAG,
                substrate: JobSubstrate | None = None) -> JobReport:
        cfg = self.config
        dag = ensure_compiled(dag, cfg.optimize)
        if substrate is None:
            kv: Any = ShardedKVStore(
                n_shards=cfg.n_kv_shards, cost=cfg.cost,
                colocate_shards=cfg.colocate_kv_shards,
            )
        else:
            kv = substrate.kv
        return kv.clock.run(self._compute_g(dag, kv, substrate))

    def compute_g(self, dag: DAG, substrate: JobSubstrate):
        dag = ensure_compiled(dag, self.config.optimize)
        return (yield from self._compute_g(dag, substrate.kv, substrate))

    def _compute_g(self, dag: DAG, kv: Any, substrate: JobSubstrate | None):
        cfg = self.config
        function = substrate.function if substrate is not None else "executor"
        clock = kv.clock
        charged0 = clock.charged_ms
        metrics = TaskMetrics(clock, enabled=cfg.record_metrics)
        pool = clock.pool(cfg.max_concurrency)
        if substrate is not None:
            platform = substrate.platform
        else:
            platform = _make_platform(cfg.platform, cfg.cost, clock)
        invokers = InvokerPool(
            cfg.num_invokers, cfg.cost, clock, pool, platform=platform,
            function=function,
            job=substrate.job if substrate is not None else None)
        compute_clock = (platform.compute_clock(clock, function)
                         if platform is not None else clock)
        done_q = clock.queue()
        inflight = [0]
        inflight_lock = threading.Lock()

        # Scheduler-side message handling is serialized (the §III-B
        # bottleneck). TCP mode additionally pays a per-connection
        # setup and an IRQ-flood term that grows with the number of
        # Lambdas holding open connections (paper §III-C) — the reason
        # pub/sub pulls ahead as tasks get longer and waves of
        # completions pile up.
        def per_msg_ms() -> float:
            if cfg.notification != "tcp":
                return cfg.cost.pubsub_msg_ms
            with inflight_lock:
                n = inflight[0]
            return (cfg.cost.tcp_connect_ms
                    + cfg.cost.tcp_msg_ms
                    * (1.0 + cfg.cost.tcp_irq_factor * n))

        def resolve_g(a):
            if isinstance(a, TaskRef):
                return (yield from kv.get_g(a.key))
            return a

        def lambda_body(key: str):
            def body():
                with inflight_lock:
                    inflight[0] += 1
                try:
                    task = dag.tasks[key]
                    t0 = clock.now_ms()
                    args = []
                    for a in task.args:
                        args.append((yield from resolve_g(a)))
                    kwargs = {}
                    for k, v in task.kwargs.items():
                        kwargs[k] = yield from resolve_g(v)
                    read_ms = clock.now_ms() - t0
                    t0 = clock.now_ms()
                    with task_clock(compute_clock):
                        out = task.fn(*args, **kwargs)
                    # Flush compute deferred inside the task function
                    # (event substrate) before reading the clock delta.
                    yield ("flush",)
                    compute_ms = clock.now_ms() - t0
                    t0 = clock.now_ms()
                    yield from kv.put_g(key, out)
                    write_ms = clock.now_ms() - t0
                    metrics.record(
                        task=key, event="executed", read_ms=read_ms,
                        compute_ms=compute_ms, write_ms=write_ms,
                        nbytes=sizeof(out),
                    )
                    done_q.put((key, None))
                except Exception as exc:  # pragma: no cover - see below
                    done_q.put((key, exc))
                finally:
                    with inflight_lock:
                        inflight[0] -= 1

            return body

        indeg = {k: len(dag.deps[k]) for k in dag.tasks}
        t0_ms = clock.now_ms()
        metrics.origin_ms = t0_ms
        for k in dag.leaves:
            invokers.submit(lambda_body(k))
        remaining = set(dag.tasks)
        deadline = clock.now_ms() + cfg.job_timeout_s * 1e3
        try:
            while remaining:
                timeout_ms = deadline - clock.now_ms()
                if timeout_ms <= 0:
                    raise JobError(f"timeout; remaining={len(remaining)}")
                try:
                    key, err = yield ("get", done_q, timeout_ms / 1e3)
                except queue.Empty:
                    continue
                if err is not None:
                    raise JobError(f"task {key!r} failed: {err!r}")
                # serialized scheduler handling
                yield ("charge", per_msg_ms())
                remaining.discard(key)
                for child in dag.children[key]:
                    indeg[child] -= 1
                    if indeg[child] == 0:
                        invokers.submit(lambda_body(child))
        finally:
            invokers.close()
            # See WukongEngine: platform-wrapped queued bodies hold
            # reservations that only their wrapper's finally releases —
            # run them, don't drop them.
            pool.shutdown(wait=False, cancel_futures=platform is None)
        wall = (clock.now_ms() - t0_ms) / 1e3
        results = {}
        for k in dag.roots:
            results[k] = yield from kv.get_g(k)
        # Snapshot inside the job generator (see WukongEngine).
        report = JobReport(
            results=results,
            wall_s=wall,
            tasks=len(dag),
            executors_invoked=invokers.invocations,
            kv_stats=kv.stats.snapshot(),
            metrics=list(metrics.records),
            charged_ms=clock.charged_ms - charged0,
            optimizer=getattr(dag, "pass_stats", ()),
            platform_stats=_platform_stats(platform, [invokers]),
            fault_stats=_merge_fault_stats(FaultStats(), [invokers]),
        )
        return report


class StrawmanEngine(_CentralizedEngine):
    """Fig. 1: per-Lambda TCP notifications, single invoker."""

    name = "strawman"

    def __init__(self, cost: CostModel | None = None, **kw: Any):
        super().__init__(CentralizedConfig(
            cost=cost or CostModel(), notification="tcp", num_invokers=1, **kw
        ))


class PubSubEngine(_CentralizedEngine):
    """Fig. 2: pub/sub notifications, single invoker."""

    name = "pubsub"

    def __init__(self, cost: CostModel | None = None, **kw: Any):
        super().__init__(CentralizedConfig(
            cost=cost or CostModel(), notification="pubsub",
            num_invokers=1, **kw
        ))


class ParallelInvokerEngine(_CentralizedEngine):
    """Fig. 3: pub/sub + dedicated parallel invoker processes."""

    name = "parallel_invoker"

    def __init__(self, cost: CostModel | None = None, num_invokers: int = 20,
                 **kw: Any):
        super().__init__(CentralizedConfig(
            cost=cost or CostModel(), notification="pubsub",
            num_invokers=num_invokers, **kw
        ))


@dataclasses.dataclass(frozen=True)
class ServerfulConfig:
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    n_workers: int = 25            # paper EC2: 5 VMs x 5 worker processes
    worker_bandwidth_mbps: float = 1000.0  # direct worker<->worker TCP
    job_timeout_s: float = 600.0   # simulated s under VirtualClock
    optimize: OptimizeConfig | None = None  # DAG compiler (chain fusion)
    # Fixed-cluster billing (the serverless counterpart bills GB-seconds
    # through repro.platform): the cluster costs VM-hours for the job's
    # simulated makespan whether its workers are busy or idle — the
    # pay-per-allocation vs pay-per-use comparison of fig14.
    n_vms: int = 5                 # paper: five t2.2xlarge VMs
    vm_price_per_hour_usd: float = 0.3712  # t2.2xlarge on-demand
    record_metrics: bool = True    # off for million-task scaling runs


class ServerfulEngine:
    """Dask-distributed stand-in: long-lived workers, centralized
    scheduler, direct worker-to-worker transfers (no KV hop), finite
    parallelism = n_workers. Locality-aware: tasks prefer the worker that
    holds most of their input bytes (Dask's data-locality heuristic)."""

    name = "serverful"

    def __init__(self, config: ServerfulConfig | None = None):
        self.config = config or ServerfulConfig()

    def compute(self, dag: DAG) -> JobReport:
        cfg = self.config
        dag = ensure_compiled(dag, cfg.optimize)
        clock_cost = dataclasses.replace(cfg.cost)
        kv = ShardedKVStore(n_shards=1, cost=clock_cost)  # clock + channels
        return kv.clock.run(self._compute_g(dag, kv))

    def _compute_g(self, dag: DAG, kv: ShardedKVStore):
        cfg = self.config
        clock = kv.clock
        metrics = TaskMetrics(clock, enabled=cfg.record_metrics)
        owner: dict[str, int] = {}    # task key -> worker that holds it
        data: list[dict[str, Any]] = [dict() for _ in range(cfg.n_workers)]
        owner_lock = threading.Lock()
        done_q = clock.queue()
        pool = clock.pool(cfg.n_workers)

        def run_on_worker(key: str, wid: int):
            def body():
                try:
                    task = dag.tasks[key]
                    t0 = clock.now_ms()

                    def resolve_g(a):
                        if not isinstance(a, TaskRef):
                            return a
                        with owner_lock:
                            src = owner[a.key]
                            val = data[src][a.key]
                        if src != wid:
                            # direct TCP transfer between workers
                            ms = sizeof(val) / (
                                cfg.worker_bandwidth_mbps * 1e6) * 1e3
                            yield ("charge", cfg.cost.tcp_msg_ms + ms)
                        return val

                    args = []
                    for a in task.args:
                        args.append((yield from resolve_g(a)))
                    kwargs = {}
                    for k, v in task.kwargs.items():
                        kwargs[k] = yield from resolve_g(v)
                    read_ms = clock.now_ms() - t0
                    t0 = clock.now_ms()
                    with task_clock(clock):
                        out = task.fn(*args, **kwargs)
                    # Flush compute deferred inside the task function
                    # (event substrate) before reading the clock delta.
                    yield ("flush",)
                    compute_ms = clock.now_ms() - t0
                    with owner_lock:
                        data[wid][key] = out
                        owner[key] = wid
                    metrics.record(task=key, event="executed",
                                   read_ms=read_ms,
                                   compute_ms=compute_ms,
                                   write_ms=0.0, nbytes=sizeof(out))
                    done_q.put((key, None))
                except Exception as exc:
                    done_q.put((key, exc))

            return body

        def pick_worker(key: str, rr: int) -> int:
            # locality: the worker holding the most input bytes
            best, best_bytes = rr % cfg.n_workers, -1
            with owner_lock:
                counts: dict[int, int] = {}
                for dep in dag.deps[key]:
                    w = owner.get(dep)
                    if w is not None:
                        counts[w] = counts.get(w, 0) + sizeof(data[w][dep])
            for w, b in counts.items():
                if b > best_bytes:
                    best, best_bytes = w, b
            return best

        indeg = {k: len(dag.deps[k]) for k in dag.tasks}
        t0_ms = clock.now_ms()
        metrics.origin_ms = t0_ms
        rr = 0
        for k in dag.leaves:
            pool.submit(run_on_worker(k, pick_worker(k, rr)))
            rr += 1
        remaining = set(dag.tasks)
        deadline = clock.now_ms() + cfg.job_timeout_s * 1e3
        try:
            while remaining:
                timeout_ms = deadline - clock.now_ms()
                if timeout_ms <= 0:
                    raise JobError(f"timeout; remaining={len(remaining)}")
                try:
                    key, err = yield ("get", done_q, timeout_ms / 1e3)
                except queue.Empty:
                    continue
                if err is not None:
                    raise JobError(f"task {key!r} failed: {err!r}")
                yield ("charge", cfg.cost.tcp_msg_ms)  # scheduler handling
                remaining.discard(key)
                for child in dag.children[key]:
                    indeg[child] -= 1
                    if indeg[child] == 0:
                        pool.submit(
                            run_on_worker(child, pick_worker(child, rr)))
                        rr += 1
        finally:
            # No FaaS platform here (fixed cluster): queued bodies
            # hold no reservations and are safe to drop.
            pool.shutdown(wait=False, cancel_futures=True)
        wall = (clock.now_ms() - t0_ms) / 1e3
        with owner_lock:
            results = {k: data[owner[k]][k] for k in dag.roots}
        # Snapshot inside the job generator (see WukongEngine).
        report = JobReport(
            results=results, wall_s=wall, tasks=len(dag),
            executors_invoked=0, kv_stats=kv.stats.snapshot(),
            metrics=list(metrics.records), charged_ms=clock.charged_ms,
            optimizer=getattr(dag, "pass_stats", ()),
            platform_stats={
                "mode": "serverful",
                "n_vms": cfg.n_vms,
                "vm_price_per_hour_usd": cfg.vm_price_per_hour_usd,
                # The cluster is billed for the makespan regardless of
                # utilization — allocation-based, not use-based.
                "billed_usd": cfg.n_vms * cfg.vm_price_per_hour_usd
                * wall / 3600.0,
                "cold_starts": 0,
                "invocations": 0,
            },
        )
        return report


ENGINES = {
    "wukong": WukongEngine,
    "strawman": StrawmanEngine,
    "pubsub": PubSubEngine,
    "parallel_invoker": ParallelInvokerEngine,
    "serverful": ServerfulEngine,
}
