"""DAG engines: WUKONG + every design iteration the paper compares against.

Engines (paper §III's "journey from the serverful to the serverless"):

- ``ServerfulEngine``  — the Dask-distributed stand-in: a centralized
  scheduler with W long-lived workers and direct worker-to-worker data
  transfer (no KV hop). "Dask (EC2)" is W large; "Dask (Laptop)" is W=4.
- ``StrawmanEngine``   — centralized; one Lambda per task; completion ACK
  over a per-Lambda TCP connection handled serially by the scheduler
  (Fig. 1).
- ``PubSubEngine``     — strawman + Redis pub/sub completion notifications
  (Fig. 2).
- ``ParallelInvokerEngine`` — pub/sub + a pool of dedicated invoker
  processes (Fig. 3).
- ``WukongEngine``     — decentralized static/dynamic scheduling (Fig. 5):
  per-leaf static schedules, executor-local data locality, fan-in
  dependency counters, become/invoke fan-outs, proxy for large fan-outs.

All engines consume the same ``DAG`` (the paper could only compare against
Dask because both shared a representation — §V-D; we keep that property
for every baseline) and the same simulated FaaS cost model.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any

from repro.core.dag import DAG, TaskRef
from repro.core.executor import (
    RESULTS_CHANNEL,
    ExecutorContext,
    TaskExecutor,
    TaskMetrics,
)
from repro.core.faults import FaultConfig, FaultInjector, HeartbeatRegistry
from repro.core.invoker import FanoutProxy, InvokerPool
from repro.core.kvstore import CostModel, ShardedKVStore, sizeof
from repro.core.optimize import OptimizeConfig, PassStats, ensure_compiled
from repro.core.schedule import generate_static_schedules


class JobError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    n_kv_shards: int = 10
    colocate_kv_shards: bool = False      # §V-B factor: shards share one VM
    counter_mode: str = "edge_set"         # or "paper" (plain INCR)
    num_initial_invokers: int = 20         # scheduler-side leaf invokers
    num_proxy_invokers: int = 20           # KV-proxy fan-out invokers
    proxy_threshold: int = 8               # max_task_fanout
    use_proxy: bool = True                 # §V-B factor
    inline_fanout_args: bool = False       # beyond-paper locality opt
    # Data-plane factor (Lambada-style batching): executors gather their
    # inputs with one pipelined mget (one kv_base_ms per shard batch)
    # instead of one round trip per key. Striping, the other data-plane
    # factor, is configured on the CostModel (stripe_threshold_bytes /
    # max_stripes) since it is a property of the storage substrate.
    batch_kv_round_trips: bool = True
    max_concurrency: int = 512             # simulated Lambda concurrency
    speculative_poll_s: float = 0.01
    job_timeout_s: float = 600.0
    # DAG compiler pipeline run before scheduling (repro.core.optimize);
    # None = run the graph verbatim (the seed behavior). Each pass is
    # independently switchable for §V-B-style factor ablations.
    optimize: OptimizeConfig | None = None


@dataclasses.dataclass
class JobReport:
    results: dict[str, Any]
    wall_s: float
    tasks: int
    executors_invoked: int
    kv_stats: dict[str, int]
    metrics: list[dict[str, Any]]
    charged_ms: float
    optimizer: tuple[PassStats, ...] = ()  # compiler pass report


class _ResultWaiter:
    """Collects root results from the results channel, dedupes duplicates
    (speculative executors may publish a root twice)."""

    def __init__(self, kv: ShardedKVStore, roots: tuple[str, ...]):
        self.kv = kv
        self.roots = set(roots)
        self.sub = kv.subscribe(RESULTS_CHANNEL)

    def wait(self, timeout_s: float) -> dict[str, Any]:
        done: set[str] = set()
        deadline = time.monotonic() + timeout_s
        while done != self.roots:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise JobError(
                    f"job timed out; missing roots: {sorted(self.roots - done)}"
                )
            try:
                msg = self.sub.get(timeout=min(remaining, 0.25))
            except queue.Empty:
                continue
            if msg["type"] == "error":
                raise JobError(f"task {msg['key']!r} failed: {msg['error']}")
            if msg["key"] in self.roots:
                done.add(msg["key"])
        return {k: self.kv.get(k) for k in sorted(self.roots)}


class WukongEngine:
    """The decentralized engine (paper §IV)."""

    def __init__(self, config: EngineConfig | None = None):
        self.config = config or EngineConfig()

    def compute(self, dag: DAG) -> JobReport:
        cfg = self.config
        # DAG compiler: rewrite/annotate before any schedule is generated.
        dag = ensure_compiled(dag, cfg.optimize)
        kv = ShardedKVStore(
            n_shards=cfg.n_kv_shards,
            cost=cfg.cost,
            colocate_shards=cfg.colocate_kv_shards,
            counter_mode=cfg.counter_mode,
        )
        schedule_set = generate_static_schedules(dag)
        # Storage Manager registers the fan-in counters at workflow start
        # — in ONE batched round trip (Lambada-style request batching),
        # or one per counter when the batching factor is ablated off.
        counters = schedule_set.fan_in_counters()
        if cfg.batch_kv_round_trips:
            kv.register_counters(counters)
        else:
            for cid, width in counters.items():
                kv.register_counter(cid, width)

        metrics = TaskMetrics()
        heartbeats = HeartbeatRegistry()
        faults = FaultInjector(cfg.faults)
        pool = ThreadPoolExecutor(max_workers=cfg.max_concurrency)
        initial_invokers = InvokerPool(
            cfg.num_initial_invokers, cfg.cost, kv.clock, pool, name="init"
        )
        proxy_invokers = InvokerPool(
            cfg.num_proxy_invokers, cfg.cost, kv.clock, pool, name="proxy"
        )
        proxy = FanoutProxy(kv, proxy_invokers) if cfg.use_proxy else None

        ctx: ExecutorContext | None = None

        def spawn(start_key, seed_cache, schedule, width, attempt=0,
                  parent=None):
            assert ctx is not None
            ship_ms = schedule.code_size_bytes / (
                cfg.cost.schedule_ship_mbps * 1e6
            ) * 1e3
            body = _executor_body(ctx, schedule, start_key, seed_cache,
                                  attempt, parent)
            if proxy is not None and width >= cfg.proxy_threshold:
                # Large fan-out: one pub/sub message offloads all the
                # invocations to the proxy's parallel invoker pool.
                kv.publish(FanoutProxy.CHANNEL, {"spawns": [body]})
            else:
                initial_invokers.submit(body, extra_ms=ship_ms)

        ctx = ExecutorContext(
            dag=dag,
            kv=kv,
            spawn=spawn,
            faults=faults,
            heartbeats=heartbeats,
            metrics=metrics,
            inline_fanout_args=cfg.inline_fanout_args,
            coalesce_batch=getattr(dag, "coalesce_batch", 0),
            batch_kv_round_trips=cfg.batch_kv_round_trips,
        )

        waiter = _ResultWaiter(kv, dag.roots)
        t0 = time.perf_counter()
        # Initial Task Executor Invokers: one executor per start batch —
        # one batch per static schedule (paper §IV-C), or fewer when the
        # coalescing pass grouped sibling leaves.
        for keys, sched in schedule_set.batches:
            spawn(keys, {}, sched, width=1)

        stop_monitor = threading.Event()
        monitor = threading.Thread(
            target=_speculative_monitor,
            args=(ctx, stop_monitor, cfg, schedule_set),
            daemon=True,
        )
        monitor.start()
        try:
            results = waiter.wait(cfg.job_timeout_s)
        finally:
            stop_monitor.set()
            initial_invokers.close()
            proxy_invokers.close()
            if proxy is not None:
                proxy.close()
            pool.shutdown(wait=False, cancel_futures=True)
        wall = time.perf_counter() - t0
        return JobReport(
            results=results,
            wall_s=wall,
            tasks=len(dag),
            executors_invoked=initial_invokers.invocations
            + proxy_invokers.invocations,
            kv_stats=kv.stats.snapshot(),
            metrics=metrics.records,
            charged_ms=kv.clock.charged_ms,
            optimizer=getattr(dag, "pass_stats", ()),
        )


def _executor_body(ctx, schedule, start_key, seed_cache, attempt, parent=None):
    def body():
        TaskExecutor(ctx, schedule, start_key, seed_cache, attempt,
                     parent=parent).run()

    return body


def _speculative_monitor(ctx, stop, cfg, schedule_set):
    """Re-invoke executors whose current task exceeds the straggler
    threshold (beyond-paper straggler mitigation; safe via idempotence)."""
    threshold_ms = cfg.faults.speculative_threshold_ms
    if threshold_ms == float("inf"):
        return
    respawned: set[int] = set()
    while not stop.wait(cfg.speculative_poll_s):
        now = time.perf_counter()
        for hb in ctx.heartbeats.inflight():
            age_ms = (now - hb.started_at) * 1e3
            scale = cfg.cost.time_scale or 1.0
            if age_ms / scale > threshold_ms and hb.executor_id not in respawned:
                respawned.add(hb.executor_id)
                # Duplicate every member of a coalesced batch, each with
                # its own covering schedule (a sibling leaf's schedule
                # need not cover the others' reachable sets). The schedule
                # set's covering index makes this O(1) per respawn instead
                # of a linear scan over every schedule.
                for key in hb.start_keys or (hb.start_key,):
                    sched = schedule_set.covering_schedule(key)
                    if sched is not None:
                        ctx.spawn(key, {}, sched, width=1,
                                  attempt=1, parent=hb.parent)


# ---------------------------------------------------------------------------
# Centralized design iterations (paper §III, Figs. 1-3) and the serverful
# baseline. They share a single implementation parameterized by the
# completion-notification transport and the invoker parallelism.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CentralizedConfig:
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    n_kv_shards: int = 10
    colocate_kv_shards: bool = False
    notification: str = "tcp"      # "tcp" (strawman) | "pubsub"
    num_invokers: int = 1          # >1 = parallel-invoker version
    max_concurrency: int = 512
    job_timeout_s: float = 600.0
    # DAG compiler pipeline (chain fusion shrinks the one-Lambda-per-task
    # graph; the executor-level passes are no-ops here). None = verbatim.
    optimize: OptimizeConfig | None = None


class _CentralizedEngine:
    """Centralized scheduler: tracks readiness, dispatches one Lambda per
    task; Lambdas read inputs from / write outputs to the KV store and
    notify the scheduler, which resolves dependents (Figs. 1-3)."""

    name = "centralized"

    def __init__(self, config: CentralizedConfig | None = None):
        self.config = config or CentralizedConfig()

    def compute(self, dag: DAG) -> JobReport:
        cfg = self.config
        dag = ensure_compiled(dag, cfg.optimize)
        kv = ShardedKVStore(
            n_shards=cfg.n_kv_shards, cost=cfg.cost,
            colocate_shards=cfg.colocate_kv_shards,
        )
        metrics = TaskMetrics()
        pool = ThreadPoolExecutor(max_workers=cfg.max_concurrency)
        invokers = InvokerPool(cfg.num_invokers, cfg.cost, kv.clock, pool)
        done_q: "queue.Queue[tuple[str, Any]]" = queue.Queue()
        inflight = [0]
        inflight_lock = threading.Lock()

        # Scheduler-side message handling is serialized (the §III-B
        # bottleneck). TCP mode additionally pays a per-connection setup
        # and an IRQ-flood term that grows with the number of Lambdas
        # holding open connections (paper §III-C) — the reason pub/sub
        # pulls ahead as tasks get longer and waves of completions pile up.
        def per_msg_ms() -> float:
            if cfg.notification != "tcp":
                return cfg.cost.pubsub_msg_ms
            with inflight_lock:
                n = inflight[0]
            return (cfg.cost.tcp_connect_ms
                    + cfg.cost.tcp_msg_ms * (1.0 + cfg.cost.tcp_irq_factor * n))

        def lambda_body(key: str):
            def body():
                with inflight_lock:
                    inflight[0] += 1
                try:
                    task = dag.tasks[key]
                    t0 = time.perf_counter()

                    def resolve(a):
                        return kv.get(a.key) if isinstance(a, TaskRef) else a

                    args = [resolve(a) for a in task.args]
                    kwargs = {k: resolve(v) for k, v in task.kwargs.items()}
                    read_ms = (time.perf_counter() - t0) * 1e3
                    t0 = time.perf_counter()
                    out = task.fn(*args, **kwargs)
                    compute_ms = (time.perf_counter() - t0) * 1e3
                    t0 = time.perf_counter()
                    kv.put(key, out)
                    write_ms = (time.perf_counter() - t0) * 1e3
                    metrics.record(
                        task=key, event="executed", read_ms=read_ms,
                        compute_ms=compute_ms, write_ms=write_ms,
                        nbytes=sizeof(out),
                    )
                    done_q.put((key, None))
                except Exception as exc:  # pragma: no cover - surfaced below
                    done_q.put((key, exc))
                finally:
                    with inflight_lock:
                        inflight[0] -= 1

            return body

        indeg = {k: len(dag.deps[k]) for k in dag.tasks}
        t0 = time.perf_counter()
        for k in dag.leaves:
            invokers.submit(lambda_body(k))
        remaining = set(dag.tasks)
        deadline = time.monotonic() + cfg.job_timeout_s
        try:
            while remaining:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    raise JobError(f"timeout; remaining={len(remaining)}")
                key, err = done_q.get(timeout=timeout)
                if err is not None:
                    raise JobError(f"task {key!r} failed: {err!r}")
                kv.clock.charge(per_msg_ms())  # serialized scheduler handling
                remaining.discard(key)
                for child in dag.children[key]:
                    indeg[child] -= 1
                    if indeg[child] == 0:
                        invokers.submit(lambda_body(child))
        finally:
            invokers.close()
            pool.shutdown(wait=False, cancel_futures=True)
        wall = time.perf_counter() - t0
        return JobReport(
            results={k: kv.get(k) for k in dag.roots},
            wall_s=wall,
            tasks=len(dag),
            executors_invoked=invokers.invocations,
            kv_stats=kv.stats.snapshot(),
            metrics=metrics.records,
            charged_ms=kv.clock.charged_ms,
            optimizer=getattr(dag, "pass_stats", ()),
        )


class StrawmanEngine(_CentralizedEngine):
    """Fig. 1: per-Lambda TCP notifications, single invoker."""

    name = "strawman"

    def __init__(self, cost: CostModel | None = None, **kw: Any):
        super().__init__(CentralizedConfig(
            cost=cost or CostModel(), notification="tcp", num_invokers=1, **kw
        ))


class PubSubEngine(_CentralizedEngine):
    """Fig. 2: pub/sub notifications, single invoker."""

    name = "pubsub"

    def __init__(self, cost: CostModel | None = None, **kw: Any):
        super().__init__(CentralizedConfig(
            cost=cost or CostModel(), notification="pubsub",
            num_invokers=1, **kw
        ))


class ParallelInvokerEngine(_CentralizedEngine):
    """Fig. 3: pub/sub + dedicated parallel invoker processes."""

    name = "parallel_invoker"

    def __init__(self, cost: CostModel | None = None, num_invokers: int = 20,
                 **kw: Any):
        super().__init__(CentralizedConfig(
            cost=cost or CostModel(), notification="pubsub",
            num_invokers=num_invokers, **kw
        ))


@dataclasses.dataclass(frozen=True)
class ServerfulConfig:
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    n_workers: int = 25            # paper EC2: 5 VMs x 5 worker processes
    worker_bandwidth_mbps: float = 1000.0  # direct worker<->worker TCP
    job_timeout_s: float = 600.0
    optimize: OptimizeConfig | None = None  # DAG compiler (chain fusion)


class ServerfulEngine:
    """Dask-distributed stand-in: long-lived workers, centralized
    scheduler, direct worker-to-worker transfers (no KV hop), finite
    parallelism = n_workers. Locality-aware: tasks prefer the worker that
    holds most of their input bytes (Dask's data-locality heuristic)."""

    name = "serverful"

    def __init__(self, config: ServerfulConfig | None = None):
        self.config = config or ServerfulConfig()

    def compute(self, dag: DAG) -> JobReport:
        cfg = self.config
        dag = ensure_compiled(dag, cfg.optimize)
        clock_cost = dataclasses.replace(cfg.cost)
        kv = ShardedKVStore(n_shards=1, cost=clock_cost)  # clock + channels
        metrics = TaskMetrics()
        owner: dict[str, int] = {}        # task key -> worker that holds it
        data: list[dict[str, Any]] = [dict() for _ in range(cfg.n_workers)]
        owner_lock = threading.Lock()
        done_q: "queue.Queue[tuple[str, Any]]" = queue.Queue()
        pool = ThreadPoolExecutor(max_workers=cfg.n_workers)

        def run_on_worker(key: str, wid: int):
            def body():
                try:
                    task = dag.tasks[key]
                    t0 = time.perf_counter()

                    def resolve(a):
                        if not isinstance(a, TaskRef):
                            return a
                        with owner_lock:
                            src = owner[a.key]
                            val = data[src][a.key]
                        if src != wid:
                            # direct TCP transfer between workers
                            ms = sizeof(val) / (
                                cfg.worker_bandwidth_mbps * 1e6) * 1e3
                            kv.clock.charge(cfg.cost.tcp_msg_ms + ms)
                        return val

                    args = [resolve(a) for a in task.args]
                    kwargs = {k: resolve(v) for k, v in task.kwargs.items()}
                    read_ms = (time.perf_counter() - t0) * 1e3
                    t0 = time.perf_counter()
                    out = task.fn(*args, **kwargs)
                    compute_ms = (time.perf_counter() - t0) * 1e3
                    with owner_lock:
                        data[wid][key] = out
                        owner[key] = wid
                    metrics.record(task=key, event="executed",
                                   read_ms=read_ms, compute_ms=compute_ms,
                                   write_ms=0.0, nbytes=sizeof(out))
                    done_q.put((key, None))
                except Exception as exc:
                    done_q.put((key, exc))

            return body

        def pick_worker(key: str, rr: int) -> int:
            # locality: the worker holding the most input bytes
            best, best_bytes = rr % cfg.n_workers, -1
            with owner_lock:
                counts: dict[int, int] = {}
                for dep in dag.deps[key]:
                    w = owner.get(dep)
                    if w is not None:
                        counts[w] = counts.get(w, 0) + sizeof(data[w][dep])
            for w, b in counts.items():
                if b > best_bytes:
                    best, best_bytes = w, b
            return best

        indeg = {k: len(dag.deps[k]) for k in dag.tasks}
        t0 = time.perf_counter()
        rr = 0
        for k in dag.leaves:
            pool.submit(run_on_worker(k, pick_worker(k, rr)))
            rr += 1
        remaining = set(dag.tasks)
        deadline = time.monotonic() + cfg.job_timeout_s
        try:
            while remaining:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    raise JobError(f"timeout; remaining={len(remaining)}")
                key, err = done_q.get(timeout=timeout)
                if err is not None:
                    raise JobError(f"task {key!r} failed: {err!r}")
                kv.clock.charge(cfg.cost.tcp_msg_ms)  # scheduler handling
                remaining.discard(key)
                for child in dag.children[key]:
                    indeg[child] -= 1
                    if indeg[child] == 0:
                        pool.submit(run_on_worker(child, pick_worker(child, rr)))
                        rr += 1
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
        wall = time.perf_counter() - t0
        with owner_lock:
            results = {k: data[owner[k]][k] for k in dag.roots}
        return JobReport(
            results=results, wall_s=wall, tasks=len(dag),
            executors_invoked=0, kv_stats=kv.stats.snapshot(),
            metrics=metrics.records, charged_ms=kv.clock.charged_ms,
            optimizer=getattr(dag, "pass_stats", ()),
        )


ENGINES = {
    "wukong": WukongEngine,
    "strawman": StrawmanEngine,
    "pubsub": PubSubEngine,
    "parallel_invoker": ParallelInvokerEngine,
    "serverful": ServerfulEngine,
}
