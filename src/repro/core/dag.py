"""DAG representation for WUKONG.

A DAG maps task keys to ``Task`` objects. Tasks name their dependencies by
key; edges always point dependency -> dependent (data flows along edges).

The graph-construction surface mirrors Dask's: a task graph is a dict
``{key: (callable, arg0, arg1, ...)}`` where string args naming other keys
are dependencies, plus literal leaves ``{key: value}``. The paper's strawman
was "a modification of the Python-written Dask distributed scheduler"; we
keep the same representation so the serverful baseline and WUKONG run the
exact same graphs (paper §V-D notes this is what made their comparison
possible).

Dynamic DAGs (Triggerflow-style reactive workflows): a task of a
:class:`DynamicDAG` may return an :class:`Expansion` instead of a plain
value — a data-dependent subgraph appended to the running job at the
point of the expanding task (fan-outs whose width depends on the data,
iterate-until-converged loops). See :meth:`DynamicDAG.apply_expansion`
for the rewrite rule that keeps an expanded run bit-identical — results,
``charged_ms``, and ``kv_stats`` — to the statically pre-expanded
equivalent graph.
"""
from __future__ import annotations

import dataclasses
import hashlib
import pickle
import threading
from typing import Any, Callable, Iterable, Mapping

# The graph-integrity rules live in the standalone validation pass
# (repro.analysis is a leaf package — no import cycle). CycleError,
# ExpansionError and EXPAND_BASE are defined there and re-exported here,
# the import path every caller and test already uses.
from repro.analysis.dagcheck import (
    EXPAND_BASE,
    CycleError,
    ExpansionError,
    build_graph,
    check_expansion,
    toposort,
)

__all__ = [
    "DAG",
    "DynamicDAG",
    "EXPAND_BASE",
    "CycleError",
    "Expansion",
    "ExpansionDelta",
    "ExpansionError",
    "Task",
    "TaskRef",
    "expansion_base_key",
]


@dataclasses.dataclass(frozen=True)
class Task:
    """A single DAG node.

    ``fn`` is the task code (shipped inside static schedules, like the
    paper's pickled task code). ``args`` may contain ``TaskRef`` objects
    (dependencies) and arbitrary literals.
    """

    key: str
    fn: Callable[..., Any]
    args: tuple[Any, ...] = ()
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def dependencies(self) -> tuple[str, ...]:
        deps = []
        for a in list(self.args) + list(self.kwargs.values()):
            if isinstance(a, TaskRef):
                deps.append(a.key)
        return tuple(dict.fromkeys(deps))  # stable-unique


@dataclasses.dataclass(frozen=True)
class TaskRef:
    """Reference to another task's output (an edge in the DAG)."""

    key: str


class DAG:
    """Directed acyclic graph of tasks.

    ``deps[k]``    — keys k reads from (in-edges).
    ``children[k]``— keys that read k (out-edges).
    ``leaves``     — tasks with no dependencies (paper: leaf nodes; one
                     static schedule is generated per leaf).
    ``roots``      — tasks nothing depends on (final outputs).
    """

    def __init__(self, tasks: Iterable[Task]):
        self.tasks, self.deps, self.children = build_graph(tasks)
        self.leaves: tuple[str, ...] = tuple(
            k for k in self.tasks if not self.deps[k]
        )
        self.roots: tuple[str, ...] = tuple(
            k for k in self.tasks if not self.children[k]
        )
        self._check_acyclic()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dsk(cls, dsk: Mapping[str, Any]) -> "DAG":
        """Build from a Dask-style graph dict."""
        tasks = []
        for key, spec in dsk.items():
            if isinstance(spec, tuple) and spec and callable(spec[0]):
                fn = spec[0]
                args = tuple(
                    TaskRef(a) if isinstance(a, str) and a in dsk else a
                    for a in spec[1:]
                )
                tasks.append(Task(key, fn, args))
            else:  # literal leaf
                tasks.append(Task(key, _literal(spec)))
        return cls(tasks)

    # -- utilities ---------------------------------------------------------
    def _check_acyclic(self) -> None:
        # The acyclicity check already computes a full topological order;
        # cache it so the host-side hot paths that re-sort the graph
        # (compiler passes, schedule generation, critical-path metrics)
        # pay O(V+E) once per DAG instead of once per call.
        self._topo_order: tuple[str, ...] = toposort(
            self.tasks, self.deps, self.children)

    def topological_order(self) -> list[str]:
        return list(self._topo_order)

    def reachable_from(self, start: str) -> set[str]:
        """All nodes reachable from ``start`` following out-edges (paper:
        the static schedule for leaf L contains every node reachable from
        L)."""
        seen = {start}
        stack = [start]
        while stack:
            k = stack.pop()
            for c in self.children[k]:
                if c not in seen:
                    seen.add(c)
                    stack.append(c)
        return seen

    def fan_in_degree(self, key: str) -> int:
        return len(self.deps[key])

    def fan_out_degree(self, key: str) -> int:
        return len(self.children[key])

    def __len__(self) -> int:
        return len(self.tasks)

    def __contains__(self, key: str) -> bool:
        return key in self.tasks

    def critical_path_length(self) -> int:
        depth: dict[str, int] = {}
        for k in self.topological_order():
            depth[k] = 1 + max((depth[d] for d in self.deps[k]), default=0)
        return max(depth.values(), default=0)


# ---------------------------------------------------------------------------
# Dynamic DAGs: runtime graph expansion (Triggerflow-style reactive
# workflows; the ROADMAP streaming open item).
# ---------------------------------------------------------------------------

# EXPAND_BASE — the placeholder dependency key inside an Expansion's
# subgraph, rewritten at apply time to the synthetic base node that
# holds the expanding task's own output value — is defined in
# repro.analysis.dagcheck (imported above) so the standalone validator
# shares it.


def expansion_base_key(key: str, n: int) -> str:
    """The synthetic base node's key for the ``n``-th expansion of
    ``key`` (0-based). Exposed so tests/benchmarks can construct the
    statically pre-expanded equivalent graph with matching names."""
    return f"{key}/__base{n}__"


@dataclasses.dataclass(frozen=True)
class Expansion:
    """Returned by a task of a :class:`DynamicDAG` instead of a plain
    value: append ``tasks`` downstream of this task at runtime.

    ``value``  — the expanding task's own output; the subgraph reads it
                 by depending on :data:`EXPAND_BASE`.
    ``tasks``  — the subgraph. Tasks may only depend on ``EXPAND_BASE``
                 or on sibling tasks of the same expansion
                 (self-contained — the property that makes the expanded
                 run charge-identical to the pre-expanded equivalent).
    ``final``  — the key (within ``tasks``) of the subgraph's sink; its
                 task is re-bound under the expanding task's key, so the
                 original downstream consumers transparently read the
                 converged/aggregated result. ``final`` itself may
                 return another Expansion (iterate-until-converged),
                 bounded by ``DynamicDAG.max_expansion_depth``.
    """

    value: Any
    tasks: tuple[Task, ...]
    final: str

    def __post_init__(self) -> None:
        object.__setattr__(self, "tasks", tuple(self.tasks))


@dataclasses.dataclass(frozen=True)
class ExpansionDelta:
    """What :meth:`DynamicDAG.apply_expansion` changed — everything the
    executor and the incremental scheduler need, O(|subgraph|).

    ``topo`` is the delta in topological order: the base node first, the
    re-bound expanding key last. ``fan_in_widths`` maps every task key
    whose in-degree the expansion (re)defined to its new width > 1; the
    executor registers these host-side (uncharged — the batched
    registration round trip at job start already paid, see
    ``ShardedKVStore.rebind_counter``).

    ``replayed=True`` marks a duplicate application: the expanding task
    ran twice (a resumed run whose crashed predecessor already pushed
    the fan-in counters past their widths, or a speculative duplicate)
    and the graph already holds this exact subgraph. The caller must
    then NOT touch the counters — the first application's subgraph is
    live on them."""

    key: str
    base_key: str
    value: Any
    new_keys: tuple[str, ...]
    topo: tuple[str, ...]
    fan_in_widths: Mapping[str, int]
    replayed: bool = False


def _value_fingerprint(value: Any) -> Any:
    """Stable digest of an expansion's value, part of the replay-dedupe
    signature. Unpicklable values get a unique token — they can never be
    proven to be a duplicate execution, so they never dedupe (a fresh
    install, which then fails on key collisions if it truly was one)."""
    try:
        return hashlib.sha1(pickle.dumps(value, protocol=4)).hexdigest()
    except Exception:
        return object()


def _retarget(task: Task, key: str, base: str) -> Task:
    """``task`` re-keyed to ``key`` with EXPAND_BASE refs bound to
    ``base``."""

    def bind(a: Any) -> Any:
        if isinstance(a, TaskRef) and a.key == EXPAND_BASE:
            return TaskRef(base)
        return a

    return Task(key, task.fn, tuple(bind(a) for a in task.args),
                {k: bind(v) for k, v in task.kwargs.items()})


class DynamicDAG(DAG):
    """A DAG whose tasks may grow the graph at runtime.

    The expansion rewrite (exactly mirrored by a statically pre-expanded
    graph, which is what the parity tests exploit):

    - a synthetic *base* node ``expansion_base_key(key, n)`` is inserted
      where the expanding task ``key`` stood: it inherits ``key``'s
      original args/deps (upstream children lists are retargeted in
      place, preserving positions) and holds the expanding task's output
      value;
    - the subgraph tasks are added with ``EXPAND_BASE`` bound to the
      base node;
    - the ``final`` task is re-bound under ``key`` itself, keeping
      ``key``'s original downstream edges intact.

    Construction order matters for bit-identical fan-out behavior: new
    children lists append in ``Expansion.tasks`` order, so the
    equivalent static graph must list the base task at the expanding
    task's original position and the subgraph tasks (with ``final``
    keyed as ``key``) after it, in the same order.

    ``max_expansion_depth`` bounds chained expansions (a re-bound final
    that expands again), so a non-converging iterate loop fails loudly
    instead of growing forever.
    """

    def __init__(self, tasks: Iterable[Task], max_expansion_depth: int = 8):
        if not isinstance(max_expansion_depth, int) \
                or isinstance(max_expansion_depth, bool) \
                or max_expansion_depth < 1:
            raise ValueError(
                f"max_expansion_depth must be a positive int, got "
                f"{max_expansion_depth!r}")
        super().__init__(tasks)
        self.max_expansion_depth = max_expansion_depth
        self._expand_lock = threading.Lock()
        self._expansion_counts: dict[str, int] = {}
        self._depths: dict[str, int] = {}
        # (key, subgraph keys, final) -> the delta it produced, so a
        # duplicate execution of an expanding task (idempotent-replay
        # crash model) replays the recorded delta instead of colliding.
        self._applied: dict[Any, ExpansionDelta] = {}
        self._topo_dirty = False
        self.expansions_applied = 0

    def topological_order(self) -> list[str]:
        with self._expand_lock:
            if self._topo_dirty:
                # Recompute (and re-verify acyclicity globally) on
                # demand: expansions themselves stay O(|subgraph|).
                self._check_acyclic()
                self._topo_dirty = False
        return list(self._topo_order)

    def apply_expansion(self, key: str, expansion: Expansion) \
            -> ExpansionDelta:
        """Install ``expansion`` at ``key``; returns the delta. Raises
        :class:`ExpansionError` on an invalid subgraph or when the
        chained-expansion depth bound is exceeded."""
        with self._expand_lock:
            return self._apply_locked(key, expansion)

    def _apply_locked(self, key: str, expansion: Expansion) \
            -> ExpansionDelta:
        if key not in self.tasks:
            raise ExpansionError(f"unknown task {key!r}")
        sig = (key, tuple(t.key for t in expansion.tasks), expansion.final,
               _value_fingerprint(expansion.value))
        prior = self._applied.get(sig)
        if prior is not None:
            # The same task produced the same expansion — same subgraph
            # AND same value — again: a duplicate execution (a resumed
            # run whose crashed predecessor already pushed the fan-in
            # counters past their widths re-runs the expanding task with
            # identical inputs). Every KV write below a task is
            # if-absent/idempotent by design, and this makes graph
            # growth match — the duplicate executor relabels onto the
            # already-installed subgraph and falls through the normal
            # (idempotent) write path. A matching subgraph with a NEW
            # value is NOT a replay: that is the next round of an
            # iterate-until-converged loop whose final re-expands under
            # the same key with the same single-task shape.
            return dataclasses.replace(prior, value=expansion.value,
                                       replayed=True)
        depth = self._depths.get(key, 0) + 1
        tasks = expansion.tasks
        n = self._expansion_counts.get(key, 0)
        base = expansion_base_key(key, n)
        # All structural rules — depth cap, collisions, self-containment,
        # orphans, subgraph acyclicity — live in the unified validator
        # (repro.analysis.dagcheck); it returns the subgraph keys plus
        # the local topological order [base, ...subgraph...] the
        # installer below consumes.
        keys, order = check_expansion(
            self.tasks, key, expansion, base, depth,
            self.max_expansion_depth)

        # ---- install (validation done; mutate atomically) -----------------
        self._expansion_counts[key] = n + 1
        orig = self.tasks[key]
        # Base node: the original task, re-keyed. Its fn is never run by
        # the dynamic executor (the expanding task already ran and its
        # value rides the relabel); recording the original fn keeps the
        # graph structurally identical to the static equivalent.
        self.tasks[base] = Task(base, orig.fn, orig.args, orig.kwargs)
        self.deps[base] = self.deps[key]
        self.children[base] = []
        for d in self.deps[base]:
            self.children[d] = [base if c == key else c
                                for c in self.children[d]]
        rebound: dict[str, str] = {expansion.final: key}
        for t in tasks:
            tk = rebound.get(t.key, t.key)
            nt = _retarget(t, tk, base)
            self.tasks[tk] = nt
            self.deps[tk] = nt.dependencies()
            if tk != key:
                self.children[tk] = []
            self._depths[tk] = depth
        # Out-edges: appended in Expansion.tasks order (final contributes
        # at its own position), matching a static graph that lists the
        # subgraph tasks in the same order.
        for t in tasks:
            tk = rebound.get(t.key, t.key)
            for d in self.deps[tk]:
                self.children[d].append(tk)
        self._depths[base] = depth
        if key in self.leaves:
            self.leaves = tuple(base if lf == key else lf
                                for lf in self.leaves)
        new_roots = [k for k in keys
                     if rebound.get(k, k) != key
                     and not self.children[rebound.get(k, k)]]
        if new_roots:
            self.roots = self.roots + tuple(new_roots)
        self._topo_dirty = True
        self.expansions_applied += 1
        new_keys = tuple(k for k in keys if k != expansion.final)
        # [base, ...subgraph in local topo order...], with the final
        # task appearing under its re-bound name (``key``).
        topo = tuple(rebound.get(k, k) for k in order)
        widths = {k: len(self.deps[k])
                  for k in [key, *new_keys]
                  if len(self.deps[k]) > 1}
        delta = ExpansionDelta(
            key=key, base_key=base, value=expansion.value,
            new_keys=new_keys, topo=topo, fan_in_widths=widths,
        )
        self._applied[sig] = delta
        return delta


def _literal(value: Any) -> Callable[[], Any]:
    def produce() -> Any:
        return value

    produce.__name__ = "literal"
    return produce
