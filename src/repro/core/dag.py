"""DAG representation for WUKONG.

A DAG maps task keys to ``Task`` objects. Tasks name their dependencies by
key; edges always point dependency -> dependent (data flows along edges).

The graph-construction surface mirrors Dask's: a task graph is a dict
``{key: (callable, arg0, arg1, ...)}`` where string args naming other keys
are dependencies, plus literal leaves ``{key: value}``. The paper's strawman
was "a modification of the Python-written Dask distributed scheduler"; we
keep the same representation so the serverful baseline and WUKONG run the
exact same graphs (paper §V-D notes this is what made their comparison
possible).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping


class CycleError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class Task:
    """A single DAG node.

    ``fn`` is the task code (shipped inside static schedules, like the
    paper's pickled task code). ``args`` may contain ``TaskRef`` objects
    (dependencies) and arbitrary literals.
    """

    key: str
    fn: Callable[..., Any]
    args: tuple[Any, ...] = ()
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def dependencies(self) -> tuple[str, ...]:
        deps = []
        for a in list(self.args) + list(self.kwargs.values()):
            if isinstance(a, TaskRef):
                deps.append(a.key)
        return tuple(dict.fromkeys(deps))  # stable-unique


@dataclasses.dataclass(frozen=True)
class TaskRef:
    """Reference to another task's output (an edge in the DAG)."""

    key: str


class DAG:
    """Directed acyclic graph of tasks.

    ``deps[k]``    — keys k reads from (in-edges).
    ``children[k]``— keys that read k (out-edges).
    ``leaves``     — tasks with no dependencies (paper: leaf nodes; one
                     static schedule is generated per leaf).
    ``roots``      — tasks nothing depends on (final outputs).
    """

    def __init__(self, tasks: Iterable[Task]):
        self.tasks: dict[str, Task] = {}
        for t in tasks:
            if t.key in self.tasks:
                raise ValueError(f"duplicate task key {t.key!r}")
            self.tasks[t.key] = t
        self.deps: dict[str, tuple[str, ...]] = {}
        self.children: dict[str, list[str]] = {k: [] for k in self.tasks}
        for k, t in self.tasks.items():
            d = t.dependencies()
            missing = [x for x in d if x not in self.tasks]
            if missing:
                raise ValueError(f"task {k!r} depends on missing keys {missing}")
            self.deps[k] = d
            for x in d:
                self.children[x].append(k)
        self.leaves: tuple[str, ...] = tuple(
            k for k in self.tasks if not self.deps[k]
        )
        self.roots: tuple[str, ...] = tuple(
            k for k in self.tasks if not self.children[k]
        )
        self._check_acyclic()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dsk(cls, dsk: Mapping[str, Any]) -> "DAG":
        """Build from a Dask-style graph dict."""
        tasks = []
        for key, spec in dsk.items():
            if isinstance(spec, tuple) and spec and callable(spec[0]):
                fn = spec[0]
                args = tuple(
                    TaskRef(a) if isinstance(a, str) and a in dsk else a
                    for a in spec[1:]
                )
                tasks.append(Task(key, fn, args))
            else:  # literal leaf
                tasks.append(Task(key, _literal(spec)))
        return cls(tasks)

    # -- utilities ---------------------------------------------------------
    def _check_acyclic(self) -> None:
        # The acyclicity check already computes a full topological order;
        # cache it so the host-side hot paths that re-sort the graph
        # (compiler passes, schedule generation, critical-path metrics)
        # pay O(V+E) once per DAG instead of once per call.
        indeg = {k: len(self.deps[k]) for k in self.tasks}
        stack = [k for k in self.tasks if indeg[k] == 0]
        out: list[str] = []
        while stack:
            k = stack.pop()
            out.append(k)
            for c in self.children[k]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
        if len(out) != len(self.tasks):
            raise CycleError("task graph contains a cycle")
        self._topo_order: tuple[str, ...] = tuple(out)

    def topological_order(self) -> list[str]:
        return list(self._topo_order)

    def reachable_from(self, start: str) -> set[str]:
        """All nodes reachable from ``start`` following out-edges (paper:
        the static schedule for leaf L contains every node reachable from
        L)."""
        seen = {start}
        stack = [start]
        while stack:
            k = stack.pop()
            for c in self.children[k]:
                if c not in seen:
                    seen.add(c)
                    stack.append(c)
        return seen

    def fan_in_degree(self, key: str) -> int:
        return len(self.deps[key])

    def fan_out_degree(self, key: str) -> int:
        return len(self.children[key])

    def __len__(self) -> int:
        return len(self.tasks)

    def __contains__(self, key: str) -> bool:
        return key in self.tasks

    def critical_path_length(self) -> int:
        depth: dict[str, int] = {}
        for k in self.topological_order():
            depth[k] = 1 + max((depth[d] for d in self.deps[k]), default=0)
        return max(depth.values(), default=0)


def _literal(value: Any) -> Callable[[], Any]:
    def produce() -> Any:
        return value

    produce.__name__ = "literal"
    return produce
