"""Lambda invocation machinery: parallel invoker pool + large-fan-out proxy.

Invoking an AWS Lambda costs ~50 ms through boto3 (paper §III-C), so
invocation throughput is governed by how many invoker processes issue
calls concurrently:

- The scheduler's *Initial Task Executor Invokers* launch one executor per
  static schedule, in parallel (paper §IV-C).
- A Task Executor performing a *small* fan-out makes its own invocations.
- A fan-out wider than ``proxy_threshold`` publishes one message to the
  KV Store Proxy, whose Fan-out Invokers make the invocations in parallel
  (paper §IV-D "Large Fan-out Task Invocations").

Each invoker lane charges the invocation latency serially per call; P
lanes give P× invocation throughput — the (near-)linear speedup of
§III-C.

Two provider models decide cold starts:

- *legacy* (``platform is None``): latency per call is drawn from
  ``CostModel.invoke_draw`` — seeded lognormal jitter on ``invoke_ms``
  plus a cold start with probability ``1 - warm_fraction``. Memoryless,
  kept for cross-checks.
- *stateful* (``platform`` set): the lane first reserves an account
  concurrency slot — invocations beyond the (burst-ramped) limit are
  throttled 429-style and retried with charged exponential backoff —
  then asks the warm-container pool for a container: a warm hit skips
  the cold start entirely, a miss provisions cold and pays
  ``cold_start_ms``. The executor body is wrapped so its simulated
  execution time is billed (per-request + GB-seconds) and the container
  returns to the pool, warm, when the body finishes.

All blocking (work queues, lane threads) goes through the engine clock's
effect protocol (``simclock``): lanes and the proxy server are generator
actors, so on the event substrate an idle invoker lane is a parked
continuation — no OS thread — and on the thread substrates it degrades
to the familiar blocking loop via ``run_effects``.
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Any, Callable

from repro.core.kvstore import CostModel
from repro.core.simclock import BaseClock, run_effects

if TYPE_CHECKING:  # import cycle: repro.platform imports repro.core
    from repro.platform import FaaSPlatform


class InvokerPool:
    """N invoker lanes; each lane issues invocations serially.

    ``submit`` enqueues an invocation request; a free lane picks it up,
    charges the invocation API latency (jitter + cold start, decided by
    the legacy seeded draw or by the stateful platform), then hands the
    executor body to the runtime pool.
    """

    def __init__(
        self,
        n_invokers: int,
        cost: CostModel,
        clock: BaseClock,
        runtime_pool: Any,
        name: str = "invoker",
        platform: "FaaSPlatform | None" = None,
        function: str = "executor",
        job: "str | None" = None,
    ):
        self.cost = cost
        self.clock = clock
        self.runtime_pool = runtime_pool
        self.platform = platform
        self.function = function
        # Billing attribution: invocations issued by this pool are billed
        # against this job label (the orchestrator passes the job's
        # namespace name; None for self-contained single-job runs).
        self.job = job
        self._q = clock.queue()
        self.invocations = 0
        self.cold_starts = 0
        self.throttle_retries = 0
        self._lock = threading.Lock()
        self._closed = False
        self._n_lanes = max(1, n_invokers)
        for i in range(self._n_lanes):
            clock.spawn(self._lane, name=f"{name}-{i}")

    def _invoke_legacy_g(self, body: Callable[[], Any],
                         extra_ms: float, index: int):
        invoke_ms, cold = self.cost.invoke_draw(index)
        if cold:
            with self._lock:
                self.cold_starts += 1
        # Invocation API latency is paid serially per lane.
        yield ("charge", invoke_ms + extra_ms)
        try:
            self.runtime_pool.submit(body)
        except RuntimeError:
            # Runtime already shut down: the job has resolved; late
            # (retry/speculative) invocations are safe to drop.
            return False
        return True

    def _invoke_platform_g(self, body: Callable[[], Any],
                           extra_ms: float, index: int):
        platform = self.platform
        assert platform is not None
        # Account concurrency: beyond the (burst-ramped) cap the invoke
        # API answers 429; the lane retries with charged exponential
        # backoff, which delays every invocation queued behind it —
        # exactly how SDK-side throttling backs pressure up the client.
        attempt = 0
        while not platform.try_reserve():
            if self._closed:
                # Job torn down while this lane was stuck in 429 retry:
                # nothing is reserved yet, so just drop the invocation
                # instead of fighting live tenants for the account cap.
                return False
            yield ("charge", platform.backoff_ms(attempt))
            attempt += 1
            with self._lock:
                self.throttle_retries += 1
        # The invoke API round trip precedes container assignment (as on
        # the real platform), so a container released while this call is
        # in flight is warm for it; the cold-start provisioning delay is
        # then paid only when the pool misses.
        yield ("charge", self.cost.invoke_jitter_ms(index) + extra_ms)
        # Locality-aware placement: executor bodies carry the
        # store-qualified keys they will read (hint_keys); the platform
        # biases container choice toward a warm container already
        # holding those bytes in its cache. Host-side knowledge only —
        # no charge, and a miss just falls back to LIFO reuse.
        cid, cold = platform.acquire(
            self.function, prefer_keys=getattr(body, "hint_keys", ()))
        if cold:
            with self._lock:
                self.cold_starts += 1
            yield ("charge", self.cost.cold_start_ms)
        try:
            self.runtime_pool.submit(
                platform.wrap_g(self.function, cid, body, job=self.job)
            )
        except RuntimeError:
            # Job resolved while this lane was mid-invoke: the body will
            # never run, so hand the slot and container straight back.
            platform.cancel(self.function, cid)
            return False
        return True

    def _lane(self):
        while True:
            item = yield ("get", self._q, None)
            if item is None:
                return
            if self._closed:
                # The job resolved/failed with this invocation still
                # queued: drop it WITHOUT charging invoke latency or
                # touching the platform — a dead job must not consume
                # shared warm-pool or concurrency-cap capacity.
                continue
            body, extra_ms = item
            with self._lock:
                self.invocations += 1
                index = self.invocations
            if self.platform is None:
                ok = yield from self._invoke_legacy_g(body, extra_ms, index)
            else:
                ok = yield from self._invoke_platform_g(body, extra_ms, index)
            if not ok:
                return

    def submit(self, body: Callable[[], Any], extra_ms: float = 0.0) -> None:
        if self._closed:
            return  # job resolved; drop late invocations (idempotent)
        self._q.put((body, extra_ms))

    def close(self) -> None:
        self._closed = True
        for _ in range(self._n_lanes):
            self._q.put(None)


class FanoutProxy:
    """KV Store Proxy: parallelizes large fan-outs (paper §IV-D).

    The executor publishes a fan-out message (fan-out id + payload keys)
    on the proxy channel; the proxy resolves the out-edges from the DAG it
    received at workflow start and issues the invocations through its own
    Fan-out Invoker pool.
    """

    CHANNEL = "__proxy__/fanout"

    def __init__(self, kv, invokers: InvokerPool):
        self.kv = kv
        self.invokers = invokers
        self._sub = kv.subscribe(self.CHANNEL)
        self._stop = threading.Event()
        self.handled_fanouts = 0
        kv.clock.spawn(self._serve, name="kv-proxy")

    def _serve(self):
        # Event-driven: the proxy parks on its subscription (costing
        # zero wall time — and, on the event substrate, zero threads)
        # until a fan-out message or the ``None`` shutdown sentinel
        # published by ``close``.
        while not self._stop.is_set():
            msg = yield ("get", self._sub, None)
            if msg is None:
                return
            spawn_fns = msg["spawns"]  # list of zero-arg callables
            self.handled_fanouts += 1
            for fn in spawn_fns:
                self.invokers.submit(fn)

    def close_g(self):
        self._stop.set()
        # The shutdown sentinel is already queued on our subscription, so
        # releasing it immediately after is safe — and mandatory on a
        # substrate that outlives this job: an abandoned proxy
        # subscription would receive (and leak) every later job's
        # fan-out messages on this channel name.
        yield from self.kv.publish_g(self.CHANNEL, None)
        self.kv.unsubscribe(self.CHANNEL, self._sub)

    def close(self) -> None:
        run_effects(self.kv.clock, self.close_g())
