"""Lambda invocation machinery: parallel invoker pool + large-fan-out proxy.

Invoking an AWS Lambda costs ~50 ms through boto3 (paper §III-C), so
invocation throughput is governed by how many invoker processes issue
calls concurrently:

- The scheduler's *Initial Task Executor Invokers* launch one executor per
  static schedule, in parallel (paper §IV-C).
- A Task Executor performing a *small* fan-out makes its own invocations.
- A fan-out wider than ``proxy_threshold`` publishes one message to the
  KV Store Proxy, whose Fan-out Invokers make the invocations in parallel
  (paper §IV-D "Large Fan-out Task Invocations").

Each invoker lane charges the invocation latency serially per call; P
lanes give P× invocation throughput — the (near-)linear speedup of
§III-C. Latency per call is drawn from ``CostModel.invoke_draw``: a
seeded lognormal jitter on ``invoke_ms`` plus a cold start with
probability ``1 - warm_fraction`` — a *distribution*, not a constant,
once those knobs are set, and reproducible because draws are keyed on
the invocation index (which the virtual clock makes deterministic).

All blocking (work queues, lane threads) goes through the engine clock's
primitives, so under the virtual clock an idle invoker lane costs zero
wall time and never holds back virtual-time advancement.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from repro.core.kvstore import CostModel
from repro.core.simclock import BaseClock


class InvokerPool:
    """N invoker lanes; each lane issues invocations serially.

    ``submit`` enqueues an invocation request; a free lane picks it up,
    charges the invocation API latency (jitter + cold-start drawn from
    the cost model's seeded distribution), then hands the executor body
    to the runtime pool.
    """

    def __init__(
        self,
        n_invokers: int,
        cost: CostModel,
        clock: BaseClock,
        runtime_pool: Any,
        name: str = "invoker",
    ):
        self.cost = cost
        self.clock = clock
        self.runtime_pool = runtime_pool
        self._q = clock.queue()
        self.invocations = 0
        self.cold_starts = 0
        self._lock = threading.Lock()
        self._closed = False
        self._n_lanes = max(1, n_invokers)
        for i in range(self._n_lanes):
            clock.spawn(self._lane, name=f"{name}-{i}")

    def _lane(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            body, extra_ms = item
            with self._lock:
                self.invocations += 1
                index = self.invocations
            invoke_ms, cold = self.cost.invoke_draw(index)
            if cold:
                with self._lock:
                    self.cold_starts += 1
            # Invocation API latency is paid serially per lane.
            self.clock.charge(invoke_ms + extra_ms)
            try:
                self.runtime_pool.submit(body)
            except RuntimeError:
                # Runtime already shut down: the job has resolved; late
                # (retry/speculative) invocations are safe to drop.
                return

    def submit(self, body: Callable[[], Any], extra_ms: float = 0.0) -> None:
        if self._closed:
            return  # job resolved; drop late invocations (idempotent)
        self._q.put((body, extra_ms))

    def close(self) -> None:
        self._closed = True
        for _ in range(self._n_lanes):
            self._q.put(None)


class FanoutProxy:
    """KV Store Proxy: parallelizes large fan-outs (paper §IV-D).

    The executor publishes a fan-out message (fan-out id + payload keys)
    on the proxy channel; the proxy resolves the out-edges from the DAG it
    received at workflow start and issues the invocations through its own
    Fan-out Invoker pool.
    """

    CHANNEL = "__proxy__/fanout"

    def __init__(self, kv, invokers: InvokerPool):
        self.kv = kv
        self.invokers = invokers
        self._sub = kv.subscribe(self.CHANNEL)
        self._stop = threading.Event()
        self.handled_fanouts = 0
        kv.clock.spawn(self._serve, name="kv-proxy")

    def _serve(self) -> None:
        # Event-driven: the proxy blocks on its subscription (costing
        # zero wall time under the virtual clock) until a fan-out message
        # or the ``None`` shutdown sentinel published by ``close``.
        while not self._stop.is_set():
            msg = self._sub.get()
            if msg is None:
                return
            spawn_fns = msg["spawns"]  # list of zero-arg callables
            self.handled_fanouts += 1
            for fn in spawn_fns:
                self.invokers.submit(fn)

    def close(self) -> None:
        self._stop.set()
        self.kv.publish(self.CHANNEL, None)
