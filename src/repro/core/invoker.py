"""Lambda invocation machinery: parallel invoker pool + large-fan-out proxy.

Invoking an AWS Lambda costs ~50 ms through boto3 (paper §III-C), so
invocation throughput is governed by how many invoker processes issue
calls concurrently:

- The scheduler's *Initial Task Executor Invokers* launch one executor per
  static schedule, in parallel (paper §IV-C).
- A Task Executor performing a *small* fan-out makes its own invocations.
- A fan-out wider than ``proxy_threshold`` publishes one message to the
  KV Store Proxy, whose Fan-out Invokers make the invocations in parallel
  (paper §IV-D "Large Fan-out Task Invocations").

Each invoker lane charges ``invoke_ms`` serially per call; P lanes give P×
invocation throughput — the (near-)linear speedup of §III-C.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

from repro.core.kvstore import Clock, CostModel


class InvokerPool:
    """N invoker lanes; each lane issues invocations serially at invoke_ms.

    ``submit`` enqueues an invocation request; a free lane picks it up,
    charges the invocation API latency (plus cold-start when the warm pool
    misses), then hands the executor body to the runtime thread pool.
    """

    def __init__(
        self,
        n_invokers: int,
        cost: CostModel,
        clock: Clock,
        runtime_pool: ThreadPoolExecutor,
        name: str = "invoker",
    ):
        self.cost = cost
        self.clock = clock
        self.runtime_pool = runtime_pool
        self._q: "queue.Queue[tuple[Callable[[], Any], float] | None]" = queue.Queue()
        self._lanes = [
            threading.Thread(target=self._lane, name=f"{name}-{i}", daemon=True)
            for i in range(max(1, n_invokers))
        ]
        self.invocations = 0
        self._lock = threading.Lock()
        self._closed = False
        for t in self._lanes:
            t.start()

    def _lane(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            body, extra_ms = item
            # Invocation API latency is paid serially per lane.
            self.clock.charge(self.cost.invoke_ms + extra_ms)
            with self._lock:
                self.invocations += 1
            try:
                self.runtime_pool.submit(body)
            except RuntimeError:
                # Runtime already shut down: the job has resolved; late
                # (retry/speculative) invocations are safe to drop.
                return

    def submit(self, body: Callable[[], Any], extra_ms: float = 0.0) -> None:
        if self._closed:
            return  # job resolved; drop late invocations (idempotent)
        self._q.put((body, extra_ms))

    def close(self) -> None:
        self._closed = True
        for _ in self._lanes:
            self._q.put(None)


class FanoutProxy:
    """KV Store Proxy: parallelizes large fan-outs (paper §IV-D).

    The executor publishes a fan-out message (fan-out id + payload keys)
    on the proxy channel; the proxy resolves the out-edges from the DAG it
    received at workflow start and issues the invocations through its own
    Fan-out Invoker pool.
    """

    CHANNEL = "__proxy__/fanout"

    def __init__(self, kv, invokers: InvokerPool):
        self.kv = kv
        self.invokers = invokers
        self._sub = kv.subscribe(self.CHANNEL)
        self._thread = threading.Thread(
            target=self._serve, name="kv-proxy", daemon=True
        )
        self._stop = threading.Event()
        self.handled_fanouts = 0
        self._thread.start()

    def _serve(self) -> None:
        while not self._stop.is_set():
            try:
                msg = self._sub.get(timeout=0.05)
            except queue.Empty:
                continue
            if msg is None:
                return
            spawn_fns = msg["spawns"]  # list of zero-arg callables
            self.handled_fanouts += 1
            for fn in spawn_fns:
                self.invokers.submit(fn)

    def close(self) -> None:
        self._stop.set()
        self.kv.publish(self.CHANNEL, None)
