"""Trigger bus: event-fired job submissions over the shared KV store.

The paper's engine runs DAGs handed to it; a serverless platform also
has to *start* them — on a timer, on a storage write, on another job
finishing, on an external event (Triggerflow's trigger model). This
module adds that control-plane layer on top of the PR 5 orchestrator:

- :class:`TriggerRule`  — a persistent event->job rule. Rules are
  journaled (``journal_append_g``) in a ``__triggers__`` namespace of
  the shared store, exactly like the PR 7 job state machine, so they
  survive orchestrator crashes and replay through ``recover()``.
- :class:`TriggerBus`   — matches events against the installed rules
  and journals every *fire* (rule match -> job submission) under a
  deterministic fire key BEFORE the job is submitted. Replay rebuilds
  the fired-set, so a recovering orchestrator neither re-fires a
  journaled fire (no duplicate job) nor loses one journaled without a
  PENDING record (the fire's journal payload carries the full job
  spec).
- four event sources, all funnelled into the orchestrator's single
  dispatch queue:

  ``timer``          — a per-rule clock actor charges ``period_ms``
                       between ticks (bounded by ``max_fires``).
  ``kv_write``       — ``ShardedKVStore.add_write_listener``: every
                       durable object write is offered, host-side, to
                       the bus's prefix filters. Rules may aggregate
                       matching writes into tumbling/sliding windows
                       by the event time encoded in the key; each
                       window close fires one job.
  ``job_completed``  — the orchestrator feeds every journaled terminal
                       transition back through the bus.
  ``external``       — ``emit_g`` publishes on a charged ``__triggers__``
                       pub/sub channel; a relay actor forwards to the
                       dispatch queue. An external event may also flush
                       the open windows (end-of-stream).

- :class:`StreamConfig` / :func:`stream_source` — a seeded Poisson
  event writer (the streaming workload of fig19): event ``i`` is a
  durable write of ``<prefix><i>@<event_ms>`` — the event time rides
  in the key, so a crashed-and-recovered orchestrator re-deriving the
  stream assigns every event to the same window and re-computes the
  same fire keys.
- :class:`StreamingReport` — steady-state metrics over a run:
  sustained window-jobs/s, p50/p95/p99 event-to-result latency,
  backlog depth.

Determinism: everything runs on the shared virtual clock; a fresh run
of the same config is bit-identical (fig19 gates this across runs AND
across the event/thread substrates).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.core.kvstore import NAMESPACE_SEP, PURGED, ShardedKVStore

TRIGGER_NS = "__triggers__"
RULE_JOURNAL = "rules"
FIRE_JOURNAL = "fires"
EVENT_CHANNEL = "events"
TRIGGER_SOURCES = ("timer", "kv_write", "job_completed", "external")
# relay-stop sentinel event name (never matches a rule)
_CLOSE = "__close__"


# ---------------------------------------------------------------------------
# Rule / stream configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriggerRule:
    """One persistent trigger: an event source, its match parameters,
    and the job template (``action``) each fire submits.

    ``action`` is a reconstructible job spec fragment — at least
    ``app``, ``size`` and ``tenant`` (``compute_ms``/``payload_bytes``
    optional) — instantiated into a ``JobRequest`` with a bus-assigned
    ``job_id`` and the fire time as ``arrival_ms``.

    Fire keys are deterministic per source so journal replay can
    de-duplicate across crash generations:

    ==============  =========================================
    timer           ``<rule_id>#t<tick>``
    kv_write        ``<rule_id>#w<window>`` (windowed) or
                    ``<rule_id>#<key>`` (per-write)
    job_completed   ``<rule_id>#<job_id of the finished job>``
    external        ``<rule_id>#<event dedup key>``
    ==============  =========================================
    """

    rule_id: str
    source: str
    action: "Mapping[str, Any]"
    # -- timer --------------------------------------------------------------
    period_ms: float = 0.0
    # timer: REQUIRED tick bound (the simulation must terminate).
    # Other sources: optional fire cap, 0 = unbounded.
    max_fires: int = 0
    # -- kv_write -----------------------------------------------------------
    key_prefix: str = ""          # store-qualified key prefix to match
    window_ms: float = 0.0        # > 0: aggregate matches into windows
    slide_ms: float = 0.0         # 0 = tumbling (slide == window)
    min_window_events: int = 1    # windows below this never fire
    # -- job_completed ------------------------------------------------------
    job_app: str = ""             # only completions of this app ("" = any)
    every_n: int = 1              # ... whose job_id % every_n == 0
    # -- external -----------------------------------------------------------
    event: str = ""               # event name to match
    flush_windows: bool = False   # this event also closes open windows

    def __post_init__(self) -> None:
        if not self.rule_id or "#" in self.rule_id:
            raise ValueError("rule_id must be non-empty and '#'-free")
        if self.source not in TRIGGER_SOURCES:
            raise ValueError(
                f"source must be one of {TRIGGER_SOURCES}, "
                f"got {self.source!r}")
        if not isinstance(self.action, Mapping) or not (
                {"app", "size", "tenant"} <= set(self.action)):
            raise ValueError(
                "action must be a mapping with at least app/size/tenant")
        object.__setattr__(self, "action", dict(self.action))
        for name in ("period_ms", "window_ms", "slide_ms"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be non-negative")
        for name in ("max_fires",):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be non-negative")
        for name in ("min_window_events", "every_n"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1")
        if self.source == "timer":
            if self.period_ms <= 0:
                raise ValueError("timer rules need period_ms > 0")
            if self.max_fires < 1:
                raise ValueError(
                    "timer rules need max_fires >= 1 (bounded ticks)")
        if self.source == "kv_write" and not self.key_prefix:
            raise ValueError("kv_write rules need a non-empty key_prefix")
        if self.window_ms > 0 and self.slide_ms > self.window_ms:
            raise ValueError("slide_ms must be <= window_ms")
        if self.source == "external" and not self.event:
            raise ValueError("external rules need a non-empty event name")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """The seeded Poisson event stream fig19 feeds the bus."""

    n_events: int = 256
    rate_per_s: float = 50.0
    seed: int = 7
    payload_bytes: int = 64
    namespace: str = "stream"     # store namespace the events land in
    key_prefix: str = "ev/"
    flush_event: str = ""         # external event emitted after the last
    # write ("" = no end-of-stream emit)

    def __post_init__(self) -> None:
        if self.n_events < 1:
            raise ValueError("n_events must be >= 1")
        if self.rate_per_s <= 0:
            raise ValueError("rate_per_s must be positive")
        if self.payload_bytes < 0:
            raise ValueError("payload_bytes must be non-negative")
        if not self.namespace or NAMESPACE_SEP in self.namespace:
            raise ValueError(
                f"namespace must be non-empty and {NAMESPACE_SEP!r}-free")
        if not self.key_prefix:
            raise ValueError("key_prefix must be non-empty")

    @property
    def store_prefix(self) -> str:
        """The store-qualified prefix a ``kv_write`` rule matches."""
        return f"{self.namespace}{NAMESPACE_SEP}{self.key_prefix}"


def stream_arrivals(cfg: StreamConfig) -> "list[float]":
    """Cumulative event times in ms — a pure function of the config
    (the determinism and crash-replay gates both rerun it)."""
    import random

    rng = random.Random(cfg.seed)
    out: "list[float]" = []
    t = 0.0
    for _ in range(cfg.n_events):
        t += rng.expovariate(cfg.rate_per_s) * 1e3
        out.append(t)
    return out


def stream_key(cfg: StreamConfig, i: int, event_ms: float) -> str:
    """``<prefix><seq>@<event_ms>`` — event time encoded in the key, so
    window assignment survives crash replay (wall clock moves on, the
    key does not)."""
    return f"{cfg.key_prefix}{i:06d}@{event_ms:.3f}"


def _event_ms(key: str, default: float) -> float:
    _, _, ts = key.rpartition("@")
    try:
        return float(ts)
    except ValueError:
        return default


# ---------------------------------------------------------------------------
# Steady-state report
# ---------------------------------------------------------------------------


def _percentile(sorted_vals: "list[float]", q: float) -> float:
    if not sorted_vals:
        return 0.0
    rank = max(1, -(-len(sorted_vals) * q // 100))  # ceil(n*q/100)
    return sorted_vals[int(rank) - 1]


@dataclasses.dataclass
class StreamingReport:
    events: int
    fires: "dict[str, int]"        # source type -> jobs fired
    windows_closed: int
    window_jobs_completed: int
    sustained_jobs_per_s: float    # window jobs / (first fire->last done)
    event_to_result_p50_s: float
    event_to_result_p95_s: float
    event_to_result_p99_s: float
    mean_backlog: float            # fired-not-yet-done window jobs,
    max_backlog: int               # sampled at every fire/completion
    duplicate_fires_suppressed: int


# ---------------------------------------------------------------------------
# The bus
# ---------------------------------------------------------------------------


class TriggerBus:
    """Rule store + event matcher + fire journal on one shared store.

    One bus instance per orchestrator generation. All *matching* is
    host-side (pure bookkeeping); all *durability* (rule and fire
    journals, the external-event channel) is charged through the
    ``__triggers__`` namespace of the shared store. The orchestrator's
    dispatch loop is the single consumer: sources enqueue raw events
    onto its queue, and it runs ``fire_g`` for every match the bus
    reports.
    """

    def __init__(self, kv: ShardedKVStore, clock: Any,
                 id_base: int = 1_000_000):
        self.kv = kv
        self.trig = kv.namespace(TRIGGER_NS)
        self.clock = clock
        self.id_base = id_base
        self.rules: "dict[str, TriggerRule]" = {}
        self._next_job = id_base
        # fire_key -> journaled fire record (journal replay rebuilds it)
        self._fired: "dict[str, dict[str, Any]]" = {}
        self._fires_by_rule: "dict[str, int]" = {}
        self._job_rule: "dict[int, TriggerRule]" = {}
        self._queue: Any = None
        self._listener: Any = None
        # kv_write bookkeeping (this generation; replay regenerates)
        self._seen_writes: "set[str]" = set()
        # rule_id -> window_idx -> [(key, event_ms, arrival_ms), ...]
        self._windows: "dict[str, dict[int, list]]" = {}
        self._watermark: "dict[str, float]" = {}
        # steady-state metrics
        self._job_events: "dict[int, list[float]]" = {}
        self._latencies: "list[float]" = []
        self._outstanding: "set[int]" = set()
        self._backlog_samples: "list[int]" = []
        self._first_fire_ms: "float | None" = None
        self._last_window_done_ms = 0.0
        self._window_jobs_done = 0
        self._suppressed = 0

    # -- source plumbing ----------------------------------------------------
    def attach(self, queue: Any) -> None:
        """Start observing durable writes, forwarding matches of any
        ``kv_write`` rule's prefix onto the dispatch ``queue``
        host-side (the listener runs inside the writer's op and must
        not charge)."""
        self._queue = queue

        def on_write(key: str, nbytes: int) -> None:
            for rule in self.rules.values():
                if (rule.source == "kv_write"
                        and key.startswith(rule.key_prefix)):
                    queue.put(("event", {
                        "source": "kv_write", "key": key, "nbytes": nbytes,
                        "at_ms": self.clock.now_ms()}))
                    return

        self._listener = on_write
        self.kv.add_write_listener(on_write)

    def detach(self) -> None:
        """Stop observing writes (a recovering orchestrator detaches
        the dead generation's bus before attaching its own)."""
        if self._listener is not None:
            self.kv.remove_write_listener(self._listener)
            self._listener = None

    def relay_actor(self, queue: Any):
        """The external-event relay: subscribed to the charged
        ``__triggers__`` pub/sub channel, forwards every emit onto the
        dispatch queue, exits on the close sentinel (or on ``PURGED``
        if the namespace is dropped under it) and always reports
        ``source_done``."""
        sub = self.trig.subscribe(EVENT_CHANNEL)
        clock = self.clock

        def relay():
            try:
                while True:
                    msg = yield ("get", sub, None)
                    if msg is PURGED or msg.get("name") == _CLOSE:
                        break
                    queue.put(("event", {
                        "source": "external", "name": msg["name"],
                        "ekey": msg.get("ekey", msg["name"]),
                        "payload": msg.get("payload"),
                        "at_ms": clock.now_ms()}))
            finally:
                self.trig.unsubscribe(EVENT_CHANNEL, sub)
                queue.put(("source_done", "relay"))

        return relay

    def timer_actor(self, rule: TriggerRule, queue: Any):
        """One bounded tick source per timer rule."""
        clock = self.clock

        def timer():
            for i in range(rule.max_fires):
                yield ("charge", rule.period_ms)
                queue.put(("event", {
                    "source": "timer", "rule_id": rule.rule_id,
                    "seq": i, "at_ms": clock.now_ms()}))
            queue.put(("source_done", f"timer:{rule.rule_id}"))

        return timer

    def emit_g(self, name: str, key: "str | None" = None,
               payload: Any = None):
        """Publish an external event (charged pub/sub into
        ``__triggers__``). ``key`` de-duplicates re-emits across crash
        generations — same key, same fire."""
        yield from self.trig.publish_g(EVENT_CHANNEL, {
            "name": name, "ekey": key if key is not None else name,
            "payload": payload})

    def close_g(self):
        """Stop the relay (end of run)."""
        yield from self.trig.publish_g(EVENT_CHANNEL, {"name": _CLOSE})

    # -- rule durability ----------------------------------------------------
    def add_rule_g(self, rule: TriggerRule):
        """Journal-then-install (the ``JobStateMachine.record_g``
        discipline): once this returns, the rule survives the
        orchestrator."""
        if rule.rule_id in self.rules:
            raise ValueError(f"duplicate rule_id {rule.rule_id!r}")
        yield from self.trig.journal_append_g(
            RULE_JOURNAL, {"rule": dataclasses.asdict(rule)})
        self.rules[rule.rule_id] = rule

    def replay_g(self):
        """Rebuild rules and the fired-set from the journals (crash
        recovery). Returns the number of entries folded."""
        n = 0
        if self.trig.journal_len(RULE_JOURNAL):
            entries = yield from self.trig.journal_scan_g(RULE_JOURNAL)
            for e in entries:
                rule = TriggerRule(**e["rule"])
                self.rules[rule.rule_id] = rule
                n += 1
        if self.trig.journal_len(FIRE_JOURNAL):
            fires = yield from self.trig.journal_scan_g(FIRE_JOURNAL)
            for rec in fires:
                self._fired[rec["fire_key"]] = rec
                self._fires_by_rule[rec["rule_id"]] = (
                    self._fires_by_rule.get(rec["rule_id"], 0) + 1)
                self._next_job = max(self._next_job, rec["job_id"] + 1)
                n += 1
        return n

    def fired_records(self) -> "list[dict[str, Any]]":
        """All journaled fires, in fire_key order (recovery walks this
        to find fires whose PENDING record never landed)."""
        return [self._fired[k] for k in sorted(self._fired)]

    # -- matching -----------------------------------------------------------
    def match(self, ev: "dict[str, Any]") -> "list[dict[str, Any]]":
        """Offer one event to every rule; returns the fires now due as
        ``{rule, fire_key, event_times}`` dicts. Pure host-side
        bookkeeping — the caller journals each fire with ``fire_g``
        before acting on it."""
        source = ev["source"]
        out: "list[dict[str, Any]]" = []
        if source == "timer":
            rule = self.rules.get(ev["rule_id"])
            if rule is not None and rule.source == "timer":
                out.extend(self._due(rule, f"t{ev['seq']}", [ev["at_ms"]]))
        elif source == "kv_write":
            key = ev["key"]
            if key in self._seen_writes:
                return out  # duplicate delivery (crash replay overlap)
            self._seen_writes.add(key)
            for rule in self._rules_of("kv_write"):
                if not key.startswith(rule.key_prefix):
                    continue
                if rule.window_ms <= 0:
                    out.extend(self._due(rule, key, [ev["at_ms"]]))
                else:
                    out.extend(self._window_event(rule, ev))
        elif source == "job_completed":
            rec = ev["record"]
            for rule in self._rules_of("job_completed"):
                if rule.job_app and rec.get("app") != rule.job_app:
                    continue
                if rec["job_id"] % rule.every_n:
                    continue
                out.extend(self._due(rule, str(rec["job_id"]),
                                     [ev["at_ms"]]))
        elif source == "external":
            for rule in self._rules_of("external"):
                if rule.event != ev["name"]:
                    continue
                out.extend(self._due(rule, ev["ekey"], [ev["at_ms"]]))
                if rule.flush_windows:
                    out.extend(self.flush())
        return out

    def flush(self) -> "list[dict[str, Any]]":
        """Close every open window of every windowed rule (end of
        stream)."""
        out: "list[dict[str, Any]]" = []
        for rule in self._rules_of("kv_write"):
            if rule.window_ms > 0:
                out.extend(self._close_windows(rule, float("inf")))
        return out

    def _rules_of(self, source: str) -> "list[TriggerRule]":
        return [r for r in self.rules.values() if r.source == source]

    def _due(self, rule: TriggerRule, suffix: str,
             event_times: "list[float]") -> "list[dict[str, Any]]":
        if rule.max_fires and \
                self._fires_by_rule.get(rule.rule_id, 0) >= rule.max_fires:
            return []
        return [{"rule": rule, "fire_key": f"{rule.rule_id}#{suffix}",
                 "event_times": list(event_times)}]

    def _window_event(self, rule: TriggerRule,
                      ev: "dict[str, Any]") -> "list[dict[str, Any]]":
        """Assign one write to its window(s) by the event time in the
        key, advance the rule's watermark, close what's due. Late
        events (crash-replay interleavings deliver out of order) still
        land: a closed-but-unfired window fires as soon as it has an
        event, and journal de-dup keeps re-fires out."""
        rid = rule.rule_id
        ts = _event_ms(ev["key"], ev["at_ms"])
        slide = rule.slide_ms or rule.window_ms
        windows = self._windows.setdefault(rid, {})
        hi = int(ts // slide)
        lo = max(0, int((ts - rule.window_ms) // slide) + 1)
        for w in range(lo, hi + 1):
            # window w covers [w*slide, w*slide + window_ms)
            if ts < w * slide or ts >= w * slide + rule.window_ms:
                continue
            windows.setdefault(w, []).append(
                (ev["key"], ts, ev["at_ms"]))
        self._watermark[rid] = max(self._watermark.get(rid, 0.0), ts)
        return self._close_windows(rule, self._watermark[rid])

    def _close_windows(self, rule: TriggerRule,
                       watermark: float) -> "list[dict[str, Any]]":
        rid = rule.rule_id
        slide = rule.slide_ms or rule.window_ms
        windows = self._windows.setdefault(rid, {})
        out: "list[dict[str, Any]]" = []
        for w in sorted(windows):
            if w * slide + rule.window_ms > watermark:
                break
            events = windows.pop(w)
            if len(events) < rule.min_window_events:
                continue
            out.extend(self._due(rule, f"w{w}",
                                 [arr for _, _, arr in events]))
        return out

    # -- firing -------------------------------------------------------------
    def fire_g(self, due: "dict[str, Any]", at_ms: float):
        """Journal one fire and return the reconstructible job spec —
        or ``None`` when the fire key is already journaled (a crash
        generation fired it; the job journal owns it from here)."""
        rule: TriggerRule = due["rule"]
        fire_key: str = due["fire_key"]
        if fire_key in self._fired:
            self._suppressed += 1
            return None
        job_id = self._next_job
        self._next_job += 1
        spec: "dict[str, Any]" = {
            "job_id": job_id, "arrival_ms": at_ms,
            "compute_ms": 20.0, "payload_bytes": 0,
        }
        spec.update(rule.action)
        rec = {"fire_key": fire_key, "rule_id": rule.rule_id,
               "source": rule.source, "job_id": job_id, "at_ms": at_ms,
               "spec": spec}
        yield from self.trig.journal_append_g(FIRE_JOURNAL, rec)
        self._fired[fire_key] = rec
        self._fires_by_rule[rule.rule_id] = (
            self._fires_by_rule.get(rule.rule_id, 0) + 1)
        self._job_rule[job_id] = rule
        if rule.source == "kv_write":
            self._job_events[job_id] = list(due["event_times"])
            self._outstanding.add(job_id)
            self._backlog_samples.append(len(self._outstanding))
            if self._first_fire_ms is None:
                self._first_fire_ms = at_ms
        return spec

    # -- completion feedback ------------------------------------------------
    def job_finished(self, rec: "dict[str, Any]", end_ms: float) -> None:
        """Steady-state accounting for a finished trigger-fired job
        (host-side; the orchestrator calls it after journaling the
        terminal transition)."""
        job_id = rec["job_id"]
        rule = self._job_rule.get(job_id)
        if rule is None or rule.source != "kv_write":
            return
        self._outstanding.discard(job_id)
        self._backlog_samples.append(len(self._outstanding))
        if rec.get("error") is None:
            self._window_jobs_done += 1
            self._last_window_done_ms = max(
                self._last_window_done_ms, end_ms)
            for arr in self._job_events.pop(job_id, ()):
                self._latencies.append((end_ms - arr) / 1e3)

    # -- reporting ----------------------------------------------------------
    def report(self, n_events: int = 0) -> StreamingReport:
        fires: "dict[str, int]" = {s: 0 for s in TRIGGER_SOURCES}
        for rec in self._fired.values():
            fires[rec["source"]] = fires.get(rec["source"], 0) + 1
        lat = sorted(self._latencies)
        span_s = 0.0
        if self._first_fire_ms is not None:
            span_s = (self._last_window_done_ms - self._first_fire_ms) / 1e3
        backlog = self._backlog_samples
        return StreamingReport(
            events=n_events,
            fires=fires,
            windows_closed=fires.get("kv_write", 0),
            window_jobs_completed=self._window_jobs_done,
            sustained_jobs_per_s=(
                self._window_jobs_done / span_s if span_s > 0 else 0.0),
            event_to_result_p50_s=_percentile(lat, 50),
            event_to_result_p95_s=_percentile(lat, 95),
            event_to_result_p99_s=_percentile(lat, 99),
            mean_backlog=(sum(backlog) / len(backlog) if backlog else 0.0),
            max_backlog=max(backlog, default=0),
            duplicate_fires_suppressed=self._suppressed,
        )


# ---------------------------------------------------------------------------
# The streaming source
# ---------------------------------------------------------------------------


def stream_source(cfg: StreamConfig, kv: ShardedKVStore, clock: Any,
                  bus: TriggerBus, queue: Any):
    """The Poisson event writer as a clock actor: charges each
    inter-arrival gap, durably writes ``stream_key(i, t_i)`` (the write
    listener turns that into a ``kv_write`` event), optionally emits
    the end-of-stream external event, and reports ``source_done``.

    Recovery: a fresh generation re-runs the whole source. Re-writes
    of already-stored keys are value-identical overwrites; the bus
    de-duplicates their events by key and the fire journal
    de-duplicates the window fires, so replay neither loses nor
    duplicates a window job."""
    ns = kv.namespace(cfg.namespace)
    arrivals = stream_arrivals(cfg)

    def source():
        t = 0.0
        for i, ts in enumerate(arrivals):
            gap = ts - t
            t = ts
            if gap > 0:
                yield ("charge", gap)
            yield from ns.put_g(stream_key(cfg, i, ts), ts,
                                nbytes=max(1, cfg.payload_bytes))
        if cfg.flush_event:
            yield from bus.emit_g(cfg.flush_event, key="flush")
        queue.put(("source_done", "stream"))

    return source
