"""DAG compiler: composable rewrite passes run before scheduling.

The paper attributes WUKONG's wins to shipping static schedules and
keeping data local to executors (§IV-B–C, §V-B); the follow-up work
(*Wukong: A Scalable and Locality-Enhanced Framework for Serverless
Parallel Computing*, PAPERS.md) goes further with task clustering and
delayed I/O to cut KV-store round trips. This module implements that
compiler layer as three composable passes over a ``DAG``:

1. **Linear-chain fusion** (``fuse_chains``): a dependency edge u -> v
   with out-degree(u) == 1 and in-degree(v) == 1 carries a value that
   exactly one consumer will ever read. Maximal runs of such edges are
   collapsed into one fused task keyed by the chain tail, so the
   intermediate values never exist as graph edges at all — they cannot
   hit the KV store, cannot be re-read, and cost zero scheduling
   overhead. Fusion never crosses a fan-in or fan-out boundary: the
   chain head may itself be a fan-in node (the boundary is *before* the
   head) and the tail may fan out (the boundary is *after* the tail),
   but no interior edge touches a node with in-degree or out-degree
   above one.

2. **Task clustering** (``cluster_tasks``): annotates every node with a
   cluster id — the head of the static *become-path* that a Task
   Executor walks (trivial fan-outs and first-child become edges), with
   fan-in nodes joining the cluster of their primary (first) parent.
   The executor uses the annotation to *delay* KV writes at fan-in
   boundaries: arrivals deposit their locally-held inputs atomically
   with the dependency-counter increment (one round trip, not two), and
   the last arriver never writes its own value at all — it keeps the
   object in executor-local memory and carries it through the fan-in.
   This is the delayed-I/O locality optimization from the follow-up
   paper; it deterministically saves one KV ``set`` (plus one base
   round-trip per arriver) at every clustered fan-in node.

3. **Fan-out coalescing** (``coalesce_fanouts``): sibling leaves that
   share an identical child signature are grouped into batches (kept
   below the proxy threshold) so one executor invocation runs the whole
   batch, draining the invoker queue ``batch`` times faster on wide
   fan-outs; the executor applies the same batching to the children it
   invokes at a runtime fan-out.

Every pass is independently switchable through ``OptimizeConfig`` so
§V-B-style factor ablations can measure each one in isolation. Passes
rewrite/annotate only; correctness is preserved by construction: the
optimized DAG computes exactly the same root values as a sequential
topological evaluation of the original DAG (see tests/test_optimize.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

from repro.analysis.dagcheck import check_compiled
from repro.core.dag import DAG, Task, TaskRef


@dataclasses.dataclass(frozen=True)
class OptimizeConfig:
    """Which passes run, and their knobs (all passes default on)."""

    fuse_chains: bool = True
    cluster_tasks: bool = True
    coalesce_fanouts: bool = True
    max_fusion_len: int = 64     # split pathological chains for retry granularity
    coalesce_batch: int = 7      # max leaves per batched invocation; kept
                                 # below the default proxy threshold (8) so
                                 # batched spawns stay on the fast path


#: Convenience preset: every pass enabled with defaults.
ALL_PASSES = OptimizeConfig()
#: Convenience preset: the identity pipeline (compile_dag returns an
#: annotated but unrewritten graph).
NO_PASSES = OptimizeConfig(
    fuse_chains=False, cluster_tasks=False, coalesce_fanouts=False
)


@dataclasses.dataclass(frozen=True)
class PassStats:
    """One row of the compiler report (surfaced in ``JobReport``)."""

    name: str
    before_tasks: int
    after_tasks: int
    detail: str = ""


class CompiledDAG(DAG):
    """A ``DAG`` plus optimizer annotations.

    ``clusters``        — task key -> cluster id (head of its become-path);
                          empty when the clustering pass is off.
    ``delayed_fanins``  — fan-in nodes where executors use the atomic
                          deposit-and-increment protocol (delayed I/O).
    ``leaf_batches``    — tuple of leaf-key tuples; each batch is started
                          by ONE executor invocation. Covers every leaf
                          (singleton batches when coalescing is off).
    ``fused``           — fused task key -> original chain keys, head first.
    ``pass_stats``      — per-pass before/after report.
    """

    def __init__(
        self,
        tasks: Iterable[Task],
        clusters: Mapping[str, str] | None = None,
        delayed_fanins: Iterable[str] = (),
        leaf_batches: Iterable[tuple[str, ...]] | None = None,
        fused: Mapping[str, tuple[str, ...]] | None = None,
        pass_stats: Iterable[PassStats] = (),
        coalesce_batch: int = 0,
    ):
        super().__init__(tasks)
        self.clusters: dict[str, str] = dict(clusters or {})
        self.delayed_fanins: frozenset[str] = frozenset(delayed_fanins)
        self.leaf_batches: tuple[tuple[str, ...], ...] = (
            tuple(tuple(b) for b in leaf_batches)
            if leaf_batches is not None
            else tuple((leaf,) for leaf in self.leaves)
        )
        self.fused: dict[str, tuple[str, ...]] = dict(fused or {})
        self.pass_stats: tuple[PassStats, ...] = tuple(pass_stats)
        self.coalesce_batch = coalesce_batch


# ---------------------------------------------------------------------------
# Pass 1: linear-chain fusion
# ---------------------------------------------------------------------------


def fusible_edges(dag: DAG) -> set[tuple[str, str]]:
    """Edges u->v collapsible without crossing a fan-in/fan-out boundary."""
    return {
        (u, vs[0])
        for u, vs in dag.children.items()
        if len(vs) == 1 and len(dag.deps[vs[0]]) == 1
    }


def find_chains(dag: DAG, max_len: int = 64) -> list[list[str]]:
    """Maximal runs of fusible edges, as key lists (head first).

    Fusible edges form vertex-disjoint paths by construction (a node has
    at most one fusible out-edge and one fusible in-edge), so a simple
    head-scan enumerates them all.
    """
    edges = fusible_edges(dag)
    has_fusible_in = {v for _, v in edges}
    seg_len = max(2, max_len)
    chains: list[list[str]] = []
    for head in dag.tasks:
        if head in has_fusible_in:
            continue  # interior or tail of some chain
        chain = [head]
        while True:
            children = dag.children[chain[-1]]
            if not children or (chain[-1], children[0]) not in edges:
                break
            chain.append(children[0])
        # Disjoint segments of at most seg_len nodes; the edge between two
        # adjacent segments survives as a regular (tail -> next head) edge.
        for i in range(0, len(chain), seg_len):
            seg = chain[i:i + seg_len]
            if len(seg) > 1:
                chains.append(seg)
    return chains


def _make_fused_fn(chain: list[str], tasks: Mapping[str, Task]):
    """One callable running the whole chain; the only graph-visible value
    is the tail's output, so interior values stay on the executor heap."""
    head = tasks[chain[0]]

    def fused(*args: Any, **kwargs: Any) -> Any:
        value = head.fn(*args, **kwargs)
        prev = chain[0]
        for key in chain[1:]:
            t = tasks[key]
            a = [value if isinstance(x, TaskRef) and x.key == prev else x
                 for x in t.args]
            kw = {k: value if isinstance(v, TaskRef) and v.key == prev else v
                  for k, v in t.kwargs.items()}
            value = t.fn(*a, **kw)
            prev = key
        return value

    fused.__name__ = f"fused[{chain[0]}..{chain[-1]}]"
    return fused


def fuse_linear_chains(
    dag: DAG, max_len: int = 64
) -> tuple[list[Task], dict[str, tuple[str, ...]]]:
    """Rewrite: collapse each chain into one task keyed by its tail.

    The fused task inherits the head's args (its in-edges) and the tail's
    key (its out-edges), so the surrounding graph is untouched and root
    keys survive verbatim.
    """
    chains = find_chains(dag, max_len)
    drop: set[str] = set()
    replace: dict[str, Task] = {}
    provenance: dict[str, tuple[str, ...]] = {}
    for chain in chains:
        head, tail = chain[0], chain[-1]
        drop.update(chain[:-1])
        replace[tail] = Task(
            key=tail,
            fn=_make_fused_fn(chain, dag.tasks),
            args=dag.tasks[head].args,
            kwargs=dag.tasks[head].kwargs,
        )
        provenance[tail] = tuple(chain)
    out = [
        replace.get(k, t) for k, t in dag.tasks.items() if k not in drop
    ]
    return out, provenance


# ---------------------------------------------------------------------------
# Pass 2: task clustering (annotation only)
# ---------------------------------------------------------------------------


def compute_clusters(dag: DAG) -> tuple[dict[str, str], frozenset[str]]:
    """Cluster id per node + the set of delayed fan-in nodes.

    A node joins its parent's cluster along edges an executor walks
    without a new invocation: the trivial fan-out / become edge (it is
    the parent's first child) or — for fan-in nodes — the primary
    (first-listed) in-edge, matching the executor that continues through
    the counter. Every other node heads a fresh cluster.
    """
    clusters: dict[str, str] = {}
    delayed: set[str] = set()
    for k in dag.topological_order():
        deps = dag.deps[k]
        if not deps:
            clusters[k] = k
        elif len(deps) == 1:
            parent = deps[0]
            is_become = dag.children[parent] and dag.children[parent][0] == k
            clusters[k] = clusters[parent] if is_become else k
        else:
            clusters[k] = clusters[deps[0]]
            delayed.add(k)  # shares a cluster with its primary parent
    return clusters, frozenset(delayed)


# ---------------------------------------------------------------------------
# Pass 3: fan-out coalescing (annotation only)
# ---------------------------------------------------------------------------


def coalesce_leaves(dag: DAG, batch: int) -> tuple[tuple[str, ...], ...]:
    """Group sibling leaves with an identical child signature into batches
    of at most ``batch`` keys; singleton batches for everything else."""
    groups: dict[tuple[str, ...], list[str]] = {}
    for leaf in dag.leaves:
        groups.setdefault(tuple(sorted(dag.children[leaf])), []).append(leaf)
    batches: list[tuple[str, ...]] = []
    step = max(1, batch)
    for siblings in groups.values():
        for i in range(0, len(siblings), step):
            batches.append(tuple(siblings[i:i + step]))
    return tuple(batches)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------


def compile_dag(dag: DAG, config: OptimizeConfig | None = None) -> CompiledDAG:
    """Run the enabled passes and return the annotated, rewritten DAG."""
    cfg = config or ALL_PASSES
    stats: list[PassStats] = []
    tasks: Iterable[Task] = dag.tasks.values()
    fused: dict[str, tuple[str, ...]] = {}
    working = dag

    if cfg.fuse_chains:
        before = len(working)
        task_list, fused = fuse_linear_chains(working, cfg.max_fusion_len)
        if fused:
            working = DAG(task_list)
            tasks = working.tasks.values()
        # else: no fusible chains — skip rebuilding (and re-validating)
        # the whole graph; host-side schedule generation is a measured
        # hot path on wide fusion-free DAGs like tree reductions.
        stats.append(PassStats(
            name="fuse_chains", before_tasks=before, after_tasks=len(working),
            detail=f"{len(fused)} chains fused",
        ))

    clusters: dict[str, str] = {}
    delayed: frozenset[str] = frozenset()
    if cfg.cluster_tasks:
        clusters, delayed = compute_clusters(working)
        stats.append(PassStats(
            name="cluster_tasks", before_tasks=len(working),
            after_tasks=len(working),
            detail=(f"{len(set(clusters.values()))} clusters, "
                    f"{len(delayed)} delayed fan-ins"),
        ))

    batches: tuple[tuple[str, ...], ...] | None = None
    if cfg.coalesce_fanouts:
        batches = coalesce_leaves(working, cfg.coalesce_batch)
        stats.append(PassStats(
            name="coalesce_fanouts", before_tasks=len(working.leaves),
            after_tasks=len(batches),
            detail=f"{len(working.leaves)} leaves -> "
                   f"{len(batches)} invocations",
        ))

    compiled = CompiledDAG(
        tasks=tasks,
        clusters=clusters,
        delayed_fanins=delayed,
        leaf_batches=batches,
        fused=fused,
        pass_stats=stats,
        coalesce_batch=cfg.coalesce_batch if cfg.coalesce_fanouts else 0,
    )
    # Pre-flight: every annotation the passes produced must be
    # consistent with the rewritten graph (ConsistencyError here means a
    # compiler-pass bug, caught before any executor is invoked).
    check_compiled(compiled)
    return compiled


def ensure_compiled(dag: DAG, config: OptimizeConfig | None) -> DAG:
    """Engine entry point: compile unless disabled or already compiled."""
    if isinstance(dag, CompiledDAG):
        return dag
    if config is None:
        return dag
    return compile_dag(dag, config)
