"""Multi-tenant job orchestrator: N concurrent DAG jobs, ONE platform.

The paper (and PRs 1-4) run one job at a time: every ``compute()`` call
builds a private KV store, a private clock, and a private platform, so
the warm-container pool and the account concurrency cap never experience
cross-job contention — yet the serverless premise ("pay per use on a
shared auto-scaling provider") only pays off in exactly that regime, and
the ROADMAP north star (serve heavy traffic from many users) is this
axis. ServerMix's tradeoff analysis and Triggerflow's multi-workflow
orchestration both study it; this module makes it runnable here:

- ``Substrate``        — ONE VirtualClock, ONE ShardedKVStore, and (in
                         shared mode) ONE stateful FaaS platform for all
                         jobs. Each job sees the store through a per-job
                         ``KVNamespace`` so names never collide while
                         shards/lanes/clock genuinely contend.
- one platform *function per tenant* — warm containers pool per
  function (tenants share the account concurrency cap and the billing
  account, never each other's containers), each with its own memory
  size (billing rate AND compute speed).
- ``generate_workload`` — seeded Poisson arrivals with a heavy-tailed
                          size mix over the paper's four applications,
                          deterministic under the virtual clock.
- ``JobOrchestrator``  — admits jobs against ``max_concurrent_jobs``
                         with per-tenant fair admission (least-loaded
                         tenant first), runs each admitted job as a
                         clock actor via the engine's injected-substrate
                         path, and reduces everything into an
                         ``OrchestratorReport`` (p50/p95/p99 job
                         latency, per-tenant billed USD, warm-share,
                         peak concurrency).

``isolate_platform=True`` is the control arm: same workload, same
admission, but every job gets a fresh platform — no cross-job warm
reuse, no shared cap. The fig15 benchmark compares the two.

Everything runs on the shared clock's primitives, so a full sweep is
bit-identical across runs (the fig15 smoke gate asserts this down to
per-tenant billed USD).

Durability (the durable control plane): the dispatcher journals every
job lifecycle transition through a :class:`JobStateMachine` persisted
in the shared store (``repro.core.statemachine``), so orchestration
state is external to the process. ``FaultConfig.orchestrator_crash_*``
kills the dispatcher at seeded points; a fresh orchestrator instance
``recover()``s by replaying the journal — journaled-complete jobs are
returned from their journal payloads (never re-executed, never
re-billed), in-flight jobs are re-admitted with ``resume=True`` (their
executors skip durably-completed tasks), and orphaned namespaces are
purged. ``run_with_recovery`` drives the crash→recover loop end to end.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
from collections import deque
from typing import TYPE_CHECKING, Any

from repro.core.engine import EngineConfig, JobSubstrate, WukongEngine
from repro.core.faults import FaultConfig, FaultInjector
from repro.core.kvstore import ShardedKVStore
from repro.core.statemachine import (
    ADMITTED,
    COMPLETED,
    CONTROL_NS,
    FAILED,
    PENDING,
    RUNNING,
    TERMINAL_STATES,
    JobStateMachine,
)
from repro.core.triggers import StreamConfig, TriggerBus, TriggerRule, \
    stream_source

if TYPE_CHECKING:  # import cycle: repro.platform imports repro.core
    from repro.platform import FaaSPlatform, PlatformConfig


# ---------------------------------------------------------------------------
# Workload model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant = one deployed platform function.

    ``memory_mb`` is the tenant's function size: its billing rate (GB-s)
    and its compute speed (CPU share proportional to memory), so tenants
    on one account genuinely differ in cost/latency profile.

    Tiering (admission + SLO accounting):

    ``tier``                — label grouped over in the report's
                              ``per_tier`` block (p50/p95/p99, SLO
                              violations, billed USD per tier).
    ``priority``            — admission priority; higher is admitted
                              first. Equal priorities fall back to the
                              PR 5 policy (fair least-loaded-tenant or
                              plain FIFO), so single-priority workloads
                              behave exactly as before.
    ``max_concurrent_jobs`` — per-tenant quota: at most this many of
                              the tenant's jobs run at once (None =
                              bounded only by the global admission cap).
    ``slo_s``               — job-latency objective (arrival →
                              completion, simulated seconds); completed
                              jobs over it count as SLO violations in
                              ``per_tier``. None = no objective (batch).
    """

    name: str
    memory_mb: int = 1792
    tier: str = "standard"
    priority: int = 1
    max_concurrent_jobs: "int | None" = None
    slo_s: "float | None" = None

    def __post_init__(self) -> None:
        if self.memory_mb <= 0:
            raise ValueError("memory_mb must be positive")
        if (self.max_concurrent_jobs is not None
                and self.max_concurrent_jobs < 1):
            raise ValueError("max_concurrent_jobs must be >= 1 or None")
        if self.slo_s is not None and self.slo_s <= 0:
            raise ValueError("slo_s must be positive or None")


DEFAULT_TENANTS: "tuple[TenantSpec, ...]" = (
    TenantSpec("tenant-a", 1792, tier="standard", priority=1, slo_s=120.0),
    TenantSpec("tenant-b", 1792, tier="standard", priority=1, slo_s=120.0),
    TenantSpec("tenant-c", 896, tier="batch", priority=0),
    TenantSpec("tenant-d", 3584, tier="premium", priority=2, slo_s=30.0),
)

# app name -> ladder of job sizes, small to large. The ladder index is
# drawn heavy-tailed (geometric), the paper's "many small jobs, few
# huge ones" traffic shape.
_SIZE_LADDERS: "dict[str, tuple[Any, ...]]" = {
    # tree_reduction: array length n (n/2 leaf tasks)
    "tree_reduction": (8, 16, 32, 64, 128),
    # gemm: (n, block_size)
    "gemm": ((64, 32), (128, 32), (128, 64)),
    # svd (TSQR): (rows, cols, n_blocks)
    "svd": ((256, 32, 4), (512, 32, 8), (1024, 32, 8)),
    # svc: (n_samples, n_blocks, n_iters)
    "svc": ((512, 4, 2), (1024, 4, 2), (2048, 8, 2)),
}


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Seeded multi-tenant traffic: Poisson arrivals, heavy-tailed mix."""

    n_jobs: int = 32
    arrival_rate_per_s: float = 4.0   # Poisson arrival intensity
    seed: int = 0
    tenants: "tuple[TenantSpec, ...]" = DEFAULT_TENANTS
    # (app, weight) — drawn per job. Defaults lean on tree reduction
    # (numpy payloads) with a minority of the linear-algebra apps.
    app_mix: "tuple[tuple[str, float], ...]" = (
        ("tree_reduction", 0.55),
        ("gemm", 0.20),
        ("svd", 0.15),
        ("svc", 0.10),
    )
    # P(size rank r) proportional to size_tail**r: ~55% smallest size,
    # a long tail of big jobs at the default 0.45.
    size_tail: float = 0.45
    # Per-task simulated compute at the baseline memory size; the
    # linear-algebra apps convert it to ms-per-flop at their smallest
    # task size so every app's tasks land in the same duration regime.
    compute_ms: float = 20.0
    payload_bytes: int = 0            # edge ballast (tree reduction only)


@dataclasses.dataclass(frozen=True)
class JobRequest:
    """One job of the workload: which tenant submits which DAG when."""

    job_id: int
    tenant: str
    app: str
    size: Any                  # entry of the app's size ladder
    arrival_ms: float          # simulated submit time
    compute_ms: float = 20.0
    payload_bytes: int = 0

    @property
    def name(self) -> str:
        return f"job{self.job_id}"

    def build_dag(self):
        """Materialize the job's DAG (lazy app import: repro.apps sits
        above repro.core in the layering)."""
        if self.app == "tree_reduction":
            from repro.apps import tree_reduction_dag

            return tree_reduction_dag(self.size,
                                      compute_ms=self.compute_ms,
                                      payload_bytes=self.payload_bytes)
        if self.app == "gemm":
            from repro.apps import gemm_dag

            n, bs = self.size
            return gemm_dag(n, bs,
                            ms_per_flop=self.compute_ms / (2.0 * bs ** 3))
        if self.app == "svd":
            from repro.apps import tsqr_svd_dag

            rows, cols, n_blocks = self.size
            block_flops = 2.0 * (rows / n_blocks) * cols * cols
            return tsqr_svd_dag(rows, cols=cols, n_blocks=n_blocks,
                                ms_per_flop=self.compute_ms / block_flops)
        if self.app == "dynamic_tree":
            from repro.apps import dynamic_tree_reduction_dag

            return dynamic_tree_reduction_dag(
                self.size, compute_ms=self.compute_ms,
                payload_bytes=self.payload_bytes)
        if self.app == "svc":
            from repro.apps import svc_dag

            n_samples, n_blocks, n_iters = self.size
            from repro.apps.svc import DIM

            block_flops = 2.0 * (n_samples / n_blocks) * DIM
            return svc_dag(n_samples, n_blocks=n_blocks, n_iters=n_iters,
                           ms_per_flop=self.compute_ms / block_flops)
        raise ValueError(f"unknown app {self.app!r}")


def generate_workload(cfg: WorkloadConfig) -> "list[JobRequest]":
    """Seeded job stream: exponential inter-arrival times (Poisson
    process), tenants drawn uniformly, apps by ``app_mix`` weight, sizes
    heavy-tailed down each app's ladder. Pure function of ``cfg`` — the
    determinism gate reruns it and expects the identical stream."""
    import random

    rng = random.Random(cfg.seed)
    apps = [a for a, _ in cfg.app_mix]
    weights = [w for _, w in cfg.app_mix]
    total_w = sum(weights)
    jobs: list[JobRequest] = []
    t_ms = 0.0
    for job_id in range(cfg.n_jobs):
        t_ms += rng.expovariate(cfg.arrival_rate_per_s) * 1e3
        tenant = cfg.tenants[rng.randrange(len(cfg.tenants))]
        # weighted app draw
        x = rng.random() * total_w
        app = apps[-1]
        for a, w in cfg.app_mix:
            if x < w:
                app = a
                break
            x -= w
        ladder = _SIZE_LADDERS[app]
        # geometric (heavy-tailed) rank, clamped to the ladder
        rank = 0
        while rank < len(ladder) - 1 and rng.random() < cfg.size_tail:
            rank += 1
        jobs.append(JobRequest(
            job_id=job_id,
            tenant=tenant.name,
            app=app,
            size=ladder[rank],
            arrival_ms=t_ms,
            compute_ms=cfg.compute_ms,
            payload_bytes=cfg.payload_bytes,
        ))
    return jobs


def _job_spec(job: JobRequest) -> "dict[str, Any]":
    """The reconstructible job spec journaled with the PENDING
    transition — everything a recovering orchestrator needs to rebuild
    the ``JobRequest`` without the dead process's memory."""
    return {
        "job_id": job.job_id,
        "tenant": job.tenant,
        "app": job.app,
        "size": job.size,
        "arrival_ms": job.arrival_ms,
        "compute_ms": job.compute_ms,
        "payload_bytes": job.payload_bytes,
    }


def _job_from_spec(spec: "dict[str, Any]") -> JobRequest:
    return JobRequest(**spec)


# ---------------------------------------------------------------------------
# The shared substrate
# ---------------------------------------------------------------------------


class Substrate:
    """One clock + one store (+ optionally one platform) shared by every
    job the orchestrator runs. ``job_substrate`` hands out the per-job
    ``JobSubstrate`` views the refactored engines accept."""

    def __init__(self, engine: EngineConfig,
                 platform: "PlatformConfig | None",
                 tenants: "tuple[TenantSpec, ...]" = (),
                 isolate_platform: bool = False):
        self.engine = engine
        self.platform_config = platform
        self.tenants = tuple(tenants)
        self.isolate_platform = isolate_platform
        self.kv = ShardedKVStore(
            n_shards=engine.n_kv_shards,
            cost=engine.cost,
            colocate_shards=engine.colocate_kv_shards,
            counter_mode=engine.counter_mode,
        )
        self.clock = self.kv.clock
        self._control = None
        # The live trigger bus generation on this substrate (recovery
        # detaches the dead one's write listener before attaching its
        # own — orphan source actors must not double-feed the new bus).
        self.trigger_bus: "TriggerBus | None" = None
        self.platform: "FaaSPlatform | None" = None
        if platform is not None and not isolate_platform:
            self.platform = self._new_platform()
            if self.platform.caches is not None:
                # Cache coherence on the shared account: purging a
                # finished job's namespace must also reclaim its objects
                # from every container-resident cache, or a recycled
                # warm container could serve a later job's colliding key
                # from a dead job's bytes. Isolated per-job platforms
                # skip this — their caches die with the job.
                self.kv.add_purge_listener(
                    self.platform.caches.invalidate_prefix)

    def _new_platform(self) -> "FaaSPlatform":
        from repro.platform import FaaSPlatform

        p = FaaSPlatform(self.platform_config, self.engine.cost, self.clock)
        for t in self.tenants:
            p.configure_function(t.name, t.memory_mb)
        return p

    def control(self):
        """The control plane's namespaced view of the shared store (the
        job state machine's journal lives here). One cached view: the
        journal must be the same object across dispatcher generations on
        this substrate — that is the durability being modeled."""
        if self._control is None:
            self._control = self.kv.namespace(CONTROL_NS)
        return self._control

    def job_substrate(self, job_name: str, tenant: str,
                      resume: bool = False) -> JobSubstrate:
        """The per-job view: namespaced KV, the shared platform (or a
        fresh one per job in the isolated control arm), the tenant's
        function identity, the job's billing label — and ``resume=True``
        when a recovering orchestrator re-admits the job (executors then
        reuse durable task outputs instead of re-executing)."""
        if self.platform is not None:
            platform = self.platform
        elif self.platform_config is not None:
            platform = self._new_platform()  # isolated: private per job
        else:
            platform = None
        return JobSubstrate(kv=self.kv.namespace(job_name),
                            platform=platform, function=tenant,
                            job=job_name, resume=resume)


# ---------------------------------------------------------------------------
# Orchestrator
# ---------------------------------------------------------------------------


def _default_engine_config() -> EngineConfig:
    # Smaller per-job invoker pools and runtime cap than the single-job
    # benchmarks: N of these run concurrently on one machine's threads.
    return EngineConfig(num_initial_invokers=4, num_proxy_invokers=4,
                        max_concurrency=512)


def _default_platform_config() -> "PlatformConfig":
    from repro.platform import PlatformConfig

    return PlatformConfig()


@dataclasses.dataclass(frozen=True)
class OrchestratorConfig:
    # Per-job engine knobs. ``engine.platform`` is ignored — the
    # orchestrator owns platform construction (shared or per-job).
    engine: EngineConfig = dataclasses.field(
        default_factory=_default_engine_config)
    # The account model. None = legacy stochastic draws (no pool, no
    # billing) — still a valid multi-tenant data-plane study.
    platform: "PlatformConfig | None" = dataclasses.field(
        default_factory=_default_platform_config)
    workload: WorkloadConfig = dataclasses.field(
        default_factory=WorkloadConfig)
    # Admission gate: how many jobs may run at once. The orchestrator's
    # defense of the shared account cap — admitted jobs' fan-outs hit
    # the throttle directly.
    max_concurrent_jobs: int = 8
    # Fair admission: pick the next job from the tenant with the fewest
    # running jobs (FIFO within a tenant; FIFO across everything when
    # off) so one flooding tenant cannot starve the others.
    fair_admission: bool = True
    # Control arm: per-job private platforms (no cross-job warm sharing,
    # no shared cap) — the isolated-per-job baseline of fig15.
    isolate_platform: bool = False
    # Orchestrator-level fault injection (``orchestrator_crash_point`` /
    # ``orchestrator_crash_at``): kills the dispatcher at a seeded point
    # so crash→replay recovery can be exercised. Task-level faults stay
    # on ``engine.faults``; this config governs the control plane.
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    # Trigger-driven admission: persistent event->job rules (journaled
    # in the ``__triggers__`` namespace, crash-recoverable) and an
    # optional Poisson event stream feeding them. Rule actions must
    # name a tenant from ``workload.tenants``. Empty = the PR 5
    # behavior, bit for bit.
    triggers: "tuple[TriggerRule, ...]" = ()
    stream: "StreamConfig | None" = None
    # First job_id the bus assigns to fired jobs (static workload ids
    # must stay below it).
    trigger_id_base: int = 1_000_000


class OrchestratorCrashed(RuntimeError):
    """The dispatcher died at an injected crash point. Carries what a
    supervisor needs to restart: the still-live shared substrate (the
    durable store survives the process) and the fault injector (its
    occurrence counters carry across generations so the same crash does
    not re-fire during recovery)."""

    def __init__(self, point: str, substrate: "Substrate",
                 injector: FaultInjector):
        super().__init__(f"orchestrator crashed at point {point!r}")
        self.point = point
        self.substrate = substrate
        self.injector = injector


@dataclasses.dataclass
class OrchestratorReport:
    mode: str                     # "shared" | "isolated"
    jobs: int
    completed: int
    failed: int
    makespan_s: float             # first arrival -> last completion
    p50_s: float                  # job latency percentiles
    p95_s: float                  # (arrival -> completion, completed jobs)
    p99_s: float
    mean_latency_s: float
    mean_queue_wait_s: float      # arrival -> admission
    warm_share: float             # warm_reuses / invocations with a pool
    cold_starts: int
    warm_reuses: int
    throttle_events: int
    peak_concurrency: int
    billed_usd_total: float
    per_tenant: "dict[str, dict[str, Any]]"
    job_records: "list[dict[str, Any]]"
    # Tier SLO accounting: tier -> {jobs, failed, p50/p95/p99, SLO
    # violations, billed USD} (empty when no tenant declares a tier).
    per_tier: "dict[str, dict[str, Any]]" = dataclasses.field(
        default_factory=dict)
    # Durable-control-plane counters: injected dispatcher crashes
    # survived, in-flight jobs re-admitted by replay, and tasks whose
    # durable outputs were reused instead of re-executed.
    crashes: int = 0
    recovered_jobs: int = 0
    tasks_resumed: int = 0
    # Account-wide locality counters (per-tier cache hits/misses/
    # evictions + residency) when the platform runs with container
    # caches; empty otherwise.
    cache: "dict[str, Any]" = dataclasses.field(default_factory=dict)


def _percentile(sorted_vals: "list[float]", q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not sorted_vals:
        return 0.0
    rank = max(1, -(-len(sorted_vals) * q // 100))  # ceil(n*q/100)
    return sorted_vals[int(rank) - 1]


class JobOrchestrator:
    """Runs a workload of DAG jobs on one shared substrate.

    The orchestrator thread is the dispatcher actor: it feeds arrivals
    from the (pre-sorted, seeded) workload, admits up to
    ``max_concurrent_jobs`` with per-tenant fairness, and spawns each
    admitted job as its own clock actor running
    ``WukongEngine.compute(dag, substrate=...)``. Completions come back
    on a clock queue. Under the virtual clock the whole traffic trace —
    arrivals, queueing, contention, billing — is bit-identical across
    runs."""

    def __init__(self, config: OrchestratorConfig | None = None):
        self.config = config or OrchestratorConfig()
        self.last_substrate: Substrate | None = None
        # Orchestrator-level fault injector. ``run_with_recovery`` hands
        # the SAME instance to each recovering generation, so a crash
        # configured to fire once fires once across the whole lifetime.
        self.injector = FaultInjector(self.config.faults)
        if self.config.engine.platform is not None:
            raise ValueError(
                "set OrchestratorConfig.platform, not engine.platform: "
                "the orchestrator owns platform construction")

    # -- admission policy ---------------------------------------------------
    def _tenant(self, name: str) -> "TenantSpec | None":
        for t in self.config.workload.tenants:
            if t.name == name:
                return t
        return None

    def _pick_next(self, ready: "list[JobRequest]",
                   tenant_running: "dict[str, int]",
                   ) -> "JobRequest | None":
        """The next job to admit, or None when every ready job is
        blocked by its tenant's quota. Order: priority tier first
        (higher ``TenantSpec.priority`` wins), then the PR 5 policy
        within a tier — least-loaded tenant (fair) or plain FIFO — so
        single-priority workloads behave exactly as before."""
        quota_ok = []
        for j in ready:
            spec = self._tenant(j.tenant)
            quota = spec.max_concurrent_jobs if spec is not None else None
            if quota is not None and tenant_running.get(j.tenant, 0) >= quota:
                continue
            quota_ok.append(j)
        if not quota_ok:
            return None

        def prio(j: JobRequest) -> int:
            spec = self._tenant(j.tenant)
            return spec.priority if spec is not None else 1

        if not self.config.fair_admission:
            # FIFO within a priority tier — deterministic under ties.
            return min(quota_ok,
                       key=lambda j: (-prio(j), j.arrival_ms, j.job_id))
        # Least-loaded tenant first within the tier; FIFO (arrival, id)
        # within a load level.
        return min(quota_ok, key=lambda j: (
            -prio(j), tenant_running.get(j.tenant, 0),
            j.arrival_ms, j.job_id))

    # -- the run loop -------------------------------------------------------
    def run(self, jobs: "list[JobRequest] | None" = None) -> OrchestratorReport:
        """Run the workload from scratch. Raises
        :class:`OrchestratorCrashed` when a configured crash point
        fires — use :meth:`run_with_recovery` (or catch and call
        :meth:`recover` on a fresh instance) to survive it."""
        cfg = self.config
        if jobs is None:
            jobs = generate_workload(cfg.workload)
        substrate = Substrate(cfg.engine, cfg.platform,
                              tenants=cfg.workload.tenants,
                              isolate_platform=cfg.isolate_platform)
        # Kept for introspection (tests, notebooks): the substrate the
        # most recent run() executed on.
        self.last_substrate = substrate
        return substrate.clock.run(self._run_g(jobs, substrate))

    def recover(self, substrate: Substrate,
                injector: "FaultInjector | None" = None,
                ) -> OrchestratorReport:
        """Recover a crashed orchestrator's workload on ITS substrate by
        replaying the control-plane journal. Call on a FRESH instance —
        recovery must need nothing from the dead process's memory; the
        journal is the only input. ``injector`` carries the crashed
        generation's occurrence counters (pass ``crash.injector``) so an
        already-fired crash does not re-fire; omit it to recover with
        this instance's own injector."""
        if injector is not None:
            self.injector = injector
        self.last_substrate = substrate
        return substrate.clock.run(self._recover_g(substrate))

    def run_with_recovery(self, jobs: "list[JobRequest] | None" = None,
                          max_crashes: int = 8) -> OrchestratorReport:
        """The supervised loop: run, and on every injected dispatcher
        crash start a FRESH orchestrator instance that replays the
        journal and carries on — up to ``max_crashes`` restarts (a
        crash-looping control plane should fail loudly, not spin)."""
        crashes = 0
        try:
            report = self.run(jobs)
        except OrchestratorCrashed as crash:
            crashes += 1
            while True:
                orch = JobOrchestrator(self.config)
                try:
                    report = orch.recover(crash.substrate,
                                          injector=crash.injector)
                    break
                except OrchestratorCrashed as again:
                    crashes += 1
                    if crashes > max_crashes:
                        raise
                    crash = again
            self.last_substrate = crash.substrate
        report.crashes = crashes
        return report

    def _run_g(self, jobs: "list[JobRequest]", substrate: Substrate):
        """The dispatcher as an effect generator: the clock drives it as
        the root continuation (event substrate) or inline on the calling
        actor thread (thread/realtime substrates)."""
        machine = JobStateMachine(substrate.control())
        # Submission: journal PENDING (with the reconstructible job
        # spec) for every job before any is admitted — from here on the
        # workload survives the dispatcher.
        clock = substrate.clock
        for job in sorted(jobs, key=lambda j: j.job_id):
            yield from machine.record_g(job.job_id, PENDING,
                                        at_ms=clock.now_ms(),
                                        payload=_job_spec(job))
        bus = None
        if self.config.triggers:
            bus = self._make_bus(substrate)
            for rule in self.config.triggers:
                yield from bus.add_rule_g(rule)
        return (yield from self._dispatch_g(
            jobs, substrate, machine,
            prior_records=[], resume_ids=frozenset(), recovered_jobs=0,
            bus=bus))

    def _make_bus(self, substrate: Substrate) -> TriggerBus:
        bus = TriggerBus(substrate.kv, substrate.clock,
                         id_base=self.config.trigger_id_base)
        substrate.trigger_bus = bus
        return bus

    def _recover_g(self, substrate: Substrate):
        """Replay-recovery as an effect generator: rebuild the state
        machine from the journal (charged scan), split jobs into
        journaled-terminal (returned from their journal payloads, their
        possibly-orphaned namespaces purged) and non-terminal (re-run;
        previously in-flight ones resume against their retained
        namespaces), then dispatch the remainder."""
        machine = JobStateMachine(substrate.control())
        yield from machine.replay_g()
        bus = None
        if self.config.triggers:
            # The dead generation's bus still observes writes (and the
            # orphan sources it spawned still produce them): detach it
            # before this generation's bus attaches, or every stream
            # event would be double-delivered.
            if substrate.trigger_bus is not None:
                substrate.trigger_bus.detach()
            bus = self._make_bus(substrate)
            yield from bus.replay_g()

        to_run: "list[JobRequest]" = []
        all_jobs: "list[JobRequest]" = []
        prior_records: "list[dict[str, Any]]" = []
        resume_ids: "set[int]" = set()
        recovered = 0
        for job_id, state in sorted(machine.jobs().items()):
            spec = machine.payload(job_id, PENDING)
            if spec is None:
                raise RuntimeError(
                    f"journal names job {job_id} without a PENDING spec")
            job = _job_from_spec(spec)
            all_jobs.append(job)
            if state in TERMINAL_STATES:
                rec = machine.payload(job_id, state)
                if rec is not None:
                    rec = dict(rec)
                    rec["from_journal"] = True
                    prior_records.append(rec)
                # The crash may have hit between journaling the terminal
                # state and purging the job's namespace: purge now.
                # Idempotent — dropping an already-purged namespace is a
                # no-op.
                substrate.kv.drop_namespace(job.name)
            else:
                to_run.append(job)
                if state in (ADMITTED, RUNNING):
                    # In flight when the dispatcher died: re-admit with
                    # resume semantics (namespace retained — durable
                    # task outputs are reused, not re-executed).
                    resume_ids.add(job_id)
                    recovered += 1
        if bus is not None:
            # A crash between journaling a fire and journaling its
            # job's PENDING record leaves a fired-but-unsubmitted job:
            # the fire's journal payload carries the full spec, so
            # re-journal and run it here — no fire is ever lost.
            for frec in bus.fired_records():
                if machine.state(frec["job_id"]) is None:
                    job = _job_from_spec(frec["spec"])
                    yield from machine.record_g(
                        job.job_id, PENDING, at_ms=substrate.clock.now_ms(),
                        payload=frec["spec"])
                    all_jobs.append(job)
                    to_run.append(job)
        return (yield from self._dispatch_g(
            all_jobs, substrate, machine,
            prior_records=prior_records, resume_ids=frozenset(resume_ids),
            recovered_jobs=recovered, to_run=to_run, bus=bus))

    def _dispatch_g(self, all_jobs: "list[JobRequest]",
                    substrate: Substrate, machine: JobStateMachine,
                    prior_records: "list[dict[str, Any]]",
                    resume_ids: "frozenset[int]", recovered_jobs: int,
                    to_run: "list[JobRequest] | None" = None,
                    bus: "TriggerBus | None" = None):
        """The admission/dispatch/completion loop shared by fresh runs
        and recovery. ``all_jobs`` is the full workload (reporting);
        ``to_run`` the subset still needing execution (defaults to all).
        Every lifecycle transition is journaled through ``machine``
        BEFORE the action it records is performed, and the injector may
        kill the dispatcher at the seeded crash points in between.

        With a trigger ``bus``, the dispatcher is also the bus's single
        event consumer: source actors (timers, the stream writer, the
        external-event relay) and the KV write listener all enqueue
        tagged events onto the SAME completion queue, and every fire is
        journaled, journaled PENDING, and admitted through the normal
        ``launch_g`` path — trigger-fired jobs are first-class jobs."""
        cfg = self.config
        clock = substrate.clock
        injector = self.injector
        tenant_memory = {t.name: t.memory_mb for t in cfg.workload.tenants}
        if to_run is None:
            to_run = list(all_jobs)

        # Dispatch epoch: submissions were journaled (a charged control-
        # plane write) before this loop, so the clock is already past the
        # earliest arrivals. Queue wait is measured from when a job became
        # ELIGIBLE for admission — max(arrival, dispatch start) — so the
        # journaling overhead is not misattributed to gate queueing.
        t0_ms = clock.now_ms()
        pending = deque(sorted(to_run, key=lambda j: (j.arrival_ms, j.job_id)))
        ready: "list[JobRequest]" = []
        tenant_running: "dict[str, int]" = {}
        records: "list[dict[str, Any]]" = []
        # isolated control arm: (tenant, private-platform snapshot) pairs
        isolated_stats: "list[tuple[str, dict[str, Any]]]" = []
        n_running = 0

        done_q = clock.queue()

        def launch_g(job: JobRequest):
            admit_ms = clock.now_ms()
            yield from machine.record_g(job.job_id, ADMITTED,
                                        at_ms=admit_ms)
            if injector.orchestrator_crash("admit"):
                # Mid-admission: ADMITTED is journaled but no runner
                # exists. Recovery re-admits from the journal.
                raise OrchestratorCrashed("admit", substrate, injector)
            sub = substrate.job_substrate(job.name, job.tenant,
                                          resume=job.job_id in resume_ids)

            def runner():
                start_ms = clock.now_ms()
                rep, error = None, None
                try:
                    engine = WukongEngine(cfg.engine)
                    rep = yield from engine.compute_g(job.build_dag(), sub)
                except Exception as exc:  # JobError, task bugs: record
                    error = repr(exc)
                done_q.put(("done", (job, admit_ms, start_ms,
                                     clock.now_ms(), rep, error, sub)))

            yield from machine.record_g(job.job_id, RUNNING,
                                        at_ms=clock.now_ms())
            clock.spawn(runner, name=job.name)
            if injector.orchestrator_crash("dispatch"):
                # Mid-dispatch: the runner actor is live on the
                # substrate but the dispatcher dies. The orphan keeps
                # running (its writes are idempotent); recovery
                # re-admits the job and resumes over its outputs.
                raise OrchestratorCrashed("dispatch", substrate, injector)

        def job_billed_usd(sub: JobSubstrate, job: JobRequest) -> float:
            if cfg.isolate_platform and sub.platform is not None:
                return sub.platform.snapshot()["billed_usd"]
            if substrate.platform is not None:
                return substrate.platform.meter.job_snapshot(
                    job.name)["billed_usd"]
            return 0.0

        # -- trigger plumbing ------------------------------------------
        n_expected = len(to_run)
        n_sources = 0
        sources_done = 0
        close_sent = bus is None

        def fires_g(ev: "dict[str, Any]"):
            """Offer one event to the bus; journal each fire, journal
            its job PENDING, and hand it to the normal admission path."""
            nonlocal n_expected
            for due in bus.match(ev):
                spec = yield from bus.fire_g(due, clock.now_ms())
                if spec is None:
                    continue  # fire journaled by a dead generation
                job = _job_from_spec(spec)
                yield from machine.record_g(job.job_id, PENDING,
                                            at_ms=clock.now_ms(),
                                            payload=dict(spec))
                all_jobs.append(job)
                n_expected += 1
                ready.append(job)

        if bus is not None:
            bus.attach(done_q)
            for rule in bus.rules.values():
                if rule.source == "timer":
                    clock.spawn(bus.timer_actor(rule, done_q),
                                name=f"timer-{rule.rule_id}")
                    n_sources += 1
            if cfg.stream is not None:
                clock.spawn(
                    stream_source(cfg.stream, substrate.kv, clock, bus,
                                  done_q),
                    name="stream-source")
                n_sources += 1
            clock.spawn(bus.relay_actor(done_q), name="trigger-relay")
            n_sources += 1
            # Re-offer completions journaled by dead generations: a
            # ``job_completed`` fire journaled before the crash is
            # deduped here; one the crash cut off between the terminal
            # journal and the fire journal fires now. Nothing is lost
            # or doubled either way.
            for rec in prior_records:
                bus.job_finished(rec, rec.get("end_ms", clock.now_ms()))
                yield from fires_g({"source": "job_completed",
                                    "record": rec,
                                    "at_ms": clock.now_ms()})

        while len(records) < n_expected or sources_done < n_sources:
            now = clock.now_ms()
            while pending and pending[0].arrival_ms <= now:
                ready.append(pending.popleft())
            while ready and n_running < cfg.max_concurrent_jobs:
                job = self._pick_next(ready, tenant_running)
                if job is None:
                    break  # all ready jobs quota-blocked
                ready.remove(job)
                tenant_running[job.tenant] = (
                    tenant_running.get(job.tenant, 0) + 1)
                n_running += 1
                yield from launch_g(job)
            if (bus is not None and not close_sent
                    and sources_done >= n_sources - 1
                    and len(records) >= n_expected
                    and not pending and not ready):
                # Every bounded source is finished and every job is
                # accounted for: stop the relay (the one open-ended
                # source) so the loop can drain and exit.
                yield from bus.close_g()
                close_sent = True
            try:
                if pending:
                    wait_s = (pending[0].arrival_ms - clock.now_ms()) / 1e3
                    msg = yield ("get", done_q, max(0.0, wait_s))
                else:
                    msg = yield ("get", done_q, None)
            except _queue.Empty:
                continue  # an arrival came due
            tag, body = msg
            if tag == "source_done":
                sources_done += 1
                continue
            if tag == "event":
                yield from fires_g(body)
                continue
            job, admit_ms, start_ms, end_ms, rep, error, sub = body
            tenant_running[job.tenant] -= 1
            n_running -= 1
            rec: "dict[str, Any]" = {
                "job_id": job.job_id,
                "tenant": job.tenant,
                "app": job.app,
                "size": job.size,
                "arrival_ms": job.arrival_ms,
                "admit_ms": admit_ms,
                "end_ms": end_ms,
                "latency_s": (end_ms - job.arrival_ms) / 1e3,
                "queue_wait_s":
                    (admit_ms - max(job.arrival_ms, t0_ms)) / 1e3,
                "error": error,
                "billed_usd": job_billed_usd(sub, job),
            }
            if rep is not None:
                rec["tasks"] = rep.tasks
                rec["executors"] = rep.executors_invoked
                rec["fault_stats"] = dict(rep.fault_stats)
                if rep.cache_stats:
                    rec["cache_stats"] = dict(rep.cache_stats)
            if cfg.isolate_platform and sub.platform is not None:
                # Private platform: its counters ARE this job's.
                isolated_stats.append(
                    (job.tenant, sub.platform.snapshot()))
            # Journal the terminal state WITH the completion record
            # before acting on it: if the dispatcher dies right after,
            # recovery returns this job from the journal — no double
            # execution, no double billing.
            yield from machine.record_g(
                job.job_id, COMPLETED if error is None else FAILED,
                at_ms=end_ms, payload=dict(rec))
            if injector.orchestrator_crash("complete"):
                # Between completion and namespace purge: the journal
                # has the result but the job's namespace is orphaned in
                # the shared store. Recovery purges it.
                raise OrchestratorCrashed("complete", substrate, injector)
            records.append(rec)
            if bus is not None:
                bus.job_finished(rec, end_ms)
                yield from fires_g({"source": "job_completed",
                                    "record": rec,
                                    "at_ms": clock.now_ms()})
            # Reclaim the finished job's namespaced objects/counters
            # from the shared store: memory stays O(concurrent
            # jobs), not O(total traffic). Host-side (no clock
            # charge); any straggler residue is bounded by the
            # job's stop signal.
            sub.kv.purge()

        # All jobs done; counters are stable (the substrate serializes
        # this reduction against any leftover actors).
        if bus is not None:
            bus.detach()
        return self._reduce(all_jobs, prior_records + records, substrate,
                            tenant_memory, isolated_stats,
                            recovered_jobs=recovered_jobs)

    # -- report reduction ---------------------------------------------------
    def _reduce(self, jobs, records, substrate, tenant_memory,
                isolated_stats, recovered_jobs: int = 0,
                ) -> OrchestratorReport:
        cfg = self.config
        records = sorted(records, key=lambda r: r["job_id"])
        ok = [r for r in records if r["error"] is None]
        latencies = sorted(r["latency_s"] for r in ok)
        first_arrival = min((j.arrival_ms for j in jobs), default=0.0)
        last_end = max((r["end_ms"] for r in records), default=0.0)
        tenant_spec = {t.name: t for t in cfg.workload.tenants}

        # -- platform totals + per-tenant billing ---------------------------
        cold = warm = throttled = peak = 0
        billed_total = 0.0
        tenant_billed: "dict[str, float]" = {}
        cache_total: "dict[str, Any]" = {}

        def fold_cache(block: "dict[str, Any] | None") -> None:
            # Sum counters across platforms; peak-style residency fields
            # also sum (concurrent private pools hold bytes at once).
            if not block:
                return
            for k, v in block.items():
                cache_total[k] = cache_total.get(k, 0) + v

        if substrate.platform is not None:          # shared account
            snap = substrate.platform.snapshot()
            cold, warm = snap["cold_starts"], snap["warm_reuses"]
            throttled = snap["throttle_events"]
            peak = snap["peak_concurrency"]
            billed_total = snap["billed_usd"]
            fold_cache(snap.get("cache"))
            for tenant, block in snap.get("billing_by_function",
                                          {}).items():
                tenant_billed[tenant] = block["billed_usd"]
        else:                                        # isolated control arm
            for tenant, snap in isolated_stats:
                cold += snap["cold_starts"]
                warm += snap["warm_reuses"]
                throttled += snap["throttle_events"]
                peak = max(peak, snap["peak_concurrency"])
                billed_total += snap["billed_usd"]
                fold_cache(snap.get("cache"))
                tenant_billed[tenant] = (
                    tenant_billed.get(tenant, 0.0) + snap["billed_usd"])

        per_tenant: "dict[str, dict[str, Any]]" = {}
        for tenant in sorted({j.tenant for j in jobs}):
            t_recs = [r for r in records if r["tenant"] == tenant]
            t_ok = [r for r in t_recs if r["error"] is None]
            lat = sorted(r["latency_s"] for r in t_ok)
            spec = tenant_spec.get(tenant)
            per_tenant[tenant] = {
                "jobs": len(t_recs),
                "failed": len(t_recs) - len(t_ok),
                "memory_mb": tenant_memory.get(tenant),
                "tier": spec.tier if spec is not None else "standard",
                "billed_usd": tenant_billed.get(tenant, 0.0),
                "p50_s": _percentile(lat, 50),
                "p95_s": _percentile(lat, 95),
                "p99_s": _percentile(lat, 99),
                "mean_latency_s": sum(lat) / len(lat) if lat else 0.0,
            }

        # -- per-tier SLO accounting ----------------------------------------
        def tier_of(tenant: str) -> str:
            spec = tenant_spec.get(tenant)
            return spec.tier if spec is not None else "standard"

        per_tier: "dict[str, dict[str, Any]]" = {}
        for tier in sorted({tier_of(j.tenant) for j in jobs}):
            tier_tenants = {j.tenant for j in jobs
                            if tier_of(j.tenant) == tier}
            t_recs = [r for r in records if r["tenant"] in tier_tenants]
            t_ok = [r for r in t_recs if r["error"] is None]
            lat = sorted(r["latency_s"] for r in t_ok)
            # One SLO per tier: the tightest objective any of its
            # tenants declares (None = no objective; nothing violates).
            slos = [tenant_spec[t].slo_s for t in tier_tenants
                    if t in tenant_spec
                    and tenant_spec[t].slo_s is not None]
            slo_s = min(slos) if slos else None
            per_tier[tier] = {
                "jobs": len(t_recs),
                "failed": len(t_recs) - len(t_ok),
                "p50_s": _percentile(lat, 50),
                "p95_s": _percentile(lat, 95),
                "p99_s": _percentile(lat, 99),
                "mean_latency_s": sum(lat) / len(lat) if lat else 0.0,
                "slo_s": slo_s,
                "slo_violations": (
                    sum(1 for v in lat if v > slo_s)
                    if slo_s is not None else 0),
                "billed_usd": sum(
                    tenant_billed.get(t, 0.0) for t in tier_tenants),
            }

        invocations = cold + warm
        return OrchestratorReport(
            mode="isolated" if cfg.isolate_platform else "shared",
            jobs=len(jobs),
            completed=len(ok),
            failed=len(records) - len(ok),
            makespan_s=(last_end - first_arrival) / 1e3,
            p50_s=_percentile(latencies, 50),
            p95_s=_percentile(latencies, 95),
            p99_s=_percentile(latencies, 99),
            mean_latency_s=(sum(latencies) / len(latencies)
                            if latencies else 0.0),
            mean_queue_wait_s=(sum(r["queue_wait_s"] for r in ok) / len(ok)
                               if ok else 0.0),
            warm_share=warm / invocations if invocations else 0.0,
            cold_starts=cold,
            warm_reuses=warm,
            throttle_events=throttled,
            peak_concurrency=peak,
            billed_usd_total=billed_total,
            per_tenant=per_tenant,
            job_records=records,
            per_tier=per_tier,
            recovered_jobs=recovered_jobs,
            tasks_resumed=sum(
                r.get("fault_stats", {}).get("tasks_resumed", 0)
                for r in records),
            cache=cache_total,
        )
