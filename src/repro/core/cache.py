"""Executor-local multi-tier cache (Wukong's locality enhancement).

The paper attributes Wukong's headline speedup on real DAG jobs to
*locality*: executors keep intermediate objects close and schedule their
own children, instead of round-tripping every cross-executor edge
through remote storage. This module models the storage side of that
claim as a three-tier hierarchy, per *container*:

- **tier 0** — in-container memory: a modeled capacity with LRU,
  size-aware eviction. Hits are free on the clock (the object is already
  in the invocation's address space).
- **tier 1** — local scratch disk: evicted tier-0 entries spill here and
  pay a charged write; a tier-1 hit pays a charged read and promotes the
  entry back to memory. Its capacity is modeled too; overflow is
  dropped (next stop: the KV store).
- **tier 2** — the shared :class:`~repro.core.kvstore.ShardedKVStore`.
  This module never talks to it: a probe miss simply means the executor
  falls through to the (already charged) remote ``mget``/``get`` path.

A cache belongs to a *container*, not an invocation: the platform's
warm-container pool hands the same :class:`ExecutorCache` to every
invocation that reuses the container, so warm reuse carries data — a
real reason warm matters beyond skipping the cold start. A cold start
gets a fresh cache; keep-alive expiry drops the container's cache with
the container (``ContainerPool`` notifies the registry).

Every charged operation is an effect-protocol generator (``..._g``), so
costs land on the engine clock identically under the event and thread
substrates — cached runs stay bit-identical across substrates and
repeats, like every other charge in the system.

Keys are *store-qualified* (namespace prefix included): a container is
shared across the jobs of one platform function, so two jobs' bare keys
must never collide in its cache. ``ShardedKVStore.drop_namespace``
notifies registered purge listeners, and the registry drops the dead
job's entries from every container — a recycled warm container can
never serve a stale object to a later job (see tests/test_orchestrator).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Iterable


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Knobs of the executor-local cache hierarchy.

    ``memory_bytes=0`` disables tier 0 (every deposit falls through),
    ``disk_bytes=0`` disables tier 1 (memory evictions are dropped);
    both zero models a cacheless container while keeping the plumbing —
    charges are then bit-identical to ``PlatformConfig.cache=None``.
    """

    memory_bytes: int = 64 << 20       # tier-0 capacity per container
    disk_bytes: int = 512 << 20        # tier-1 spill capacity per container
    disk_base_ms: float = 0.1          # per-op local-disk latency
    disk_read_mbps: float = 200.0      # tier-1 read bandwidth (charged)
    disk_write_mbps: float = 100.0     # tier-1 spill-write bandwidth (charged)

    def __post_init__(self) -> None:
        if self.memory_bytes < 0 or self.disk_bytes < 0:
            raise ValueError("cache capacities must be >= 0")
        if self.disk_base_ms < 0:
            raise ValueError("disk_base_ms must be >= 0")
        if self.disk_read_mbps <= 0 or self.disk_write_mbps <= 0:
            raise ValueError("disk bandwidths must be positive")

    def disk_read_ms(self, nbytes: int) -> float:
        return self.disk_base_ms + nbytes / (self.disk_read_mbps * 1e6) * 1e3

    def disk_write_ms(self, nbytes: int) -> float:
        return self.disk_base_ms + nbytes / (self.disk_write_mbps * 1e6) * 1e3


@dataclasses.dataclass
class CacheStats:
    """Per-tier hit/miss/eviction counters plus bytes served per tier.

    Kept twice: each :class:`ExecutorCache` counts its own traffic
    (surfaced account-wide through the registry / platform snapshot),
    and executors pass a per-job sink so ``JobReport.cache_stats`` never
    includes another tenant's hits on a shared platform.
    """

    mem_hits: int = 0          # tier-0 hits (free on the clock)
    disk_hits: int = 0         # tier-1 hits (charged read + promotion)
    misses: int = 0            # fell through to the shared KV store
    deposits: int = 0          # outputs written into tier 0
    spills: int = 0            # tier-0 entries demoted to disk (charged)
    mem_evictions: int = 0     # entries pushed out of tier 0
    disk_evictions: int = 0    # entries dropped from tier 1
    bytes_local: int = 0       # bytes served from tier 0
    bytes_disk: int = 0        # bytes served from tier 1

    def snapshot(self) -> "dict[str, int]":
        return dataclasses.asdict(self)

    def add(self, other: "CacheStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


class ExecutorCache:
    """One container's memory → disk cache (tiers 0 and 1).

    Host-side mutation is atomic under ``_lock`` and happens *before*
    the charge is yielded, so a concurrent executor (or a retried task)
    always observes a fully inserted/spilled/evicted entry — never a
    half-spilled one. Charges are computed from the mutation and yielded
    once, keeping the op a single effect-protocol step.
    """

    def __init__(self, config: CacheConfig):
        self.config = config
        self._lock = threading.Lock()
        # key -> (value, nbytes); insertion order is LRU order (oldest
        # first) — move_to_end on every touch.
        self._mem: "OrderedDict[str, tuple[Any, int]]" = OrderedDict()
        self._disk: "OrderedDict[str, tuple[Any, int]]" = OrderedDict()
        self._mem_bytes = 0
        self._disk_bytes = 0
        self.stats = CacheStats()

    # -- host-side inspection (uncharged) -----------------------------------
    def contains(self, key: str) -> bool:
        with self._lock:
            return key in self._mem or key in self._disk

    def resident_bytes(self, keys: Iterable[str]) -> int:
        """Total bytes of ``keys`` resident in either tier — the
        locality score used for become-choice and warm-container
        placement (scheduler-side knowledge, so uncharged)."""
        total = 0
        with self._lock:
            for k in keys:
                entry = self._mem.get(k) or self._disk.get(k)
                if entry is not None:
                    total += entry[1]
        return total

    @property
    def mem_bytes(self) -> int:
        return self._mem_bytes

    @property
    def disk_bytes(self) -> int:
        return self._disk_bytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem) + len(self._disk)

    # -- stats (call with _lock held) ---------------------------------------
    def _count(self, sink: "CacheStats | None", **fields: int) -> None:
        for target in (self.stats, sink):
            if target is None:
                continue
            for name, delta in fields.items():
                setattr(target, name, getattr(target, name) + delta)

    # -- charged operations (effect protocol) -------------------------------
    def probe_g(self, key: str, stats: "CacheStats | None" = None):
        """Look ``key`` up through the tiers. Returns ``(hit, value)``.

        Tier-0 hit: free. Tier-1 hit: charged disk read, and the entry is
        promoted back to memory (possibly spilling colder entries, whose
        writes are charged in the same step). Miss: free — the caller
        pays the remote fetch it was about to do anyway.
        """
        charge = 0.0
        with self._lock:
            entry = self._mem.get(key)
            if entry is not None:
                self._mem.move_to_end(key)
                self._count(stats, mem_hits=1, bytes_local=entry[1])
                return True, entry[0]
            entry = self._disk.get(key)
            if entry is not None:
                # Promote to tier 0: pay the disk read; the insert may
                # spill colder entries (charged writes, same step).
                del self._disk[key]
                self._disk_bytes -= entry[1]
                charge = self.config.disk_read_ms(entry[1])
                charge += self._insert_mem(key, entry[0], entry[1], stats)
                self._count(stats, disk_hits=1, bytes_disk=entry[1])
            else:
                self._count(stats, misses=1)
                return False, None
        yield ("charge", charge)
        return True, entry[0]

    def deposit_g(self, key: str, value: Any, nbytes: int,
                  stats: "CacheStats | None" = None):
        """Insert a task output into tier 0, spilling LRU entries to
        disk (charged writes) as the capacity demands. Depositing a key
        already resident refreshes it (LRU touch), charging nothing."""
        charge = 0.0
        with self._lock:
            self._count(stats, deposits=1)
            if key in self._mem:
                self._mem.move_to_end(key)
            else:
                if key in self._disk:
                    # Re-produced after a spill (e.g. a retry recomputed
                    # it): the fresh copy supersedes the spilled one.
                    _, old_n = self._disk.pop(key)
                    self._disk_bytes -= old_n
                charge = self._insert_mem(key, value, nbytes, stats)
        if charge > 0:
            yield ("charge", charge)
        return None

    # -- insertion / eviction internals (call with _lock held) ---------------
    def _insert_mem(self, key: str, value: Any, nbytes: int,
                    sink: "CacheStats | None") -> float:
        if nbytes > self.config.memory_bytes:
            # Too large for tier 0 outright: straight to disk (the
            # common case for capacity-0 configs, where it then also
            # fails the disk bound and is simply not cached).
            self._count(sink, mem_evictions=1)
            return self._insert_disk(key, value, nbytes, sink)
        self._mem[key] = (value, nbytes)
        self._mem_bytes += nbytes
        charge = 0.0
        while self._mem_bytes > self.config.memory_bytes:
            victim, (vval, vn) = self._mem.popitem(last=False)
            self._mem_bytes -= vn
            self._count(sink, mem_evictions=1)
            charge += self._insert_disk(victim, vval, vn, sink)
        return charge

    def _insert_disk(self, key: str, value: Any, nbytes: int,
                     sink: "CacheStats | None") -> float:
        if nbytes > self.config.disk_bytes:
            return 0.0  # exceeds the whole tier: not cached at all
        self._disk[key] = (value, nbytes)
        self._disk_bytes += nbytes
        self._count(sink, spills=1)
        while self._disk_bytes > self.config.disk_bytes:
            _, (_, vn) = self._disk.popitem(last=False)
            self._disk_bytes -= vn
            self._count(sink, disk_evictions=1)
        return self.config.disk_write_ms(nbytes)

    # -- reclamation (host-side, uncharged) ---------------------------------
    def invalidate_prefix(self, prefix: str) -> int:
        """Drop every entry under ``prefix`` (a finished job's
        namespace) from both tiers. Provider-side reclamation, like
        ``drop_namespace`` — charges nothing."""
        removed = 0
        with self._lock:
            for tier, attr in ((self._mem, "_mem_bytes"),
                               (self._disk, "_disk_bytes")):
                doomed = [k for k in tier if k.startswith(prefix)]
                for k in doomed:
                    _, n = tier.pop(k)
                    setattr(self, attr, getattr(self, attr) - n)
                removed += len(doomed)
        return removed

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._disk.clear()
            self._mem_bytes = 0
            self._disk_bytes = 0


class CacheRegistry:
    """All container caches of one platform, keyed ``(function, cid)``.

    The platform's warm pool decides container identity; the registry
    just makes the cache follow it: ``cache_for`` on (re)use, ``drop``
    when the pool expires or reclaims a container (its stats are folded
    into the retired accumulator so account-wide totals survive), and
    ``invalidate_prefix`` when a job's namespace is purged.
    """

    def __init__(self, config: CacheConfig):
        self.config = config
        self._lock = threading.Lock()
        self._caches: "dict[tuple[str, int], ExecutorCache]" = {}
        self._retired = CacheStats()

    def cache_for(self, function: str, container_id: int) -> ExecutorCache:
        key = (function, container_id)
        with self._lock:
            cache = self._caches.get(key)
            if cache is None:
                cache = ExecutorCache(self.config)
                self._caches[key] = cache
            return cache

    def get(self, function: str, container_id: int) -> "ExecutorCache | None":
        with self._lock:
            return self._caches.get((function, container_id))

    def drop(self, function: str, container_id: int) -> None:
        """The container is gone (keep-alive expiry / zero keep-alive
        reclamation): its cache dies with it."""
        with self._lock:
            cache = self._caches.pop((function, container_id), None)
            if cache is not None:
                self._retired.add(cache.stats)

    def invalidate_prefix(self, prefix: str) -> int:
        """Purge a finished job's entries from every container cache
        (registered as a ``ShardedKVStore`` purge listener)."""
        with self._lock:
            caches = list(self._caches.values())
        return sum(c.invalidate_prefix(prefix) for c in caches)

    def resident_bytes(self, function: str, container_id: int,
                       keys: Iterable[str]) -> int:
        cache = self.get(function, container_id)
        return cache.resident_bytes(keys) if cache is not None else 0

    def snapshot(self) -> "dict[str, Any]":
        """Account-wide cache counters: live + retired container stats,
        plus current residency. Fresh dict per call (the platform
        snapshot contract)."""
        with self._lock:
            caches = list(self._caches.values())
            total = CacheStats()
            total.add(self._retired)
        for c in caches:
            total.add(c.stats)
        out: "dict[str, Any]" = total.snapshot()
        out["containers"] = len(caches)
        out["resident_mem_bytes"] = sum(c.mem_bytes for c in caches)
        out["resident_disk_bytes"] = sum(c.disk_bytes for c in caches)
        return out
