"""User-facing DAG construction API (Dask-delayed style).

WUKONG's front-end parses "user-defined job code" into a DAG (paper
§IV-B: "users submit a Python computing job to WUKONG's DAG generator").
``GraphBuilder`` is that generator: calls record tasks, ``TaskRef``s wire
dependencies, ``build()`` validates and freezes the DAG.

    g = GraphBuilder()
    a = g.add(np.add, x, y, name="a")
    b = g.add(np.sum, a)
    dag = g.build()
    report = WukongEngine().compute(dag)
"""
from __future__ import annotations

import itertools
from typing import Any, Callable

from repro.core.dag import DAG, Task, TaskRef
from repro.core.optimize import CompiledDAG, OptimizeConfig, compile_dag


class GraphBuilder:
    def __init__(self) -> None:
        self._tasks: dict[str, Task] = {}
        self._counter = itertools.count()

    def add(
        self,
        fn: Callable[..., Any],
        *args: Any,
        name: str | None = None,
        **kwargs: Any,
    ) -> TaskRef:
        """Record a task; returns a ``TaskRef`` usable as an argument to
        later tasks."""
        key = name or f"{getattr(fn, '__name__', 'task')}-{next(self._counter)}"
        if key in self._tasks:
            raise ValueError(f"duplicate task name {key!r}")
        self._tasks[key] = Task(key, fn, tuple(args), dict(kwargs))
        return TaskRef(key)

    def literal(self, value: Any, name: str | None = None) -> TaskRef:
        """A leaf task producing a constant (input data block)."""
        key = name or f"literal-{next(self._counter)}"

        def produce() -> Any:
            return value

        produce.__name__ = "literal"
        if key in self._tasks:
            raise ValueError(f"duplicate task name {key!r}")
        self._tasks[key] = Task(key, produce)
        return TaskRef(key)

    def build(
        self, optimize: bool | OptimizeConfig | None = None
    ) -> DAG | CompiledDAG:
        """Validate and freeze the DAG.

        ``optimize`` runs the DAG compiler (``repro.core.optimize``)
        before freezing: ``True`` enables every pass with defaults, an
        ``OptimizeConfig`` selects passes individually, and ``None`` /
        ``False`` returns the graph verbatim. Engines run a compiled
        graph as-is (annotations included), so building optimized here
        is equivalent to setting ``optimize`` on the engine config.
        """
        dag = DAG(self._tasks.values())
        if not optimize:
            return dag
        cfg = optimize if isinstance(optimize, OptimizeConfig) else None
        return compile_dag(dag, cfg)


def delayed_graph(dsk: dict[str, Any]) -> DAG:
    """Build a DAG from a raw Dask-style dict (used by tests and by the
    serverful-baseline comparisons)."""
    return DAG.from_dsk(dsk)
