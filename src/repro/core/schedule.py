"""Static schedule generation (paper §IV-B).

For a DAG with n leaf nodes, n static schedules are generated. The schedule
for leaf L is the subgraph of all nodes reachable from L (computed with a
DFS starting at L) together with every edge into and out of those nodes.
A static schedule ships the task *code* for its member nodes plus the KV
store keys for task inputs, so a Task Executor never has to fetch task code
at runtime — the decentralization that §V-B measures as the single largest
performance factor.

A static schedule contains three types of operations: task execution,
fan-in and fan-out. We materialize these implicitly: between every
dependent pair (u, v) there is a fan-out at u (width = out-degree of u,
width 1 == the paper's "trivial fan-out") followed by a fan-in at v
(width = in-degree of v). The executor walks the schedule bottom-up from
its leaf, executing tasks along a single path and performing the dynamic
become/invoke (fan-out) and counter (fan-in) protocols at the boundaries.

Schedules only define a valid *partial order*; the time and place tasks
run is decided dynamically (paper: "A static schedule does not map a given
task T to a processor").
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import Iterator, Mapping

from repro.core.dag import DAG


@dataclasses.dataclass(frozen=True)
class StaticSchedule:
    """The DFS-reachable subgraph from one leaf, with shipped task code.

    ``nodes`` is the set of tasks whose code this schedule carries. The
    executor may only *execute* tasks in ``nodes``; in-edges arriving from
    other schedules' regions are known by key only (their outputs are read
    from the KV store after the fan-in counter resolves).

    When the DAG was run through the optimizer (``repro.core.optimize``)
    the schedule additionally ships the compiler annotations its executor
    consumes at runtime:

    ``clusters``       — member node -> cluster id (head of the node's
                         static become-path; the clustering pass).
    ``delayed_fanins`` — member fan-in nodes where arrivals use the atomic
                         deposit-and-increment protocol so the completing
                         arriver's locally-held inputs never travel to the
                         KV store (delayed I/O).
    """

    leaf: str
    nodes: frozenset[str]
    code_size_bytes: int  # serialized size of shipped task code (cost model)
    clusters: Mapping[str, str] = dataclasses.field(default_factory=dict)
    delayed_fanins: frozenset[str] = frozenset()

    def covers(self, key: str) -> bool:
        return key in self.nodes

    def delayed(self, key: str) -> bool:
        """True if fan-in arrivals at ``key`` delay KV writes (clustering)."""
        return key in self.delayed_fanins


@dataclasses.dataclass(frozen=True)
class ScheduleSet:
    """All static schedules for one DAG + the fan-in counter registry.

    The Storage Manager receives the DAG and the static schedules at the
    start of workflow processing (paper §IV-D); the counter ids created
    here are registered with the KV store before any executor launches.

    ``batches`` lists the initial executor invocations: one entry per
    invocation, as ``(start_keys, schedule)``. Without the coalescing
    pass every batch is a single leaf with its own schedule; with it,
    sibling leaves share one invocation and a merged schedule.
    """

    dag: DAG
    schedules: dict[str, StaticSchedule]  # leaf -> schedule
    batches: tuple[tuple[tuple[str, ...], StaticSchedule], ...] = ()

    def fan_in_counters(self) -> dict[str, int]:
        """counter id -> number of in-edges, for every true fan-in node."""
        return {
            _counter_id(k): len(self.dag.deps[k])
            for k in self.dag.tasks
            if len(self.dag.deps[k]) > 1
        }


def _counter_id(key: str) -> str:
    return f"__fanin__/{key}"


def generate_static_schedules(dag: DAG) -> ScheduleSet:
    """One schedule per leaf node, via DFS reachability (paper §IV-B).

    Optimizer annotations (``CompiledDAG``) are sliced into each schedule;
    a plain ``DAG`` yields annotation-free schedules and singleton batches.
    """
    clusters: Mapping[str, str] = getattr(dag, "clusters", {})
    delayed: frozenset[str] = getattr(dag, "delayed_fanins", frozenset())
    leaf_batches = getattr(dag, "leaf_batches", None) or tuple(
        (leaf,) for leaf in dag.leaves
    )
    schedules: dict[str, StaticSchedule] = {}
    for leaf in dag.leaves:
        nodes = dag.reachable_from(leaf)
        schedules[leaf] = _make_schedule(dag, leaf, nodes, clusters, delayed)
    batches = []
    for keys in leaf_batches:
        if len(keys) == 1:
            batches.append((tuple(keys), schedules[keys[0]]))
        else:
            union: set[str] = set()
            for k in keys:
                union |= schedules[k].nodes
            batches.append(
                (tuple(keys),
                 _make_schedule(dag, keys[0], union, clusters, delayed))
            )
    return ScheduleSet(dag=dag, schedules=schedules, batches=tuple(batches))


def _make_schedule(dag, leaf, nodes, clusters, delayed) -> StaticSchedule:
    return StaticSchedule(
        leaf=leaf,
        nodes=frozenset(nodes),
        code_size_bytes=_estimate_code_size(dag, nodes),
        clusters={k: clusters[k] for k in nodes if k in clusters},
        delayed_fanins=frozenset(k for k in nodes if k in delayed),
    )


def _estimate_code_size(dag: DAG, nodes: set[str]) -> int:
    """Serialized size of the shipped schedule (keys + task code refs).

    Real WUKONG cloudpickles task code into the schedule; we estimate with
    pickled key/function-name payloads so the invocation cost model can
    charge for schedule transfer without pickling unpicklable closures.
    """
    payload = [(k, getattr(dag.tasks[k].fn, "__name__", "fn")) for k in nodes]
    try:
        return len(pickle.dumps(payload))
    except Exception:  # pragma: no cover - defensive
        return 64 * len(nodes)


def subschedule_start_points(
    schedule: StaticSchedule, dag: DAG, node: str
) -> Iterator[str]:
    """Out-edges of ``node`` within ``schedule`` (fan-out targets).

    Each invoked Executor is assigned a static schedule that begins with
    one of the out edges; that schedule is a sub-graph of the inviting
    executor's schedule, so invoked executors reuse the parent's shipped
    code (paper §IV-C).
    """
    for child in dag.children[node]:
        assert schedule.covers(child), (
            "out-edge target must be reachable from the schedule's leaf"
        )
        yield child
