"""Static schedule generation (paper §IV-B).

For a DAG with n leaf nodes, n static schedules are generated. The schedule
for leaf L is the subgraph of all nodes reachable from L together with
every edge into and out of those nodes. A static schedule ships the task
*code* for its member nodes plus the KV store keys for task inputs, so a
Task Executor never has to fetch task code at runtime — the
decentralization that §V-B measures as the single largest performance
factor.

The seed implementation ran one DFS *per leaf* (the paper's description,
kept below as :func:`generate_static_schedules_dfs` — the reference
baseline the perf tests compare against). The production path is a single
reverse-topological sweep: each node's reachable set is built once from
its children's sets (O(V+E) set unions, shared by every leaf above it),
the shipped-code size is accumulated incrementally along the same sweep,
and a key -> covering-leaf index is derived in one forward pass so the
speculative monitor resolves a respawn's schedule in O(1) instead of
scanning every schedule.

A static schedule contains three types of operations: task execution,
fan-in and fan-out. We materialize these implicitly: between every
dependent pair (u, v) there is a fan-out at u (width = out-degree of u,
width 1 == the paper's "trivial fan-out") followed by a fan-in at v
(width = in-degree of v). The executor walks the schedule bottom-up from
its leaf, executing tasks along a single path and performing the dynamic
become/invoke (fan-out) and counter (fan-in) protocols at the boundaries.

Schedules only define a valid *partial order*; the time and place tasks
run is decided dynamically (paper: "A static schedule does not map a given
task T to a processor").
"""
from __future__ import annotations

import dataclasses
import pickle
import threading as _threading
from collections.abc import Mapping as _MappingABC
from typing import Iterator, Mapping

from repro.analysis.dagcheck import fan_in_counter_id as _counter_id
from repro.core.dag import DAG


@dataclasses.dataclass(frozen=True)
class StaticSchedule:
    """The reachable subgraph from one leaf, with shipped task code.

    ``nodes`` is the set of tasks whose code this schedule carries. The
    executor may only *execute* tasks in ``nodes``; in-edges arriving from
    other schedules' regions are known by key only (their outputs are read
    from the KV store after the fan-in counter resolves).

    When the DAG was run through the optimizer (``repro.core.optimize``)
    the schedule additionally ships the compiler annotations its executor
    consumes at runtime:

    ``clusters``       — node -> cluster id (head of the node's static
                         become-path; the clustering pass). May be the
                         whole DAG's mapping shared across schedules —
                         ``covers()`` gates membership, so entries for
                         non-member nodes are never consulted.
    ``delayed_fanins`` — fan-in nodes where arrivals use the atomic
                         deposit-and-increment protocol so the completing
                         arriver's locally-held inputs never travel to the
                         KV store (delayed I/O). Shared like ``clusters``.
    """

    leaf: str
    nodes: frozenset[str]
    code_size_bytes: int  # serialized size of shipped task code (cost model)
    clusters: Mapping[str, str] = dataclasses.field(default_factory=dict)
    delayed_fanins: frozenset[str] = frozenset()

    def covers(self, key: str) -> bool:
        return key in self.nodes

    def delayed(self, key: str) -> bool:
        """True if fan-in arrivals at ``key`` delay KV writes (clustering)."""
        return key in self.delayed_fanins


@dataclasses.dataclass(frozen=True)
class ScheduleSet:
    """All static schedules for one DAG + the fan-in counter registry.

    The Storage Manager receives the DAG and the static schedules at the
    start of workflow processing (paper §IV-D); the counter ids created
    here are registered with the KV store (in one batched round trip)
    before any executor launches.

    ``batches`` lists the initial executor invocations: one entry per
    invocation, as ``(start_keys, schedule)``. Without the coalescing
    pass every batch is a single leaf with its own schedule; with it,
    sibling leaves share one invocation and a merged schedule.

    ``covering`` maps every task key to one leaf whose schedule covers it
    (the speculative monitor's respawn index). Empty for schedule sets
    built by the reference DFS generator; ``covering_schedule`` falls
    back to a linear scan in that case.
    """

    dag: DAG
    schedules: Mapping[str, StaticSchedule]  # leaf -> schedule (may be lazy)
    batches: tuple[tuple[tuple[str, ...], StaticSchedule], ...] = ()
    covering: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def fan_in_counters(self) -> dict[str, int]:
        """counter id -> number of in-edges, for every true fan-in node."""
        return {
            _counter_id(k): len(self.dag.deps[k])
            for k in self.dag.tasks
            if len(self.dag.deps[k]) > 1
        }

    def covering_schedule(self, key: str) -> StaticSchedule | None:
        """A schedule covering ``key``: O(1) through the precomputed
        index, linear scan as a fallback for externally-built sets.
        The index hit is re-verified with ``covers`` — on a DynamicDAG a
        key added at runtime may map to a leaf whose (pre-expansion)
        schedule does not actually cover it."""
        leaf = self.covering.get(key)
        if leaf is not None:
            sched = self.schedules.get(leaf)
            if sched is not None and sched.covers(key):
                return sched
        for sched in self.schedules.values():
            if sched.covers(key):
                return sched
        return None

    def expansion_schedule(self, delta) -> StaticSchedule:
        """Incremental re-scheduling after a runtime expansion
        (``DynamicDAG.apply_expansion``): a schedule rooted at the
        expansion's base node, built in O(|subgraph|) by extending the
        O(V+E) sweep's retained reach/size tables over the delta —
        downstream reach of the re-bound key is reused, never re-swept.
        Falls back to a full reachability walk for schedule sets built
        by the reference DFS generator."""
        sched = self.schedules
        if isinstance(sched, _LeafSchedules):
            return sched.extend_for_expansion(self.dag, delta)
        nodes = self.dag.reachable_from(delta.base_key)
        return _make_schedule(
            self.dag, delta.base_key, nodes,
            getattr(self.dag, "clusters", {}),
            getattr(self.dag, "delayed_fanins", frozenset()))


# _counter_id is repro.analysis.dagcheck.fan_in_counter_id (imported
# above): the validator and the schedule generator must agree on the
# "__fanin__/" registration prefix, so there is exactly one definition.


# Shipped-code size estimate: real WUKONG cloudpickles task code into the
# schedule; we estimate per-node (key + function-name payload) sizes so
# the invocation cost model can charge for schedule transfer without
# pickling unpicklable closures. The per-node item sizes are summed
# incrementally along the reverse-topological sweep — no per-schedule
# serialization on the host hot path.
_CODE_BASE_BYTES = 16      # container/framing overhead
_CODE_ITEM_BYTES = 12      # per-item (key + fn-name) tuple/marker overhead

# _new_schedule writes the dataclass fields directly; fail at import time
# (not with a silent stale-field bug later) if StaticSchedule ever grows
# or reorders fields without this fast path being updated. An explicit
# raise, not an assert: the guard must survive python -O.
_SCHEDULE_FIELDS = ("leaf", "nodes", "code_size_bytes", "clusters",
                    "delayed_fanins")
if tuple(f.name for f in dataclasses.fields(StaticSchedule)) != \
        _SCHEDULE_FIELDS:
    raise RuntimeError(
        "update _new_schedule for the new StaticSchedule fields")


def _new_schedule(leaf, nodes, code_size_bytes, clusters, delayed):
    """Construct a StaticSchedule without the frozen-dataclass __init__
    (one ``object.__setattr__`` per field — measurably hot at one object
    per leaf/batch on wide DAGs). Guarded by the _SCHEDULE_FIELDS check
    above."""
    s = StaticSchedule.__new__(StaticSchedule)
    d = s.__dict__
    d["leaf"] = leaf
    d["nodes"] = nodes
    d["code_size_bytes"] = code_size_bytes
    d["clusters"] = clusters
    d["delayed_fanins"] = delayed
    return s


class _LeafSchedules(_MappingABC):
    """leaf -> StaticSchedule, materialized on first access.

    With the coalescing pass on, initial invocations use merged *batch*
    schedules, so most per-leaf schedule objects are only ever needed if
    the speculative monitor respawns into one — building them eagerly is
    pure host-side overhead on the job-start hot path. This view carries
    the sweep's shared reach/size tables and constructs (then caches) a
    schedule only when asked. Iteration order and membership match
    ``dag.leaves`` exactly, so the mapping is indistinguishable from the
    eager dict for every reader.
    """

    __slots__ = ("_leaves", "_leafset", "_reach", "_csize", "_clusters",
                 "_delayed", "_cache", "_extend_lock")

    def __init__(self, leaves, reach, csize, clusters, delayed):
        self._leaves = leaves
        self._leafset = frozenset(leaves)
        self._reach = reach
        self._csize = csize
        self._clusters = clusters
        self._delayed = delayed
        self._cache: dict[str, StaticSchedule] = {}
        # Serializes runtime-expansion table extensions (real concurrency
        # only exists in the realtime clock mode; the virtual substrates
        # run one actor at a time).
        self._extend_lock = _threading.Lock()

    def extend_for_expansion(self, dag, delta) -> StaticSchedule:
        """Extend the retained reach/size tables over an expansion delta
        (``delta.topo`` = base first, re-bound key last) and return the
        schedule rooted at the base node. O(|subgraph|): the re-bound
        key's downstream reach is already in the tables (its out-edges
        did not change) and is reused as-is."""
        reach, csize = self._reach, self._csize
        tasks, children = dag.tasks, dag.children
        with self._extend_lock:
            for k in reversed(delta.topo):
                if k == delta.key:
                    continue  # downstream reach unchanged; reuse
                item = (len(k)
                        + len(getattr(tasks[k].fn, "__name__", "fn"))
                        + _CODE_ITEM_BYTES)
                cs = children[k]
                if len(cs) == 1:
                    c = cs[0]
                    reach[k] = reach[c] | {k}
                    csize[k] = csize[c] + item
                elif not cs:
                    reach[k] = frozenset((k,))
                    csize[k] = item
                else:
                    union: set = {k}
                    for c in cs:
                        union |= reach[c]
                    r = frozenset(union)
                    reach[k] = r
                    csize[k] = sum(
                        len(n)
                        + len(getattr(tasks[n].fn, "__name__", "fn"))
                        + _CODE_ITEM_BYTES
                        for n in r)
            base = delta.base_key
            return _new_schedule(
                base, reach[base], _CODE_BASE_BYTES + csize[base],
                self._clusters, self._delayed)

    def __getitem__(self, leaf: str) -> StaticSchedule:
        s = self._cache.get(leaf)
        if s is None:
            if leaf not in self._leafset:
                raise KeyError(leaf)
            s = self._cache[leaf] = _new_schedule(
                leaf, self._reach[leaf],
                _CODE_BASE_BYTES + self._csize[leaf],
                self._clusters, self._delayed,
            )
        return s

    def __iter__(self):
        return iter(self._leaves)

    def __len__(self) -> int:
        return len(self._leaves)

    def __contains__(self, leaf) -> bool:
        return leaf in self._leafset


class _CoveringIndex(_MappingABC):
    """key -> one leaf whose schedule covers the key.

    Replaces the seed's per-respawn linear scan over every schedule with
    an O(V) index: a leaf covering any parent of ``k`` covers ``k`` too,
    so the first parent's covering leaf propagates in one forward
    topological pass. Built once, on first lookup — the speculative
    monitor only consults it when a straggler respawns, so the common
    job-start path never pays for it; every respawn after the first is an
    O(1) dict hit.
    """

    __slots__ = ("_dag", "_map")

    def __init__(self, dag: DAG):
        self._dag = dag
        self._map: dict[str, str] | None = None

    def _build(self) -> dict[str, str]:
        m: dict[str, str] = {}
        deps = self._dag.deps
        for k in self._dag.topological_order():
            d = deps[k]
            m[k] = m[d[0]] if d else k
        self._map = m
        return m

    def get(self, key, default=None):
        m = self._map
        if m is None:
            m = self._build()
        return m.get(key, default)

    def __getitem__(self, key: str) -> str:
        m = self._map
        if m is None:
            m = self._build()
        return m[key]

    def __iter__(self):
        m = self._map
        if m is None:
            m = self._build()
        return iter(m)

    def __len__(self) -> int:
        m = self._map
        if m is None:
            m = self._build()
        return len(m)


def generate_static_schedules(dag: DAG) -> ScheduleSet:
    """One schedule per leaf node via one reverse-topological sweep.

    Optimizer annotations (``CompiledDAG``) ride into each schedule as
    shared whole-DAG maps; a plain ``DAG`` yields annotation-free
    schedules and singleton batches. Semantics match the paper's per-leaf
    DFS (:func:`generate_static_schedules_dfs`) — see the equivalence
    property in tests/test_kvstore_dataplane.py.
    """
    clusters: Mapping[str, str] = getattr(dag, "clusters", {})
    delayed: frozenset[str] = getattr(dag, "delayed_fanins", frozenset())
    leaf_batches = getattr(dag, "leaf_batches", None) or tuple(
        (leaf,) for leaf in dag.leaves
    )
    topo = dag.topological_order()

    # Reverse sweep: children's reachable sets and code sizes exist before
    # their parents need them, so every set is built exactly once and
    # shared by all upstream nodes (the seed re-walked the region once per
    # leaf).
    tasks = dag.tasks
    children = dag.children
    item: dict[str, int] = {
        k: len(k) + len(getattr(t.fn, "__name__", "fn")) + _CODE_ITEM_BYTES
        for k, t in tasks.items()
    }
    reach: dict[str, frozenset[str]] = {}
    csize: dict[str, int] = {}
    for k in reversed(topo):
        cs = children[k]
        if len(cs) == 1:
            c = cs[0]
            reach[k] = reach[c] | {k}
            # k not in reach[c] (the DAG is acyclic), so sizes stay additive
            csize[k] = csize[c] + item[k]
        elif not cs:
            reach[k] = frozenset((k,))
            csize[k] = item[k]
        else:
            union: set[str] = {k}
            for c in cs:
                union |= reach[c]
            r = frozenset(union)
            reach[k] = r
            csize[k] = sum(item[n] for n in r)

    schedules = _LeafSchedules(dag.leaves, reach, csize, clusters, delayed)

    batches: list[tuple[tuple[str, ...], StaticSchedule]] = []
    for keys in leaf_batches:
        if len(keys) == 1:
            batches.append((tuple(keys), schedules[keys[0]]))
            continue
        k0 = keys[0]
        sig = children[k0]
        same_sig = True
        extra = 0
        for k in keys[1:]:
            if children[k] != sig:
                same_sig = False
                break
            extra += item[k]
        if same_sig:
            # The coalescing pass only batches sibling leaves with an
            # identical child signature, so their reachable sets differ
            # only in the leaves themselves: extend one member's set
            # instead of re-unioning the whole region per batch.
            union_nodes = reach[k0].union(keys[1:])
            code_size = _CODE_BASE_BYTES + csize[k0] + extra
        else:
            union_nodes = frozenset().union(*(reach[k] for k in keys))
            code_size = (_CODE_BASE_BYTES
                         + sum(item[n] for n in union_nodes))
        batches.append((
            tuple(keys),
            _new_schedule(k0, union_nodes, code_size, clusters, delayed),
        ))

    return ScheduleSet(dag=dag, schedules=schedules, batches=tuple(batches),
                       covering=_CoveringIndex(dag))


# ---------------------------------------------------------------------------
# Reference implementation: the paper's per-leaf DFS (the seed behavior).
# Kept as the baseline that the O(V+E) sweep is validated and benchmarked
# against; not used on the production path.
# ---------------------------------------------------------------------------


def generate_static_schedules_dfs(dag: DAG) -> ScheduleSet:
    """One schedule per leaf node, via one DFS per leaf (paper §IV-B)."""
    clusters: Mapping[str, str] = getattr(dag, "clusters", {})
    delayed: frozenset[str] = getattr(dag, "delayed_fanins", frozenset())
    leaf_batches = getattr(dag, "leaf_batches", None) or tuple(
        (leaf,) for leaf in dag.leaves
    )
    schedules: dict[str, StaticSchedule] = {}
    for leaf in dag.leaves:
        nodes = dag.reachable_from(leaf)
        schedules[leaf] = _make_schedule(dag, leaf, nodes, clusters, delayed)
    batches = []
    for keys in leaf_batches:
        if len(keys) == 1:
            batches.append((tuple(keys), schedules[keys[0]]))
        else:
            union: set[str] = set()
            for k in keys:
                union |= schedules[k].nodes
            batches.append(
                (tuple(keys),
                 _make_schedule(dag, keys[0], union, clusters, delayed))
            )
    return ScheduleSet(dag=dag, schedules=schedules, batches=tuple(batches))


def _make_schedule(dag, leaf, nodes, clusters, delayed) -> StaticSchedule:
    return StaticSchedule(
        leaf=leaf,
        nodes=frozenset(nodes),
        code_size_bytes=_estimate_code_size(dag, nodes),
        clusters={k: clusters[k] for k in nodes if k in clusters},
        delayed_fanins=frozenset(k for k in nodes if k in delayed),
    )


def _estimate_code_size(dag: DAG, nodes: set[str]) -> int:
    """Serialized size of the shipped schedule via an actual pickle of the
    key/function-name payload (the reference generator's estimator)."""
    payload = [(k, getattr(dag.tasks[k].fn, "__name__", "fn")) for k in nodes]
    try:
        return len(pickle.dumps(payload))
    except Exception:  # pragma: no cover - defensive
        return 64 * len(nodes)


def subschedule_start_points(
    schedule: StaticSchedule, dag: DAG, node: str
) -> Iterator[str]:
    """Out-edges of ``node`` within ``schedule`` (fan-out targets).

    Each invoked Executor is assigned a static schedule that begins with
    one of the out edges; that schedule is a sub-graph of the inviting
    executor's schedule, so invoked executors reuse the parent's shipped
    code (paper §IV-C).
    """
    for child in dag.children[node]:
        assert schedule.covers(child), (
            "out-edge target must be reachable from the schedule's leaf"
        )
        yield child
