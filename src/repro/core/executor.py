"""The decentralized Task Executor runtime (paper §IV-C).

Each executor is one simulated Lambda invocation. It receives a static
schedule start point and walks the DAG bottom-up along a single path:

  1. *fan-in* at the current node (in-degree > 1): publish locally-held
     input objects, atomically record this in-edge on the dependency
     counter; the LAST arriver continues, everyone else stops. Nobody
     waits — FaaS bills wall-clock, so waiting is money (paper §IV-C).
  2. *execute* the current task, caching the output in executor-local
     memory (data locality: a chain of tasks costs zero network I/O).
  3. *fan-out*: width 1 is trivial (continue along the chain). Width n>1:
     publish the output, *become* the executor of one out-edge and
     *invoke* executors for the other n-1 (through the proxy when the
     width crosses the proxy threshold).

Fault tolerance: an injected failure aborts the invocation; the engine
re-invokes the executor from its start point with a fresh local cache,
exactly like AWS Lambda's automatic retry (≤ 2). Idempotent KV writes and
edge-set counters make retries and speculative duplicates safe.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable

from repro.core.dag import DAG, TaskRef
from repro.core.faults import (
    ExecutorHeartbeat,
    FaultInjector,
    HeartbeatRegistry,
    SimulatedTaskFailure,
)
from repro.core.kvstore import ShardedKVStore, sizeof
from repro.core.schedule import StaticSchedule, _counter_id

RESULTS_CHANNEL = "__results__"


class TaskMetrics:
    """Per-task timing records for the Fig.13-style CDF breakdown."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.records: list[dict[str, Any]] = []

    def record(self, **kw: Any) -> None:
        with self._lock:
            self.records.append(kw)


class ExecutorContext:
    """Everything an executor needs from the engine (shared, read-mostly)."""

    def __init__(
        self,
        dag: DAG,
        kv: ShardedKVStore,
        spawn: Callable[..., None],
        faults: FaultInjector,
        heartbeats: HeartbeatRegistry,
        metrics: TaskMetrics,
        inline_fanout_args: bool = False,
        executed_counter: list[int] | None = None,
    ):
        self.dag = dag
        self.kv = kv
        self.spawn = spawn  # spawn(start_key, seed_cache, schedule, width)
        self.faults = faults
        self.heartbeats = heartbeats
        self.metrics = metrics
        self.inline_fanout_args = inline_fanout_args
        self._id_lock = threading.Lock()
        self._next_id = 0

    def next_executor_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id


class TaskExecutor:
    def __init__(
        self,
        ctx: ExecutorContext,
        schedule: StaticSchedule,
        start_key: str,
        seed_cache: dict[str, Any] | None = None,
        attempt: int = 0,
        parent: str | None = None,
    ):
        self.ctx = ctx
        self.schedule = schedule
        self.start_key = start_key
        self.seed_cache = dict(seed_cache or {})
        self.attempt = attempt
        # The in-edge this executor travels into its start node (set when
        # invoked at a fan-out). Required so fan-in edge ids are unique per
        # in-edge — two executors invoked into the same fan-in node from
        # different parents must increment different edge ids.
        self.parent = parent
        self.executor_id = ctx.next_executor_id()
        self.cache: dict[str, Any] = {}
        self.tasks_executed = 0

    # -- helpers -------------------------------------------------------------
    def _edge_id(self, src: str, dst: str) -> str:
        return f"{src}=>{dst}"

    def _publish_local_deps_of(self, key: str) -> float:
        """Publish locally-held objects that ``key`` depends on. Returns
        simulated/wall ms spent writing."""
        t0 = time.perf_counter()
        for dep in self.ctx.dag.deps[key]:
            if dep in self.cache:
                self.ctx.kv.put_if_absent(dep, self.cache[dep])
        return (time.perf_counter() - t0) * 1e3

    def _gather_inputs(self, key: str) -> tuple[list[Any], dict[str, Any], float]:
        task = self.ctx.dag.tasks[key]
        t0 = time.perf_counter()

        def resolve(a: Any) -> Any:
            if isinstance(a, TaskRef):
                if a.key in self.cache:
                    return self.cache[a.key]  # data locality: no network
                return self.ctx.kv.get(a.key)
            return a

        args = [resolve(a) for a in task.args]
        kwargs = {k: resolve(v) for k, v in task.kwargs.items()}
        return args, kwargs, (time.perf_counter() - t0) * 1e3

    # -- the walk -------------------------------------------------------------
    def run(self) -> None:
        hb = ExecutorHeartbeat(
            executor_id=self.executor_id,
            start_key=self.start_key,
            current_key=self.start_key,
            started_at=time.perf_counter(),
            parent=self.parent,
        )
        self.ctx.heartbeats.beat(hb)
        try:
            self._walk()
        except SimulatedTaskFailure:
            if self.attempt < self.ctx.faults.config.max_retries:
                # Lambda automatic retry: fresh container, same event payload.
                self.ctx.spawn(
                    self.start_key,
                    dict(self.seed_cache),
                    self.schedule,
                    width=1,
                    attempt=self.attempt + 1,
                    parent=self.parent,
                )
            else:
                self.ctx.kv.publish(
                    RESULTS_CHANNEL,
                    {"type": "error", "key": self.start_key,
                     "error": "task failed after max retries"},
                )
        except Exception as exc:  # task-code bug: fail the job loudly
            self.ctx.kv.publish(
                RESULTS_CHANNEL,
                {"type": "error", "key": self.start_key, "error": repr(exc)},
            )
        finally:
            self.ctx.heartbeats.done(self.executor_id)

    def _walk(self) -> None:
        dag = self.ctx.dag
        kv = self.ctx.kv
        self.cache.update(self.seed_cache)
        current = self.start_key
        prev: str | None = self.parent

        while True:
            # ---- fan-in operation (paper §IV-C) --------------------------
            indeg = len(dag.deps[current])
            if indeg > 1:
                write_ms = self._publish_local_deps_of(current)
                edge = self._edge_id(prev or "__leaf__", current)
                count = kv.increment_dependency(_counter_id(current), edge)
                if count < indeg:
                    # Some dependencies unsatisfied: store outputs and STOP.
                    # (Never wait: Lambda bills wait time, paper §IV-C.)
                    self.ctx.metrics.record(
                        task=current, event="fanin_stop", write_ms=write_ms,
                        executor=self.executor_id,
                    )
                    return
                # Last arriver: continue through the fan-in.

            # ---- task execution ------------------------------------------
            if not self.schedule.covers(current):
                raise AssertionError(
                    f"executor schedule {self.schedule.leaf!r} does not "
                    f"cover task {current!r}"
                )
            args, kwargs, read_ms = self._gather_inputs(current)
            hb = ExecutorHeartbeat(
                executor_id=self.executor_id,
                start_key=self.start_key,
                current_key=current,
                started_at=time.perf_counter(),
                parent=self.parent,
            )
            self.ctx.heartbeats.beat(hb)

            if self.ctx.faults.should_fail(current, self.attempt):
                raise SimulatedTaskFailure(current)
            straggle = self.ctx.faults.straggle_ms(current, self.attempt)
            if straggle > 0:
                kv.clock.charge(straggle)

            t0 = time.perf_counter()
            out = dag.tasks[current].fn(*args, **kwargs)
            compute_ms = (time.perf_counter() - t0) * 1e3
            self.cache[current] = out
            self.tasks_executed += 1

            children = dag.children[current]
            # ---- sink: final result --------------------------------------
            if not children:
                t0 = time.perf_counter()
                kv.put_if_absent(current, out)
                write_ms = (time.perf_counter() - t0) * 1e3
                kv.publish(
                    RESULTS_CHANNEL,
                    {"type": "result", "key": current},
                )
                self.ctx.metrics.record(
                    task=current, event="executed", read_ms=read_ms,
                    compute_ms=compute_ms, write_ms=write_ms,
                    nbytes=sizeof(out), executor=self.executor_id,
                )
                return

            self.ctx.metrics.record(
                task=current, event="executed", read_ms=read_ms,
                compute_ms=compute_ms, write_ms=0.0, nbytes=sizeof(out),
                executor=self.executor_id,
            )

            # ---- fan-out operation (paper §IV-C) -------------------------
            if len(children) == 1:
                prev, current = current, children[0]  # trivial fan-out
                continue

            become, *invoked = children
            write_ms = 0.0
            if not self.ctx.inline_fanout_args:
                # Intermediate outputs needed by the new executors go to the
                # KV store; invoked executors receive the keys (paper §IV-C).
                t0 = time.perf_counter()
                kv.put_if_absent(current, out)
                write_ms = (time.perf_counter() - t0) * 1e3
                seed: dict[str, Any] = {}
            else:
                # Beyond-paper optimization: carry the value inline with the
                # invocation payload (fan-in republish keeps correctness).
                seed = {current: out}
            for child in invoked:
                self.ctx.spawn(child, dict(seed), self.schedule,
                               width=len(invoked), parent=current)
            self.ctx.metrics.record(
                task=current, event="fanout", width=len(children),
                write_ms=write_ms, executor=self.executor_id,
            )
            prev, current = current, become
