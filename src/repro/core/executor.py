"""The decentralized Task Executor runtime (paper §IV-C).

Each executor is one simulated Lambda invocation. It receives a static
schedule start point and walks the DAG bottom-up along a single path:

  1. *fan-in* at the current node (in-degree > 1): publish locally-held
     input objects, atomically record this in-edge on the dependency
     counter; the LAST arriver continues, everyone else stops. Nobody
     waits — FaaS bills wall-clock, so waiting is money (paper §IV-C).
  2. *execute* the current task, caching the output in executor-local
     memory (data locality: a chain of tasks costs zero network I/O).
  3. *fan-out*: width 1 is trivial (continue along the chain). Width n>1:
     publish the output, *become* the executor of one out-edge and
     *invoke* executors for the other n-1 (through the proxy when the
     width crosses the proxy threshold).

Fault tolerance: an injected failure aborts the invocation; the engine
re-invokes the executor from its start point with a fresh local cache,
exactly like AWS Lambda's automatic retry (≤ 2). Idempotent KV writes and
edge-set counters make retries and speculative duplicates safe.

Optimizer integration (repro.core.optimize):

- *coalescing*: an executor may receive several start keys (a batch of
  sibling leaves, or a chunk of fan-out children). It walks them in
  order with ONE shared local cache, so a batch whose members meet at a
  fan-in resolves the fan-in entirely in executor memory.
- *clustering / delayed I/O*: at fan-in nodes the schedule marks as
  delayed, arrivals use the KV store's atomic deposit-and-increment:
  locally-held inputs are persisted in the same round trip as the
  counter update, and the completing arrival skips the write, carrying
  its objects through the fan-in in local memory. Safe under retries
  and speculation because every (re-)invocation starts from its start
  key and recomputes the values it holds locally.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from repro.core.cache import CacheStats, ExecutorCache
from repro.core.dag import DAG, Expansion, TaskRef
from repro.core.faults import (
    ExecutorHeartbeat,
    FaultInjector,
    FaultStats,
    HeartbeatRegistry,
    SimulatedTaskFailure,
)
from repro.core.kvstore import ShardedKVStore, sizeof
from repro.core.schedule import StaticSchedule, _counter_id
from repro.core.simclock import BaseClock, task_clock

RESULTS_CHANNEL = "__results__"


class TaskMetrics:
    """Per-task timing records for the Fig.13-style CDF breakdown.

    Every record is stamped ``at_ms`` from the engine clock — virtual
    milliseconds under the virtual clock, so the fig13 CDF is
    deterministic and independent of host load."""

    def __init__(self, clock: BaseClock | None = None,
                 enabled: bool = True) -> None:
        self._lock = threading.Lock()
        self.clock = clock
        # Million-task runs: ~2.5 record dicts per task dominate memory;
        # the scaling benchmarks disable recording (charges/kv counters
        # are unaffected — records never touch the clock).
        self.enabled = enabled
        # Stamps are relative to this origin (the engine sets it to the
        # job's t0). On a shared substrate the clock does not restart per
        # job, so absolute stamps would make otherwise-identical jobs
        # report differently.
        self.origin_ms = 0.0
        self.records: list[dict[str, Any]] = []

    def record(self, **kw: Any) -> None:
        if not self.enabled:
            return
        if self.clock is not None and "at_ms" not in kw:
            kw["at_ms"] = self.clock.now_ms() - self.origin_ms
        with self._lock:
            self.records.append(kw)


class ExecutorContext:
    """Everything an executor needs from the engine (shared, read-mostly)."""

    def __init__(
        self,
        dag: DAG,
        kv: ShardedKVStore,
        spawn: Callable[..., Any],
        faults: FaultInjector,
        heartbeats: HeartbeatRegistry,
        metrics: TaskMetrics,
        inline_fanout_args: bool = False,
        executed_counter: list[int] | None = None,
        coalesce_batch: int = 0,
        batch_kv_round_trips: bool = True,
        compute_clock: Any = None,
        stop: Any = None,
        resume: bool = False,
        fault_stats: "FaultStats | None" = None,
        schedule_set: Any = None,
    ):
        self.dag = dag
        self.kv = kv
        # spawn(start_keys, seed_cache, schedule, width) — a generator
        # function (effect protocol); executors drive it with yield from.
        self.spawn = spawn
        self.faults = faults
        self.heartbeats = heartbeats
        self.metrics = metrics
        self.inline_fanout_args = inline_fanout_args
        # >0: chunk invoked fan-out children into batches of this size
        # (optimizer coalescing pass; 0 disables).
        self.coalesce_batch = coalesce_batch
        # Gather task inputs with one pipelined mget per task (one
        # kv_base_ms per shard batch) instead of one get per key.
        self.batch_kv_round_trips = batch_kv_round_trips
        # Clock installed around task-function calls. The platform model
        # passes a memory-scaled proxy here (CPU share proportional to
        # memory size); None = the engine clock unscaled.
        self.compute_clock = compute_clock or kv.clock
        # Per-job stop signal (Event-compatible). Set when the job
        # resolves OR fails; executors check it at task boundaries so an
        # abandoned job stops consuming shared warm-pool / throttle /
        # lane capacity instead of running its walk to the end.
        self.stop = stop
        # Resumed job (crash recovery): executors probe the store for a
        # durable output before executing each task and reuse it instead
        # of recomputing — journaled-complete work is never re-executed.
        self.resume = resume
        # Shared per-job fault/retry observability counters (JobReport).
        self.fault_stats = fault_stats or FaultStats()
        # The job's ScheduleSet (repro.core.schedule): dynamic-DAG
        # expansions re-schedule incrementally through it. None for
        # callers that never expand (tests building contexts by hand).
        self.schedule_set = schedule_set
        # Per-job cache-tier counters (JobReport.cache_stats): container
        # caches count account-wide on their own; executors pass this
        # sink so the job's report never includes another tenant's hits.
        self.cache_stats = CacheStats()
        # Container caches are shared across jobs of a function, so they
        # key on STORE-QUALIFIED names (namespace prefix included).
        self.cache_prefix = (
            kv.qualified_key("") if hasattr(kv, "qualified_key") else "")
        self._id_lock = threading.Lock()
        self._next_id = 0

    def stopped(self) -> bool:
        return self.stop is not None and self.stop.is_set()

    def next_executor_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id


class TaskExecutor:
    def __init__(
        self,
        ctx: ExecutorContext,
        schedule: StaticSchedule,
        start_key: "str | tuple[str, ...]",
        seed_cache: dict[str, Any] | None = None,
        attempt: int = 0,
        parent: str | None = None,
        container_cache: "ExecutorCache | None" = None,
    ):
        self.ctx = ctx
        self.schedule = schedule
        # Coalesced invocations carry several start keys; the executor
        # walks them in order with one shared local cache.
        self.start_keys: tuple[str, ...] = (
            (start_key,) if isinstance(start_key, str) else tuple(start_key)
        )
        self.start_key = self.start_keys[0]
        self.seed_cache = dict(seed_cache or {})
        self.attempt = attempt
        # The in-edge this executor travels into its start node (set when
        # invoked at a fan-out). Required so fan-in edge ids are unique per
        # in-edge — two executors invoked into the same fan-in node from
        # different parents must increment different edge ids. Every start
        # key in a coalesced batch shares the same parent (same fan-out).
        self.parent = parent
        self.executor_id = ctx.next_executor_id()
        self.cache: dict[str, Any] = {}
        # The CONTAINER's multi-tier cache (repro.core.cache), handed in
        # by the platform wrapper: outlives this invocation on warm
        # reuse, so it serves objects across executors — unlike
        # ``self.cache``, which is this walk's private (free, unbounded)
        # working set. None without a platform cache configured.
        self.ccache = container_cache
        self.tasks_executed = 0
        self._failed_at = 0  # index of the start key whose walk failed

    # -- helpers -------------------------------------------------------------
    def _edge_id(self, src: str, dst: str) -> str:
        return f"{src}=>{dst}"

    def _publish_local_deps_of_g(self, key: str):
        """Publish locally-held objects that ``key`` depends on. Returns
        simulated ms spent writing (clock delta: charged latency plus any
        lane-contention queueing)."""
        clock = self.ctx.kv.clock
        t0 = clock.now_ms()
        for dep in self.ctx.dag.deps[key]:
            if dep in self.cache:
                yield from self.ctx.kv.put_if_absent_g(dep, self.cache[dep])
        return clock.now_ms() - t0

    def _qkey(self, key: str) -> str:
        return self.ctx.cache_prefix + key

    def _probe_tiers_g(self, key: str):
        """Probe the container cache (memory, then disk) for ``key``.
        Returns ``(hit, value)``; a miss means tier 2 — the shared KV
        store — which the caller was about to pay anyway."""
        if self.ccache is None:
            return False, None
        return (yield from self.ccache.probe_g(
            self._qkey(key), stats=self.ctx.cache_stats))

    def _readthrough_g(self, key: str, value: Any):
        """Deposit a remotely-fetched input into the container cache."""
        if self.ccache is not None:
            yield from self.ccache.deposit_g(
                self._qkey(key), value, sizeof(value),
                stats=self.ctx.cache_stats)

    def _resolve_g(self, a: Any, fetched: dict[str, Any]):
        if isinstance(a, TaskRef):
            if a.key in self.cache:
                return self.cache[a.key]  # data locality: no network
            if a.key in fetched:
                return fetched[a.key]
            hit, val = yield from self._probe_tiers_g(a.key)
            if hit:
                return val
            val = yield from self.ctx.kv.get_g(a.key)
            yield from self._readthrough_g(a.key, val)
            return val
        return a

    def _gather_inputs_g(self, key: str):
        task = self.ctx.dag.tasks[key]
        clock = self.ctx.kv.clock
        t0 = clock.now_ms()

        # Remote inputs (not in the local cache) are fetched in ONE
        # pipelined mget — keys grouped by shard, one base round trip per
        # shard batch — instead of one round trip per key (the fan-in
        # path's completing arrival reads all its siblings' outputs here).
        fetched: dict[str, Any] = {}
        if self.ctx.batch_kv_round_trips:
            need: list[str] = []
            for a in list(task.args) + list(task.kwargs.values()):
                if (isinstance(a, TaskRef) and a.key not in self.cache
                        and a.key not in fetched):
                    # Tier probe before the remote mget: an input a
                    # previous invocation of this container produced (or
                    # spilled) is served locally and drops out of the
                    # remote batch entirely.
                    hit, val = yield from self._probe_tiers_g(a.key)
                    if hit:
                        fetched[a.key] = val
                        continue
                    fetched[a.key] = None
                    need.append(a.key)
            if need:
                values = yield from self.ctx.kv.mget_g(need)
                fetched.update(zip(need, values))
                for k in need:
                    # Read-through: a remote fetch leaves a tier-0 copy
                    # behind, so the NEXT invocation this container hosts
                    # (a hint-steered sibling sharing the input, a
                    # retry) reads it locally. This is where shared
                    # inputs — e.g. a GEMM block feeding b multiplies —
                    # stop costing one KV transfer per consumer.
                    yield from self._readthrough_g(k, fetched[k])

        args = []
        for a in task.args:
            args.append((yield from self._resolve_g(a, fetched)))
        kwargs = {}
        for k, v in task.kwargs.items():
            kwargs[k] = yield from self._resolve_g(v, fetched)
        return args, kwargs, clock.now_ms() - t0

    # -- the walk -------------------------------------------------------------
    def run_g(self):
        """The executor body as an effect-protocol generator (simclock).

        Drive it with ``clock.spawn`` (event substrate runs it as a frame,
        thread substrates interpret it via ``run_effects``)."""
        hb = ExecutorHeartbeat(
            executor_id=self.executor_id,
            start_key=self.start_key,
            current_key=self.start_key,
            started_at=self.ctx.kv.clock.now_ms(),
            parent=self.parent,
            start_keys=self.start_keys,
        )
        self.ctx.heartbeats.beat(hb)
        try:
            yield from self._walk_g()
        except SimulatedTaskFailure:
            failed = self._failed_at
            if self.ctx.stopped():
                pass  # dead job: no retry, no error publish
            elif self.attempt < self.ctx.faults.config.max_retries:
                # Lambda's retry delay: charged (not slept) on the clock,
                # exponential in the attempt number.
                backoff = self.ctx.faults.retry_backoff_ms(self.attempt)
                if backoff > 0:
                    yield ("charge", backoff)
                self.ctx.fault_stats.bump("task_retries")
                # Lambda automatic retry: fresh container. Only the failing
                # start re-runs on the incremented attempt; completed walks
                # are durable (idempotent deposits/spawns), and un-walked
                # batch members have not consumed any of their own retry
                # budget yet, so they respawn at attempt 0. This keeps a
                # coalesced batch's fault tolerance identical per-task to
                # uncoalesced execution.
                hints = ()
                if self.ccache is not None:
                    # Bias the retry toward a container holding the
                    # failed walk's inputs: the retry then refetches
                    # them from its cache tiers instead of the KV store.
                    hints = tuple(dict.fromkeys(
                        self._qkey(d)
                        for d in self.ctx.dag.deps[self.start_keys[failed]]))
                yield from self.ctx.spawn(
                    self.start_keys[failed],
                    dict(self.seed_cache),
                    self.schedule,
                    width=1,
                    attempt=self.attempt + 1,
                    parent=self.parent,
                    hint_keys=hints,
                )
                rest = self.start_keys[failed + 1:]
                if rest:
                    yield from self.ctx.spawn(
                        rest,
                        dict(self.seed_cache),
                        self.schedule,
                        width=1,
                        attempt=0,
                        parent=self.parent,
                    )
            else:
                yield from self.ctx.kv.publish_g(
                    RESULTS_CHANNEL,
                    {"type": "error", "key": self.start_keys[failed],
                     "error": "task failed after max retries"},
                )
        except Exception as exc:  # task-code bug: fail the job loudly
            yield from self.ctx.kv.publish_g(
                RESULTS_CHANNEL,
                {"type": "error", "key": self.start_key, "error": repr(exc)},
            )
        finally:
            self.ctx.heartbeats.done(self.executor_id)

    def _walk_g(self):
        self.cache.update(self.seed_cache)
        # Coalesced batches: walk each start key in order. The local cache
        # persists across walks, so batch members meeting at a fan-in
        # resolve it without any KV reads.
        for i, start in enumerate(self.start_keys):
            self._failed_at = i
            yield from self._walk_from_g(start)

    def _walk_from_g(self, start: str):
        dag = self.ctx.dag
        kv = self.ctx.kv
        clock = kv.clock
        current = start
        prev: str | None = self.parent

        while True:
            # ---- job-cancellation boundary -------------------------------
            if self.ctx.stopped():
                # The job resolved or failed while this executor was in
                # flight: stop here rather than walking (and billing)
                # the rest of the path against a dead job.
                return

            # ---- fan-in operation (paper §IV-C) --------------------------
            indeg = len(dag.deps[current])
            if indeg > 1:
                edge = self._edge_id(prev or "__leaf__", current)
                missing: list[str] = []
                if self.schedule.delayed(current):
                    # Delayed I/O (optimizer clustering pass): deposit the
                    # locally-held inputs atomically with the counter
                    # update; the completing arrival skips the write and
                    # keeps its objects in executor memory. The presence
                    # of the remaining inputs rides the same reply.
                    items = {
                        dep: self.cache[dep]
                        for dep in dag.deps[current]
                        if dep in self.cache
                    }
                    expected = tuple(
                        dep for dep in dag.deps[current] if dep not in items
                    )
                    t0 = clock.now_ms()
                    count, missing = yield from kv.deposit_and_increment_g(
                        _counter_id(current), edge, items, expected
                    )
                    write_ms = clock.now_ms() - t0
                else:
                    write_ms = yield from self._publish_local_deps_of_g(
                        current
                    )
                    count = yield from kv.increment_dependency_g(
                        _counter_id(current), edge
                    )
                if count < indeg:
                    # Some dependencies unsatisfied: store outputs and STOP.
                    # (Never wait: Lambda bills wait time, paper §IV-C.)
                    self.ctx.metrics.record(
                        task=current, event="fanin_stop", write_ms=write_ms,
                        executor=self.executor_id,
                    )
                    return
                # Last arriver: continue through the fan-in.
                if missing:
                    # Delayed I/O keeps the completing arrival's value out
                    # of the KV store, so a retried/coalesced invocation
                    # can observe a fully-recorded counter whose missing
                    # input lives only in the memory of the invocation
                    # that recorded it (e.g. a later start key of this
                    # very batch, not yet re-walked this attempt). Stop;
                    # the invocation that recomputes the value completes
                    # the fan-in.
                    self.ctx.metrics.record(
                        task=current, event="fanin_defer",
                        executor=self.executor_id,
                    )
                    return

            # ---- task execution ------------------------------------------
            if not self.schedule.covers(current):
                raise AssertionError(
                    f"executor schedule {self.schedule.leaf!r} does not "
                    f"cover task {current!r}"
                )
            resumed = False
            read_ms = 0.0
            compute_ms = 0.0
            if self.ctx.resume:
                # Crash recovery: a prior generation may already have
                # executed this task durably. One charged probe round
                # trip; on a hit the output is fetched (charged) and the
                # execution — and its fault injection — is skipped, so
                # journaled-complete work is never re-executed.
                yield ("charge", kv.cost.kv_base_ms)
                if kv.exists(current):
                    out = yield from kv.get_g(current)
                    resumed = True
                    self.ctx.fault_stats.bump("tasks_resumed")

            if not resumed:
                args, kwargs, read_ms = yield from self._gather_inputs_g(
                    current)
                hb = ExecutorHeartbeat(
                    executor_id=self.executor_id,
                    start_key=self.start_key,
                    current_key=current,
                    started_at=clock.now_ms(),
                    parent=self.parent,
                    start_keys=self.start_keys,
                )
                self.ctx.heartbeats.beat(hb)

                self.ctx.fault_stats.bump("task_attempts")
                if self.ctx.faults.should_fail(current, self.attempt):
                    self.ctx.fault_stats.bump("injected_failures")
                    raise SimulatedTaskFailure(current)
                straggle = self.ctx.faults.straggle_ms(current, self.attempt)
                if straggle > 0:
                    yield ("charge", straggle)

                # The engine clock is installed for the duration of the task
                # function so workload-declared compute (simulated_compute /
                # per-flop costs) is charged as simulated time.
                t0 = clock.now_ms()
                with task_clock(self.ctx.compute_clock):
                    out = dag.tasks[current].fn(*args, **kwargs)
                # Event substrate: compute charged inside the task function
                # is deferred (the function cannot yield); flush it onto the
                # clock before reading the delta. No-op on the thread
                # substrates.
                yield ("flush",)
                compute_ms = clock.now_ms() - t0
                self.tasks_executed += 1

            # ---- dynamic expansion (DynamicDAG) ----------------------
            if isinstance(out, Expansion):
                # The task grew the graph: install the subgraph, then
                # relabel this walk to the synthetic base node carrying
                # the task's own value and fall through to the NORMAL
                # sink/fan-out path — every KV write, counter op, and
                # spawn below is then identical to running the
                # statically pre-expanded equivalent graph.
                apply = getattr(dag, "apply_expansion", None)
                if apply is None:
                    raise RuntimeError(
                        f"task {current!r} returned an Expansion but the "
                        f"DAG is not a DynamicDAG")
                delta = apply(current, out)
                # Fan-in counters for the delta: registered/re-bound
                # host-side, uncharged (the job-start batched
                # registration already paid; see
                # ShardedKVStore.rebind_counter). A replayed delta (the
                # task ran twice — resume over a crashed run's counters,
                # or a speculative duplicate) must leave the counters
                # alone: the first application's subgraph is live on
                # them, and a reset would strand its in-flight edges.
                if not delta.replayed:
                    for k, width in delta.fan_in_widths.items():
                        kv.rebind_counter(_counter_id(k), width)
                if self.ctx.schedule_set is not None:
                    self.schedule = \
                        self.ctx.schedule_set.expansion_schedule(delta)
                current = delta.base_key
                out = delta.value

            self.cache[current] = out
            # One sizeof walk per output, reused by metrics and as the
            # KV write's size hint (the store records it per key).
            out_nbytes = sizeof(out)
            if self.ccache is not None:
                # Tier-0 deposit: the output stays container-resident
                # across warm reuses, so later invocations landing here
                # (fan-in completers, retries, other jobs' readers are
                # excluded by key qualification) skip the KV read. The
                # write-through below is unchanged — the static schedule
                # has non-local consumers (invoked children / the result
                # waiter) whenever it happens at all.
                yield from self.ccache.deposit_g(
                    self._qkey(current), out, out_nbytes,
                    stats=self.ctx.cache_stats)

            children = dag.children[current]
            # ---- sink: final result --------------------------------------
            if not children:
                t0 = clock.now_ms()
                yield from kv.put_if_absent_g(current, out, nbytes=out_nbytes)
                write_ms = clock.now_ms() - t0
                yield from kv.publish_g(
                    RESULTS_CHANNEL,
                    {"type": "result", "key": current},
                )
                self.ctx.metrics.record(
                    task=current,
                    event="resumed" if resumed else "executed",
                    read_ms=read_ms, compute_ms=compute_ms,
                    write_ms=write_ms, nbytes=out_nbytes,
                    executor=self.executor_id,
                )
                return

            self.ctx.metrics.record(
                task=current,
                event="resumed" if resumed else "executed",
                read_ms=read_ms, compute_ms=compute_ms, write_ms=0.0,
                nbytes=out_nbytes, executor=self.executor_id,
            )

            # ---- fan-out operation (paper §IV-C) -------------------------
            if len(children) == 1:
                prev, current = current, children[0]  # trivial fan-out
                continue

            # Locality-aware become-choice: walk the child whose inputs
            # are most container-resident (by bytes); its siblings are
            # invoked elsewhere. An empty/absent cache scores every
            # child 0 and the tiebreak keeps the schedule order, so the
            # cacheless walk is unchanged bit for bit.
            if self.ccache is not None and len(children) > 1:
                idx = max(
                    range(len(children)),
                    key=lambda i: (self.ccache.resident_bytes(
                        self._qkey(d) for d in dag.deps[children[i]]), -i),
                )
                become = children[idx]
                invoked = children[:idx] + children[idx + 1:]
            else:
                become, *invoked = children
            write_ms = 0.0
            if not self.ctx.inline_fanout_args:
                # Intermediate outputs needed by the new executors go to the
                # KV store; invoked executors receive the keys (paper §IV-C).
                t0 = clock.now_ms()
                yield from kv.put_if_absent_g(current, out, nbytes=out_nbytes)
                write_ms = clock.now_ms() - t0
                seed: dict[str, Any] = {}
            else:
                # Beyond-paper optimization: carry the value inline with the
                # invocation payload (fan-in republish keeps correctness).
                seed = {current: out}
            # Coalescing (optimizer pass): chunk the invoked children so
            # one invocation walks several siblings, shrinking invoker
            # pressure on large fan-outs.
            batch = self.ctx.coalesce_batch
            if batch > 1:
                groups = [tuple(invoked[i:i + batch])
                          for i in range(0, len(invoked), batch)]
            else:
                groups = [(child,) for child in invoked]
            for group in groups:
                # Placement hint: the group's input keys (store-
                # qualified); the invoker biases this invocation toward
                # a warm container whose cache already holds them.
                hints = ()
                if self.ccache is not None:
                    hints = tuple(dict.fromkeys(
                        self._qkey(d)
                        for k in group for d in dag.deps[k]))
                yield from self.ctx.spawn(group, dict(seed), self.schedule,
                                          width=len(groups), parent=current,
                                          hint_keys=hints)
            self.ctx.metrics.record(
                task=current, event="fanout", width=len(children),
                write_ms=write_ms, executor=self.executor_id,
            )
            prev, current = current, become
