"""Sharded KV store with atomic fan-in counters, pub/sub, and a cost model.

Models the paper's intermediate-storage substrate: a Redis cluster
partitioned across shards (paper ran 10 c5.18xlarge shards). Because this
container has no AWS, the *costs* of the serverless environment are
simulated and the *algorithms* are real:

- every op pays a base latency plus size/bandwidth transfer time,
- a shard's transfer lane is held for the duration of a transfer, so
  concurrent large transfers to one shard queue up — this reproduces the
  NIC contention that §V-B measured ("running each KV Store shard on its
  own separate VM resulted in a significant performance improvement") and
  the heavy read/write tail of Fig. 13,
- ``colocate_shards=True`` puts all shards behind one transfer lane
  (the "all shards on the same VM" configuration of §V-B).

Fan-in dependency counters (paper §IV-C) are atomic. Two modes:
- ``paper``: plain atomic increment, exactly the paper's Redis INCR.
- ``edge_set`` (default): the counter is a set of satisfied in-edge ids;
  the "count" is the set size. This makes increments idempotent so that
  Lambda-style automatic retries and speculative duplicate executors
  cannot double-fire a fan-in — a correctness hole in the paper's INCR
  scheme that we close (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import pickle
import queue
import threading
import time
from typing import Any, Iterable


def sizeof(value: Any) -> int:
    """Approximate wire size of a task payload in bytes."""
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(value, (bytes, bytearray)):
        return len(value)
    if isinstance(value, str):
        return len(value.encode())
    if isinstance(value, (int, float, bool, type(None))):
        return 8
    if isinstance(value, (tuple, list)):
        return 16 + sum(sizeof(v) for v in value)
    if isinstance(value, dict):
        return 16 + sum(sizeof(k) + sizeof(v) for k, v in value.items())
    try:
        return len(pickle.dumps(value))
    except Exception:
        return 64


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Latency model of the serverless substrate, in *simulated* ms.

    Defaults follow the paper's measurements where it gives them
    (invoke_ms ~50ms via boto3) and plausible AWS numbers elsewhere.
    ``time_scale`` converts simulated ms to real sleep seconds; 0 disables
    sleeping entirely (used by unit tests, which check protocol
    correctness, not timing).
    """

    invoke_ms: float = 50.0          # Lambda invocation API call (paper §III-C)
    cold_start_ms: float = 250.0     # container cold start (paper §II-A)
    warm_fraction: float = 1.0       # paper warms a pool of Lambdas (§V-A)
    kv_base_ms: float = 0.5          # per-op KV latency
    kv_bandwidth_mbps: float = 600.0 # per-shard transfer lane
    tcp_connect_ms: float = 4.0      # per-Lambda TCP connect (strawman)
    tcp_msg_ms: float = 0.4          # scheduler-side serialized msg handling
    tcp_irq_factor: float = 0.5      # IRQ-flood term: extra msg cost per
                                     # concurrently-open Lambda connection
                                     # (paper §III-C: "IRQ requests which
                                     # flood the strawman case")
    pubsub_msg_ms: float = 0.05      # Redis pub/sub message
    schedule_ship_mbps: float = 600.0  # static-schedule payload transfer
    time_scale: float = 0.0

    def transfer_ms(self, nbytes: int) -> float:
        return nbytes / (self.kv_bandwidth_mbps * 1e6) * 1e3


class Clock:
    """Charges simulated latency (optionally sleeping) and accounts totals."""

    def __init__(self, cost: CostModel):
        self.cost = cost
        self._lock = threading.Lock()
        self.charged_ms = 0.0

    def charge(self, ms: float) -> None:
        if ms <= 0:
            return
        with self._lock:
            self.charged_ms += ms
        if self.cost.time_scale > 0:
            time.sleep(ms * self.cost.time_scale / 1e3)


@dataclasses.dataclass
class KVStats:
    gets: int = 0
    puts: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    incrs: int = 0
    publishes: int = 0

    def snapshot(self) -> dict[str, int]:
        return dataclasses.asdict(self)


class _Shard:
    def __init__(self) -> None:
        self.data: dict[str, Any] = {}
        self.lock = threading.Lock()          # metadata atomicity
        self.lane = threading.Lock()          # transfer lane (NIC contention)


class ShardedKVStore:
    """The KV Store + Storage Manager counter registry."""

    def __init__(
        self,
        n_shards: int = 10,
        cost: CostModel | None = None,
        colocate_shards: bool = False,
        counter_mode: str = "edge_set",
    ):
        if counter_mode not in ("edge_set", "paper"):
            raise ValueError(counter_mode)
        self.cost = cost or CostModel()
        self.clock = Clock(self.cost)
        self.shards = [_Shard() for _ in range(max(1, n_shards))]
        if colocate_shards:
            # all shards share one VM -> one NIC -> one transfer lane
            shared = self.shards[0].lane
            for s in self.shards:
                s.lane = shared
        self.counter_mode = counter_mode
        self._counters: dict[str, set[str] | int] = {}
        self._counter_widths: dict[str, int] = {}
        self._counter_lock = threading.Lock()
        self._channels: dict[str, list[queue.Queue]] = {}
        self._chan_lock = threading.Lock()
        self.stats = KVStats()
        self._stats_lock = threading.Lock()

    # -- placement ---------------------------------------------------------
    def _shard(self, key: str) -> _Shard:
        return self.shards[hash(key) % len(self.shards)]

    def _pay(self, shard: _Shard, nbytes: int) -> None:
        # Base latency is paid outside the lane; transfer holds the lane so
        # concurrent large objects to one shard serialize (NIC model).
        self.clock.charge(self.cost.kv_base_ms)
        t_ms = self.cost.transfer_ms(nbytes)
        if t_ms > 0:
            with shard.lane:
                self.clock.charge(t_ms)

    # -- object store ------------------------------------------------------
    def put(self, key: str, value: Any) -> None:
        shard = self._shard(key)
        nbytes = sizeof(value)
        self._pay(shard, nbytes)
        with shard.lock:
            shard.data[key] = value
        with self._stats_lock:
            self.stats.puts += 1
            self.stats.bytes_written += nbytes

    def put_if_absent(self, key: str, value: Any) -> bool:
        """Idempotent write used by retried/speculative executors."""
        shard = self._shard(key)
        with shard.lock:
            if key in shard.data:
                return False
        nbytes = sizeof(value)
        self._pay(shard, nbytes)
        with shard.lock:
            if key in shard.data:
                return False
            shard.data[key] = value
        with self._stats_lock:
            self.stats.puts += 1
            self.stats.bytes_written += nbytes
        return True

    def get(self, key: str) -> Any:
        shard = self._shard(key)
        with shard.lock:
            if key not in shard.data:
                raise KeyError(key)
            value = shard.data[key]
        self._pay(shard, sizeof(value))
        with self._stats_lock:
            self.stats.gets += 1
            self.stats.bytes_read += sizeof(value)
        return value

    def exists(self, key: str) -> bool:
        shard = self._shard(key)
        with shard.lock:
            return key in shard.data

    def delete(self, key: str) -> None:
        shard = self._shard(key)
        with shard.lock:
            shard.data.pop(key, None)

    # -- fan-in dependency counters (paper §IV-C) ---------------------------
    def register_counter(self, counter_id: str, width: int) -> None:
        with self._counter_lock:
            self._counter_widths[counter_id] = width
            if self.counter_mode == "edge_set":
                self._counters.setdefault(counter_id, set())
            else:
                self._counters.setdefault(counter_id, 0)

    def _record_edge_locked(self, counter_id: str, edge_id: str) -> int:
        """Record a satisfied in-edge; return the new count. Caller must
        hold ``_counter_lock`` (shared by both fan-in protocols so the
        edge_set/INCR semantics can never diverge between them)."""
        cur = self._counters.get(counter_id)
        if cur is None:
            cur = set() if self.counter_mode == "edge_set" else 0
        if self.counter_mode == "edge_set":
            assert isinstance(cur, set)
            cur = cur | {edge_id}
            self._counters[counter_id] = cur
            return len(cur)
        count = int(cur) + 1
        self._counters[counter_id] = count
        return count

    def increment_dependency(self, counter_id: str, edge_id: str) -> int:
        """Atomically record a satisfied in-edge; return the new count.

        ``edge_id`` identifies the in-edge being satisfied. In ``paper``
        mode it is ignored (plain INCR). The caller compares the returned
        count against the fan-in width: equal -> it is the last arriver
        and continues through the fan-in; less -> it stores its outputs
        and stops (nobody ever waits).
        """
        self.clock.charge(self.cost.kv_base_ms)
        with self._counter_lock:
            count = self._record_edge_locked(counter_id, edge_id)
        with self._stats_lock:
            self.stats.incrs += 1
        return count

    def deposit_and_increment(
        self,
        counter_id: str,
        edge_id: str,
        items: "dict[str, Any]",
        expected: "tuple[str, ...]" = (),
    ) -> "tuple[int, list[str]]":
        """Atomic fan-in arrival with delayed I/O (the optimizer's
        clustering pass; Wukong follow-up's locality optimization).

        Records ``edge_id`` on the dependency counter and — unless this
        arrival completes the fan-in — persists ``items`` (the caller's
        locally-held input objects) in the *same* round trip, saving the
        separate ``set`` round trip of the classic publish-then-increment
        protocol. The completing arrival skips the write entirely: its
        objects stay in executor memory and never touch the network.

        ``expected`` lists keys the caller will need if it completes the
        fan-in; the keys among them absent from the store are reported
        back in the same reply (no extra round trip), so a completing
        arrival can detect inputs that exist only in another invocation's
        memory (retried/coalesced executors) and defer.

        Counters must be registered (width known) for the completing
        arrival to be detected; unregistered counters always store, which
        degrades gracefully to the classic protocol. Edge-set mode keeps
        the op idempotent: a retried arrival on a recorded edge re-reads
        the same count, and its stores are if-absent.
        Returns ``(count, missing_expected_keys)``.
        """
        self.clock.charge(self.cost.kv_base_ms)  # one combined round trip
        stored: dict[str, Any] = {}
        missing: list[str] = []
        with self._counter_lock:
            width = self._counter_widths.get(counter_id)
            count = self._record_edge_locked(counter_id, edge_id)
            completing = width is not None and count >= width
            if not completing:
                # Store before the increment becomes visible to the
                # completing arrival (it reads these keys right after).
                for key, value in items.items():
                    shard = self._shard(key)
                    with shard.lock:
                        if key not in shard.data:
                            shard.data[key] = value
                            stored[key] = value
            for key in expected:
                shard = self._shard(key)
                with shard.lock:
                    if key not in shard.data:
                        missing.append(key)
        with self._stats_lock:
            self.stats.incrs += 1
            self.stats.puts += len(stored)
            self.stats.bytes_written += sum(
                sizeof(v) for v in stored.values()
            )
        # Transfer time is charged outside the counter lock: the bytes are
        # already durable; only the simulated clock accounting remains.
        for key, value in stored.items():
            t_ms = self.cost.transfer_ms(sizeof(value))
            if t_ms > 0:
                with self._shard(key).lane:
                    self.clock.charge(t_ms)
        return count, missing

    def counter_value(self, counter_id: str) -> int:
        with self._counter_lock:
            cur = self._counters.get(counter_id, 0)
            return len(cur) if isinstance(cur, set) else int(cur)

    # -- pub/sub (paper §III-B) ---------------------------------------------
    def subscribe(self, channel: str) -> "queue.Queue[Any]":
        q: queue.Queue[Any] = queue.Queue()
        with self._chan_lock:
            self._channels.setdefault(channel, []).append(q)
        return q

    def publish(self, channel: str, message: Any) -> None:
        self.clock.charge(self.cost.pubsub_msg_ms)
        with self._chan_lock:
            subs = list(self._channels.get(channel, ()))
        for q in subs:
            q.put(message)
        with self._stats_lock:
            self.stats.publishes += 1

    # -- bulk --------------------------------------------------------------
    def mget(self, keys: Iterable[str]) -> list[Any]:
        return [self.get(k) for k in keys]

    def reset_stats(self) -> None:
        with self._stats_lock:
            self.stats = KVStats()
