"""Sharded KV store with atomic fan-in counters, pub/sub, and a cost model.

Models the paper's intermediate-storage substrate: a Redis cluster
partitioned across shards (paper ran 10 c5.18xlarge shards). Because this
container has no AWS, the *costs* of the serverless environment are
simulated and the *algorithms* are real:

- every op pays a base latency plus size/bandwidth transfer time, charged
  on the engine clock (repro.core.simclock) — the deterministic virtual
  discrete-event clock by default, the seed real-sleep mode when
  ``CostModel.time_scale > 0``,
- a shard's transfer lane is held for the duration of a transfer, so
  concurrent large transfers to one shard queue up — this reproduces the
  NIC contention that §V-B measured ("running each KV Store shard on its
  own separate VM resulted in a significant performance improvement") and
  the heavy read/write tail of Fig. 13,
- ``colocate_shards=True`` puts all shards behind one transfer lane
  (the "all shards on the same VM" configuration of §V-B).

Data-plane optimizations (beyond the paper, from its follow-ups):

- **Striped large objects** (Wukong follow-up's chunked storage): values
  larger than ``CostModel.stripe_threshold_bytes`` are split into up to
  ``max_stripes`` stripes placed on *distinct* shards and transferred
  over their lanes concurrently, so a large object pays the *max* of the
  stripe lane times instead of the *sum* of one lane's serial transfer.
  A manifest entry under the original key keeps ``get``/``exists``/
  ``put_if_absent``/``delete`` and idempotent retries correct. The
  stripes model the byte extents' placement and transfer cost; the
  Python object itself rides the manifest (the costs are simulated, the
  placement/laning/idempotence algorithms are real). With
  ``colocate_shards=True`` every stripe shares one lane, so striping
  degenerates to the serial transfer — exactly the §V-B NIC story.
- **Batched round trips** (Lambada-style): ``mget`` groups keys by shard
  and pays one ``kv_base_ms`` per shard batch instead of one per key;
  ``register_counters`` registers a whole job's fan-in counters in one
  round trip.

Fan-in dependency counters (paper §IV-C) are atomic. Two modes:
- ``paper``: plain atomic increment, exactly the paper's Redis INCR.
- ``edge_set`` (default): the counter is a set of satisfied in-edge ids;
  the "count" is the set size. This makes increments idempotent so that
  Lambda-style automatic retries and speculative duplicate executors
  cannot double-fire a fan-in — a correctness hole in the paper's INCR
  scheme that we close (see DESIGN.md §2).

Multi-tenancy (the orchestrator substrate): ``namespace(job_id)`` returns
a :class:`KVNamespace` — a per-job view over the shared store that
prefixes every key, counter id, and pub/sub channel with the job id and
keeps its OWN :class:`KVStats`, so N concurrent jobs share the shards,
lanes, and clock (contending for them, which is the point) without
colliding on names or polluting each other's reports. Shard *placement*
ignores the namespace prefix, so a job's data-plane behavior (placement,
lane contention with itself) is independent of which job id it was
assigned — two identical jobs on one substrate report identically.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import random
import threading
import zlib
from typing import Any, Iterable, Mapping

from repro.core.simclock import (
    BaseClock,
    _current_frame,
    clock_for_scale,
    run_effects,
)

# Separator between a namespace (job id) and the user key. Placement
# hashing strips everything up to the first separator, so a namespaced
# key lands on the same shard its bare key would.
NAMESPACE_SEP = "::"


class _Purged:
    """Sentinel delivered to subscribers still blocked on a channel when
    ``drop_namespace`` sweeps it away, so a consumer of a cancelled job
    wakes up and can exit instead of waiting forever on a channel nobody
    can publish to anymore. Compare with ``is PURGED``."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "<PURGED>"


PURGED = _Purged()

# Per-actor stats sink: while a KVNamespace call is on the stack, the
# parent store's counter bumps are mirrored into the view's own KVStats
# (the view can't re-derive byte counts — entry sizes are recorded once
# at put time and not returned by the ops). On the event substrate the
# sink rides on the *frame* (the op suspends and resumes inside the
# scope, and many frames share one driver thread); thread-locals remain
# the fallback for the thread substrates and external callers.
_stats_sink = threading.local()


class _SinkScope:
    """Installs a view as the current actor's stats sink for one parent
    call (frame-scoped under the event substrate, thread-scoped
    otherwise)."""

    __slots__ = ("view", "_prev", "_frame")

    def __init__(self, view: "KVNamespace"):
        self.view = view

    def __enter__(self) -> None:
        frame = _current_frame()
        self._frame = frame
        if frame is not None:
            self._prev = frame.sink
            frame.sink = self.view
        else:
            self._prev = getattr(_stats_sink, "view", None)
            _stats_sink.view = self.view

    def __exit__(self, *exc: Any) -> None:
        if self._frame is not None:
            self._frame.sink = self._prev
        else:
            _stats_sink.view = self._prev


def sizeof(value: Any) -> int:
    """Approximate wire size of a task payload in bytes."""
    nbytes = getattr(value, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(value, (bytes, bytearray)):
        return len(value)
    if isinstance(value, str):
        return len(value.encode())
    if isinstance(value, (int, float, bool, type(None))):
        return 8
    if isinstance(value, (tuple, list)):
        return 16 + sum(sizeof(v) for v in value)
    if isinstance(value, dict):
        return 16 + sum(sizeof(k) + sizeof(v) for k, v in value.items())
    try:
        return len(pickle.dumps(value))
    except Exception:
        return 64


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Latency model of the serverless substrate, in *simulated* ms.

    Defaults follow the paper's measurements where it gives them
    (invoke_ms ~50ms via boto3) and plausible AWS numbers elsewhere.

    ``time_scale`` selects the clock mode (repro.core.simclock): 0 — the
    default — runs on a deterministic virtual discrete-event clock
    (idle simulated time costs zero wall time, runs are bit-identical);
    > 0 keeps the seed real-time mode, really sleeping
    ``ms * time_scale / 1e3`` seconds per charge, for sanity
    cross-checks against the virtual substrates.

    ``substrate`` picks the virtual scheduler when ``time_scale == 0``:
    ``"event"`` (the default; override via ``REPRO_SIM_SUBSTRATE``) is
    the continuation/event-driven engine that scales to million-task
    DAGs; ``"thread"`` is the PR-3 thread-per-actor engine kept as a
    cross-check mode. Both produce bit-identical charges.

    Invocation latency is a seeded *distribution*, not a constant, when
    the jitter/cold-start knobs are set: each invocation ``index`` draws
    a lognormal multiplier on ``invoke_ms`` (``invoke_sigma``) and a
    cold start with probability ``1 - warm_fraction`` adding
    ``cold_start_ms`` — the cost dimension ServerMix argues dominates
    serverless analytics. Draws are keyed on ``(latency_seed, index)``
    so runs are reproducible.
    """

    invoke_ms: float = 50.0          # Lambda invocation API call (paper §III-C)
    cold_start_ms: float = 250.0     # container cold start (paper §II-A)
    warm_fraction: float = 1.0       # paper warms a pool of Lambdas (§V-A)
    invoke_sigma: float = 0.0        # lognormal sigma on invoke_ms (0 = const)
    latency_seed: int = 0            # seed for the invocation-latency draws
    kv_base_ms: float = 0.5          # per-op KV latency
    kv_bandwidth_mbps: float = 600.0 # per-shard transfer lane
    tcp_connect_ms: float = 4.0      # per-Lambda TCP connect (strawman)
    tcp_msg_ms: float = 0.4          # scheduler-side serialized msg handling
    tcp_irq_factor: float = 0.5      # IRQ-flood term: extra msg cost per
                                     # concurrently-open Lambda connection
                                     # (paper §III-C: "IRQ requests which
                                     # flood the strawman case")
    pubsub_msg_ms: float = 0.05      # Redis pub/sub message
    schedule_ship_mbps: float = 600.0  # static-schedule payload transfer
    # Striping (Wukong follow-up's chunked large-object storage): values
    # larger than stripe_threshold_bytes split into <= max_stripes stripes
    # on distinct shards. <= 0 disables striping entirely.
    stripe_threshold_bytes: int = 1 << 20
    max_stripes: int = 8
    time_scale: float = 0.0
    substrate: str = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_SIM_SUBSTRATE",
                                               "event"))

    def transfer_ms(self, nbytes: int) -> float:
        return nbytes / (self.kv_bandwidth_mbps * 1e6) * 1e3

    def invoke_draw(self, index: int) -> "tuple[float, bool]":
        """(latency_ms, was_cold) for invocation number ``index``.

        Deterministic per (latency_seed, index) via crc32, the same
        process-stable hashing the fault injector and shard placement
        use (tuple/str hash() is a PYTHONHASHSEED lottery)."""
        ms = self.invoke_ms
        if self.invoke_sigma <= 0 and self.warm_fraction >= 1.0:
            return ms, False
        token = f"{self.latency_seed}|invoke|{index}".encode()
        rng = random.Random(zlib.crc32(token))
        if self.invoke_sigma > 0:
            ms *= rng.lognormvariate(0.0, self.invoke_sigma)
        cold = rng.random() >= self.warm_fraction
        if cold:
            ms += self.cold_start_ms
        return ms, cold

    def invoke_jitter_ms(self, index: int) -> float:
        """Jitter-only invocation latency for invocation ``index`` —
        the ``invoke_draw`` lognormal component WITHOUT the stochastic
        cold-start term. The stateful platform model (repro.platform)
        uses this: whether invocation ``index`` is cold is decided by
        the warm-container pool's state, not a coin flip, and the
        cold-start delay is added by the platform when the pool misses.
        Same (latency_seed, index) keying as ``invoke_draw`` so the
        jitter component matches between the two modes."""
        ms = self.invoke_ms
        if self.invoke_sigma <= 0:
            return ms
        token = f"{self.latency_seed}|invoke|{index}".encode()
        rng = random.Random(zlib.crc32(token))
        return ms * rng.lognormvariate(0.0, self.invoke_sigma)


@dataclasses.dataclass
class KVStats:
    gets: int = 0
    puts: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    incrs: int = 0
    publishes: int = 0
    striped_puts: int = 0
    striped_gets: int = 0
    mget_batches: int = 0
    journal_appends: int = 0
    journal_scans: int = 0

    def snapshot(self) -> dict[str, int]:
        return dataclasses.asdict(self)


class _Entry:
    """A stored object plus its wire size, recorded once at put time so
    reads never re-derive it (the recursive ``sizeof`` walk is a host-side
    hot path on deep containers)."""

    __slots__ = ("value", "nbytes")

    def __init__(self, value: Any, nbytes: int):
        self.value = value
        self.nbytes = nbytes


class _StripeManifest:
    """Manifest for a striped object: the home-shard entry under the
    original key. Records the stripe layout so every API (get / exists /
    put_if_absent / delete / retries) resolves the object through one
    stable key."""

    __slots__ = ("value", "nbytes", "n_stripes")

    def __init__(self, value: Any, nbytes: int, n_stripes: int):
        self.value = value
        self.nbytes = nbytes
        self.n_stripes = n_stripes


class _Stripe:
    """One stripe's byte extent (placement + transfer-cost record)."""

    __slots__ = ("nbytes",)

    def __init__(self, nbytes: int):
        self.nbytes = nbytes


def _stripe_key(key: str, i: int) -> str:
    return f"{key}/__stripe__/{i}"


class _Shard:
    def __init__(self, lane: Any) -> None:
        self.data: dict[str, Any] = {}
        self.lock = threading.Lock()          # metadata atomicity
        # Transfer lane (NIC contention): a clock-aware lock, so an actor
        # holding the lane across a simulated transfer cooperates with
        # the virtual clock instead of wedging it.
        self.lane = lane


class ShardedKVStore:
    """The KV Store + Storage Manager counter registry."""

    def __init__(
        self,
        n_shards: int = 10,
        cost: CostModel | None = None,
        colocate_shards: bool = False,
        counter_mode: str = "edge_set",
        clock: BaseClock | None = None,
    ):
        if counter_mode not in ("edge_set", "paper"):
            raise ValueError(counter_mode)
        self.cost = cost or CostModel()
        self.clock: BaseClock = clock or clock_for_scale(
            self.cost.time_scale, getattr(self.cost, "substrate", "event"))
        if colocate_shards:
            # all shards share one VM -> one NIC -> one transfer lane
            shared = self.clock.lock()
            self.shards = [_Shard(shared) for _ in range(max(1, n_shards))]
        else:
            self.shards = [_Shard(self.clock.lock())
                           for _ in range(max(1, n_shards))]
        self.counter_mode = counter_mode
        self._counters: dict[str, set[str] | int] = {}
        self._counter_widths: dict[str, int] = {}
        self._counter_lock = threading.Lock()
        self._channels: dict[str, list[Any]] = {}
        self._chan_lock = threading.Lock()
        # Namespaces handed out by ``namespace()``. Placement hashing
        # only strips prefixes registered here, so ordinary user keys
        # that happen to contain the separator keep their placement.
        self._namespaces: set[str] = set()
        self._ns_lock = threading.Lock()
        # Append-only journals (control-plane event logs), keyed by
        # journal id. Entries are (payload, nbytes) in append order.
        # Kept OUTSIDE shard.data: a journal is a log, not an object —
        # it has no get/exists/delete surface and must survive the
        # object-store observables (shard byte counts, purge sweeps
        # measure *data-plane* state).
        self._journals: dict[str, list[tuple[Any, int]]] = {}
        self._journal_lock = threading.Lock()
        # Called with the dropped prefix after ``drop_namespace`` sweeps
        # the store, so caches holding store-qualified keys (the
        # platform's container caches, repro.core.cache) reclaim a
        # finished job's entries in the same breath as its KV objects.
        self._purge_listeners: list[Any] = []
        # Called host-side with ``(key, nbytes)`` after every durable
        # object write (put / put_if_absent / deposit stores), with the
        # store-qualified key. This is the trigger bus's kv_write event
        # source: listeners observe, they do not charge — the written
        # bytes already paid their round trip.
        self._write_listeners: list[Any] = []
        self.stats = KVStats()
        self._stats_lock = threading.Lock()

    # -- stats -------------------------------------------------------------
    def _bump(self, **fields: int) -> None:
        """Add counter deltas to the store stats and, when the call came
        through a :class:`KVNamespace`, to that view's stats too."""
        with self._stats_lock:
            st = self.stats
            for name, delta in fields.items():
                setattr(st, name, getattr(st, name) + delta)
        frame = _current_frame()
        if frame is not None:
            view = frame.sink
        else:
            view = getattr(_stats_sink, "view", None)
        if view is not None:
            view._bump(**fields)

    # -- placement ---------------------------------------------------------
    def _placement_key(self, key: str) -> str:
        """The key placement hashes on: a REGISTERED namespace prefix is
        stripped, so a job's placement (and therefore its self-contention
        profile) must not depend on its job id. Only registered prefixes
        count — an ordinary user key that happens to contain the
        separator keeps its full-key placement."""
        head, sep, rest = key.partition(NAMESPACE_SEP)
        if sep and head in self._namespaces:
            return rest
        return key

    def _shard_index(self, key: str) -> int:
        # Stable across processes (unlike hash(), which PYTHONHASHSEED
        # randomizes), so shard placement — and therefore lane contention
        # and benchmark numbers — is reproducible run to run.
        return zlib.crc32(
            self._placement_key(key).encode("utf-8")) % len(self.shards)

    def _shard(self, key: str) -> _Shard:
        return self.shards[self._shard_index(key)]

    def stripes_for(self, nbytes: int) -> int:
        """Number of stripes a value of ``nbytes`` would be split into
        (1 = stored whole)."""
        thr = self.cost.stripe_threshold_bytes
        if thr <= 0 or nbytes <= thr or len(self.shards) < 2:
            return 1
        return min(
            self.cost.max_stripes,
            len(self.shards),
            -(-nbytes // thr),  # ceil div
        )

    def _stripe_layout(self, key: str, nbytes: int, n_stripes: int):
        """(shard_index, stripe_key, stripe_bytes) per stripe; stripes go
        on consecutive (distinct) shards starting at the home shard."""
        base = self._shard_index(key)
        n = len(self.shards)
        per, rem = divmod(nbytes, n_stripes)
        return [
            ((base + i) % n, _stripe_key(key, i), per + (1 if i < rem else 0))
            for i in range(n_stripes)
        ]

    def _pay_g(self, shard: _Shard, nbytes: int) -> Any:
        # Base latency is paid outside the lane; transfer holds the lane so
        # concurrent large objects to one shard serialize (NIC model).
        yield ("charge", self.cost.kv_base_ms)
        t_ms = self.cost.transfer_ms(nbytes)
        if t_ms > 0:
            yield ("acquire", shard.lane)
            try:
                yield ("charge", t_ms)
            finally:
                shard.lane.release()

    def _charge_striped_transfer_g(self, layout) -> Any:
        """Charge a striped transfer: stripes move over their lanes
        concurrently, so the op is billed the slowest *lane's* total (one
        stripe per lane when shards are distinct; the full serial sum when
        ``colocate_shards`` folds every lane into one).

        Only the home-shard lane is *held* for that duration: holding all
        stripe lanes would let one striped op block every other (with 8
        stripes over 10 shards, any two ops share a lane — a convoy that
        erases the wall-clock win striping exists to provide). The home
        lane still serializes same-object retries and same-shard
        traffic; remote stripe lanes are modeled as load-spread, which is
        exactly the follow-up paper's argument for chunking across
        shards. Under ``colocate_shards`` every lane IS the home lane, so
        the full serial occupancy is preserved."""
        lane_ms: dict[int, float] = {}
        for shard_idx, _, nbytes in layout:
            lid = id(self.shards[shard_idx].lane)
            lane_ms[lid] = lane_ms.get(lid, 0.0) + self.cost.transfer_ms(
                nbytes)
        wait_ms = max(lane_ms.values(), default=0.0)
        if wait_ms <= 0:
            return
        lane = self.shards[layout[0][0]].lane
        yield ("acquire", lane)
        try:
            yield ("charge", wait_ms)
        finally:
            lane.release()

    # -- object store ------------------------------------------------------
    def _drop_stripes(self, key: str, n_stripes: int, first: int = 0) -> None:
        """Remove stripe records ``first..n_stripes-1`` of ``key``."""
        base = self._shard_index(key)
        n = len(self.shards)
        for i in range(first, n_stripes):
            s = self.shards[(base + i) % n]
            with s.lock:
                s.data.pop(_stripe_key(key, i), None)

    def _write_stripes_g(self, key: str, value: Any, nbytes: int,
                         n_stripes: int, if_absent: bool) -> Any:
        """Write stripes + manifest (manifest last: its insertion is the
        linearization point, so readers never observe a torn object).
        Returns False when ``if_absent`` and the manifest already existed
        — concurrent retried writers produce byte-identical stripes, so
        the loser's stripe writes are harmless no-ops. A plain overwrite
        of a previously-striped value drops the old stripes its new
        layout does not cover."""
        layout = self._stripe_layout(key, nbytes, n_stripes)
        yield ("charge", self.cost.kv_base_ms)
        yield from self._charge_striped_transfer_g(layout)
        for shard_idx, skey, snbytes in layout:
            shard = self.shards[shard_idx]
            with shard.lock:
                if not if_absent or skey not in shard.data:
                    shard.data[skey] = _Stripe(snbytes)
        home = self._shard(key)
        manifest = _StripeManifest(value, nbytes, n_stripes)
        with home.lock:
            if if_absent and key in home.data:
                return False
            old = home.data.get(key)
            home.data[key] = manifest
        if isinstance(old, _StripeManifest) and old.n_stripes > n_stripes:
            self._drop_stripes(key, old.n_stripes, first=n_stripes)
        return True

    def put_g(self, key: str, value: Any, nbytes: int | None = None) -> Any:
        """Store ``value``. ``nbytes`` is an optional caller-known size
        hint (skips the recursive ``sizeof`` walk)."""
        if nbytes is None:
            nbytes = sizeof(value)
        n_stripes = self.stripes_for(nbytes)
        if n_stripes > 1:
            yield from self._write_stripes_g(key, value, nbytes, n_stripes,
                                             if_absent=False)
            self._bump(puts=1, striped_puts=1, bytes_written=nbytes)
            self._notify_write(key, nbytes)
            return
        shard = self._shard(key)
        yield from self._pay_g(shard, nbytes)
        with shard.lock:
            old = shard.data.get(key)
            shard.data[key] = _Entry(value, nbytes)
        if isinstance(old, _StripeManifest):
            # the overwritten value was striped: reclaim its stripes
            self._drop_stripes(key, old.n_stripes)
        self._bump(puts=1, bytes_written=nbytes)
        self._notify_write(key, nbytes)

    def put(self, key: str, value: Any, nbytes: int | None = None) -> None:
        run_effects(self.clock, self.put_g(key, value, nbytes))

    def put_if_absent_g(self, key: str, value: Any,
                        nbytes: int | None = None) -> Any:
        """Idempotent write used by retried/speculative executors."""
        shard = self._shard(key)
        with shard.lock:
            if key in shard.data:
                return False
        if nbytes is None:
            nbytes = sizeof(value)
        n_stripes = self.stripes_for(nbytes)
        if n_stripes > 1:
            ok = yield from self._write_stripes_g(key, value, nbytes,
                                                  n_stripes, if_absent=True)
            if not ok:
                return False
            self._bump(puts=1, striped_puts=1, bytes_written=nbytes)
            self._notify_write(key, nbytes)
            return True
        yield from self._pay_g(shard, nbytes)
        with shard.lock:
            if key in shard.data:
                return False
            shard.data[key] = _Entry(value, nbytes)
        self._bump(puts=1, bytes_written=nbytes)
        self._notify_write(key, nbytes)
        return True

    def put_if_absent(self, key: str, value: Any,
                      nbytes: int | None = None) -> bool:
        return run_effects(self.clock,
                           self.put_if_absent_g(key, value, nbytes))

    def get_g(self, key: str) -> Any:
        shard = self._shard(key)
        with shard.lock:
            if key not in shard.data:
                raise KeyError(key)
            entry = shard.data[key]
        if isinstance(entry, _StripeManifest):
            layout = self._stripe_layout(key, entry.nbytes, entry.n_stripes)
            yield ("charge", self.cost.kv_base_ms)
            yield from self._charge_striped_transfer_g(layout)
            self._bump(gets=1, striped_gets=1, bytes_read=entry.nbytes)
            return entry.value
        # Size was recorded once at put time; reads never re-derive it.
        yield from self._pay_g(shard, entry.nbytes)
        self._bump(gets=1, bytes_read=entry.nbytes)
        return entry.value

    def get(self, key: str) -> Any:
        return run_effects(self.clock, self.get_g(key))

    def exists(self, key: str) -> bool:
        shard = self._shard(key)
        with shard.lock:
            return key in shard.data

    def delete(self, key: str) -> None:
        shard = self._shard(key)
        with shard.lock:
            entry = shard.data.pop(key, None)
        if isinstance(entry, _StripeManifest):
            self._drop_stripes(key, entry.n_stripes)

    # -- fan-in dependency counters (paper §IV-C) ---------------------------
    def register_counter_g(self, counter_id: str, width: int) -> Any:
        yield ("charge", self.cost.kv_base_ms)
        with self._counter_lock:
            self._register_locked(counter_id, width)

    def register_counter(self, counter_id: str, width: int) -> None:
        run_effects(self.clock, self.register_counter_g(counter_id, width))

    def register_counters_g(self, widths: Mapping[str, int]) -> Any:
        """Batched registration: the Storage Manager registers a whole
        job's fan-in counters in ONE round trip at workflow start
        (Lambada-style batching of many small storage requests). An empty
        registration sends nothing and costs nothing."""
        if not widths:
            return
        yield ("charge", self.cost.kv_base_ms)
        with self._counter_lock:
            for counter_id, width in widths.items():
                self._register_locked(counter_id, width)

    def register_counters(self, widths: Mapping[str, int]) -> None:
        run_effects(self.clock, self.register_counters_g(widths))

    def _register_locked(self, counter_id: str, width: int) -> None:
        self._counter_widths[counter_id] = width
        if self.counter_mode == "edge_set":
            self._counters.setdefault(counter_id, set())
        else:
            self._counters.setdefault(counter_id, 0)

    def _record_edge_locked(self, counter_id: str, edge_id: str) -> int:
        """Record a satisfied in-edge; return the new count. Caller must
        hold ``_counter_lock`` (shared by both fan-in protocols so the
        edge_set/INCR semantics can never diverge between them)."""
        cur = self._counters.get(counter_id)
        if cur is None:
            cur = set() if self.counter_mode == "edge_set" else 0
        if self.counter_mode == "edge_set":
            assert isinstance(cur, set)
            cur = cur | {edge_id}
            self._counters[counter_id] = cur
            return len(cur)
        count = int(cur) + 1
        self._counters[counter_id] = count
        return count

    def increment_dependency_g(self, counter_id: str, edge_id: str) -> Any:
        """Atomically record a satisfied in-edge; return the new count.

        ``edge_id`` identifies the in-edge being satisfied. In ``paper``
        mode it is ignored (plain INCR). The caller compares the returned
        count against the fan-in width: equal -> it is the last arriver
        and continues through the fan-in; less -> it stores its outputs
        and stops (nobody ever waits).
        """
        yield ("charge", self.cost.kv_base_ms)
        with self._counter_lock:
            count = self._record_edge_locked(counter_id, edge_id)
        self._bump(incrs=1)
        return count

    def increment_dependency(self, counter_id: str, edge_id: str) -> int:
        return run_effects(
            self.clock, self.increment_dependency_g(counter_id, edge_id))

    def deposit_and_increment_g(
        self,
        counter_id: str,
        edge_id: str,
        items: "dict[str, Any]",
        expected: "tuple[str, ...]" = (),
    ) -> Any:
        """Atomic fan-in arrival with delayed I/O (the optimizer's
        clustering pass; Wukong follow-up's locality optimization).

        Records ``edge_id`` on the dependency counter and — unless this
        arrival completes the fan-in — persists ``items`` (the caller's
        locally-held input objects) in the *same* round trip, saving the
        separate ``set`` round trip of the classic publish-then-increment
        protocol. The completing arrival skips the write entirely: its
        objects stay in executor memory and never touch the network.
        Items above the striping threshold are persisted striped, same as
        ``put``.

        ``expected`` lists keys the caller will need if it completes the
        fan-in; the keys among them absent from the store are reported
        back in the same reply (no extra round trip), so a completing
        arrival can detect inputs that exist only in another invocation's
        memory (retried/coalesced executors) and defer.

        Counters must be registered (width known) for the completing
        arrival to be detected; unregistered counters always store, which
        degrades gracefully to the classic protocol. Edge-set mode keeps
        the op idempotent: a retried arrival on a recorded edge re-reads
        the same count, and its stores are if-absent.
        Returns ``(count, missing_expected_keys)``.
        """
        yield ("charge", self.cost.kv_base_ms)  # one combined round trip
        # Sizes are derived BEFORE the counter lock: the recursive sizeof
        # walk of every item must not serialize the whole job's fan-in
        # protocol (every arrival in the job takes this lock).
        sized = {key: sizeof(value) for key, value in items.items()}
        stored: list[tuple[str, int, int]] = []  # key, nbytes, n_stripes
        missing: list[str] = []
        with self._counter_lock:
            width = self._counter_widths.get(counter_id)
            count = self._record_edge_locked(counter_id, edge_id)
            completing = width is not None and count >= width
            if not completing:
                # Store before the increment becomes visible to the
                # completing arrival (it reads these keys right after).
                for key, value in items.items():
                    home = self._shard(key)
                    with home.lock:
                        if key in home.data:
                            continue
                    nbytes = sized[key]
                    n_stripes = self.stripes_for(nbytes)
                    if n_stripes > 1:
                        layout = self._stripe_layout(key, nbytes, n_stripes)
                        for shard_idx, skey, snb in layout:
                            s = self.shards[shard_idx]
                            with s.lock:
                                s.data.setdefault(skey, _Stripe(snb))
                        with home.lock:
                            if key in home.data:
                                continue
                            home.data[key] = _StripeManifest(
                                value, nbytes, n_stripes)
                    else:
                        with home.lock:
                            if key in home.data:
                                continue
                            home.data[key] = _Entry(value, nbytes)
                    stored.append((key, nbytes, n_stripes))
            for key in expected:
                shard = self._shard(key)
                with shard.lock:
                    if key not in shard.data:
                        missing.append(key)
        self._bump(
            incrs=1,
            puts=len(stored),
            striped_puts=sum(1 for _, _, n in stored if n > 1),
            bytes_written=sum(nb for _, nb, _ in stored),
        )
        for key, nbytes, _ in stored:
            self._notify_write(key, nbytes)
        # Transfer time is charged outside the counter lock: the bytes are
        # already durable; only the simulated clock accounting remains.
        for key, nbytes, n_stripes in stored:
            if n_stripes > 1:
                yield from self._charge_striped_transfer_g(
                    self._stripe_layout(key, nbytes, n_stripes))
                continue
            t_ms = self.cost.transfer_ms(nbytes)
            if t_ms > 0:
                lane = self._shard(key).lane
                yield ("acquire", lane)
                try:
                    yield ("charge", t_ms)
                finally:
                    lane.release()
        return count, missing

    def deposit_and_increment(
        self,
        counter_id: str,
        edge_id: str,
        items: "dict[str, Any]",
        expected: "tuple[str, ...]" = (),
    ) -> "tuple[int, list[str]]":
        return run_effects(self.clock, self.deposit_and_increment_g(
            counter_id, edge_id, items, expected))

    def counter_value(self, counter_id: str) -> int:
        with self._counter_lock:
            cur = self._counters.get(counter_id, 0)
            return len(cur) if isinstance(cur, set) else int(cur)

    def rebind_counter(self, counter_id: str, width: int) -> None:
        """Host-side (uncharged) reset of a counter to a new width with
        no recorded edges. Used when a dynamic-DAG expansion rebinds a
        task key to the tail of its expansion subgraph: the key's fan-in
        is now the subgraph's, and the edges satisfied under the OLD
        binding must not count toward it. Uncharged by design — the
        batched ``register_counters_g`` round trip at job start already
        paid for registration, and counter ids never affect per-op
        charges, so charge parity with a statically pre-expanded graph
        is preserved (see repro.core.dag.DynamicDAG)."""
        with self._counter_lock:
            self._counter_widths[counter_id] = width
            if self.counter_mode == "edge_set":
                self._counters[counter_id] = set()
            else:
                self._counters[counter_id] = 0

    # -- pub/sub (paper §III-B) ---------------------------------------------
    def subscribe(self, channel: str) -> Any:
        """Returns a ``queue.Queue``-compatible subscription (clock-aware
        in virtual mode, so blocked subscribers never hold back virtual
        time). Callers MUST :meth:`unsubscribe` the returned queue when
        done — on a substrate that outlives one job, an abandoned
        subscription is a leak: it accumulates in ``_channels`` forever
        and every later ``publish`` still fans out to it."""
        q = self.clock.queue()
        with self._chan_lock:
            self._channels.setdefault(channel, []).append(q)
        return q

    def unsubscribe(self, channel: str, q: Any) -> None:
        """Release a subscription returned by :meth:`subscribe`. The
        channel entry is dropped once its last subscriber leaves, so a
        torn-down job leaves ``_channels`` exactly as it found it.
        Idempotent: unsubscribing twice (or a queue that was never
        subscribed) is a no-op."""
        with self._chan_lock:
            subs = self._channels.get(channel)
            if subs is None:
                return
            try:
                subs.remove(q)
            except ValueError:
                return
            if not subs:
                del self._channels[channel]

    def subscriber_count(self, channel: str | None = None,
                         prefix: str = "") -> int:
        """Live subscriptions on ``channel`` (channels starting with
        ``prefix`` when None; every channel by default) — the
        leak-regression observable for teardown tests."""
        with self._chan_lock:
            if channel is not None:
                return len(self._channels.get(channel, ()))
            return sum(len(subs) for ch, subs in self._channels.items()
                       if ch.startswith(prefix))

    def publish_g(self, channel: str, message: Any) -> Any:
        yield ("charge", self.cost.pubsub_msg_ms)
        with self._chan_lock:
            subs = list(self._channels.get(channel, ()))
        for q in subs:
            q.put(message)
        self._bump(publishes=1)

    def publish(self, channel: str, message: Any) -> None:
        run_effects(self.clock, self.publish_g(channel, message))

    # -- journals ----------------------------------------------------------
    def journal_append_g(self, journal: str, entry: Any,
                         nbytes: int | None = None) -> Any:
        """Append ``entry`` to the named event journal. Charged like a
        small put to the journal's home shard (base round trip + lane
        transfer), because durability is not free — the control plane
        pays the same store it shares with the data plane. Returns the
        entry's sequence number (0-based)."""
        if nbytes is None:
            nbytes = sizeof(entry)
        yield from self._pay_g(self._shard(journal), nbytes)
        with self._journal_lock:
            log = self._journals.setdefault(journal, [])
            seq = len(log)
            log.append((entry, nbytes))
        self._bump(journal_appends=1, bytes_written=nbytes)
        return seq

    def journal_append(self, journal: str, entry: Any,
                       nbytes: int | None = None) -> int:
        return run_effects(self.clock,
                           self.journal_append_g(journal, entry, nbytes))

    def journal_scan_g(self, journal: str) -> Any:
        """Read the full journal in append order. Charged one base round
        trip plus the transfer of every recorded entry — replay cost
        grows with journal length, which is exactly the recovery-time
        observable fig17 sweeps. Missing journal reads as empty (a fresh
        control plane has nothing to replay)."""
        with self._journal_lock:
            log = list(self._journals.get(journal, ()))
        total = sum(nb for _, nb in log)
        yield from self._pay_g(self._shard(journal), total)
        self._bump(journal_scans=1, bytes_read=total)
        return [entry for entry, _ in log]

    def journal_scan(self, journal: str) -> list[Any]:
        return run_effects(self.clock, self.journal_scan_g(journal))

    def journal_len(self, journal: str) -> int:
        """Host-side (uncharged) journal length — an observability probe,
        not a simulated op."""
        with self._journal_lock:
            return len(self._journals.get(journal, ()))

    # -- bulk --------------------------------------------------------------
    def mget_g(self, keys: Iterable[str]) -> Any:
        """Pipelined multi-get: keys are grouped by shard and each shard
        batch pays ONE ``kv_base_ms`` round trip (Lambada-style batching
        of small requests); transfer time is still charged per lane.
        Returns values in input order."""
        keys = list(keys)
        by_shard: dict[int, list[str]] = {}
        queued: set[str] = set()
        for k in keys:
            if k not in queued:
                queued.add(k)
                by_shard.setdefault(self._shard_index(k), []).append(k)
        entries: dict[str, Any] = {}
        striped: list[tuple[str, Any]] = []
        total_bytes = 0
        n_striped = 0
        for idx in sorted(by_shard):
            shard = self.shards[idx]
            yield ("charge", self.cost.kv_base_ms)  # one RT per shard batch
            with shard.lock:
                for k in by_shard[idx]:
                    if k not in shard.data:
                        raise KeyError(k)
                    entries[k] = shard.data[k]
            batch_bytes = 0
            for k in by_shard[idx]:
                e = entries[k]
                if isinstance(e, _StripeManifest):
                    striped.append((k, e))
                    n_striped += 1
                else:
                    batch_bytes += e.nbytes
                total_bytes += e.nbytes
            t_ms = self.cost.transfer_ms(batch_bytes)
            if t_ms > 0:
                yield ("acquire", shard.lane)
                try:
                    yield ("charge", t_ms)
                finally:
                    shard.lane.release()
        for k, manifest in striped:
            yield from self._charge_striped_transfer_g(
                self._stripe_layout(k, manifest.nbytes, manifest.n_stripes))
        self._bump(gets=len(queued), striped_gets=n_striped,
                   mget_batches=len(by_shard), bytes_read=total_bytes)
        return [entries[k].value for k in keys]

    def mget(self, keys: Iterable[str]) -> list[Any]:
        return run_effects(self.clock, self.mget_g(keys))

    def reset_stats(self) -> None:
        with self._stats_lock:
            self.stats = KVStats()

    def qualified_key(self, key: str) -> str:
        """The store-global form of ``key`` as seen through this view —
        the identity here; ``KVNamespace`` prefixes. Container caches
        key on this, so bare keys of different jobs never collide."""
        return key

    # -- write notifications (trigger bus event source) ---------------------
    def add_write_listener(self, fn: Any) -> None:
        """Register ``fn(key, nbytes)`` to run host-side after every
        durable object write, with the store-qualified key. Idempotent.
        Listeners must be cheap and must not perform charged KV ops —
        they run inside the writer's op, after its charges."""
        if fn not in self._write_listeners:
            self._write_listeners.append(fn)

    def remove_write_listener(self, fn: Any) -> None:
        """Deregister a write listener (no-op when absent)."""
        try:
            self._write_listeners.remove(fn)
        except ValueError:
            pass

    def _notify_write(self, key: str, nbytes: int) -> None:
        for fn in tuple(self._write_listeners):
            fn(key, nbytes)

    # -- multi-tenancy ------------------------------------------------------
    def add_purge_listener(self, fn: Any) -> None:
        """Register ``fn(prefix)`` to run after ``drop_namespace``
        removes a namespace's objects (idempotent: re-registering the
        same callable is a no-op)."""
        if fn not in self._purge_listeners:
            self._purge_listeners.append(fn)

    def namespace(self, name: str) -> "KVNamespace":
        """A per-job view of this store: keys, counter ids, and pub/sub
        channels are prefixed with ``name`` and the view keeps its own
        :class:`KVStats`. Shards, transfer lanes, and the clock are
        shared — which is exactly how concurrent jobs contend. The name
        is registered so placement hashing can strip it (and ONLY
        registered prefixes)."""
        view = KVNamespace(self, name)
        with self._ns_lock:
            self._namespaces.add(name)
        return view

    def drop_namespace(self, name: str) -> int:
        """Host-side reclamation of a finished job's namespaced state:
        every object (incl. stripe records), fan-in counter, and channel
        under ``name`` is removed; returns the number of objects
        dropped. On a substrate that outlives jobs this is what keeps
        store memory O(concurrent jobs) instead of O(total traffic) —
        the provider reclaiming a job's intermediates, so it charges
        nothing on the clock. A straggling executor of the dropped job
        may re-create a few entries afterwards (its writes are
        if-absent); the stop signal bounds that residue to the job's
        in-flight work."""
        prefix = name + NAMESPACE_SEP
        removed = 0
        for shard in self.shards:
            with shard.lock:
                doomed = [k for k in shard.data if k.startswith(prefix)]
                for k in doomed:
                    del shard.data[k]
                removed += len(doomed)
        with self._counter_lock:
            for cid in [c for c in self._counters if c.startswith(prefix)]:
                del self._counters[cid]
            for cid in [c for c in self._counter_widths
                        if c.startswith(prefix)]:
                del self._counter_widths[cid]
        with self._chan_lock:
            for ch in [c for c in self._channels if c.startswith(prefix)]:
                # Release still-subscribed queues, not just the channel
                # entry: a consumer blocked on a dropped channel would
                # otherwise wait forever (nobody can publish to it again)
                # and its subscription would read as a leak. The PURGED
                # sentinel wakes it so it can exit and the subscriber
                # count under the dropped prefix really ends at 0.
                for q in self._channels[ch]:
                    q.put(PURGED)
                del self._channels[ch]
        with self._journal_lock:
            for j in [j for j in self._journals if j.startswith(prefix)]:
                del self._journals[j]
        # Same reclamation, one layer out: container-resident cache
        # entries of the dropped job (keyed store-qualified) must go
        # too, or a recycled warm container could serve a stale object
        # to a later job reusing the bare key.
        for fn in tuple(self._purge_listeners):
            fn(prefix)
        return removed


class KVNamespace:
    """A job-scoped view over a shared :class:`ShardedKVStore`.

    Engine-compatible: exposes the same op surface the executors and
    schedulers use, rewriting every key / counter id / channel to
    ``"<name>::<key>"`` before delegating, and keeping its OWN stats so
    a JobReport built from a shared store never includes another job's
    traffic. All *costs* (clock charges, lane occupancy) hit the shared
    substrate — the view renames, it does not isolate performance.
    """

    def __init__(self, parent: ShardedKVStore, name: str):
        if NAMESPACE_SEP in name:
            raise ValueError(f"namespace may not contain {NAMESPACE_SEP!r}")
        self.parent = parent
        self.name = name
        self._prefix = name + NAMESPACE_SEP
        self.cost = parent.cost
        self.clock = parent.clock
        self.counter_mode = parent.counter_mode
        self.stats = KVStats()
        self._stats_lock = threading.Lock()

    def _k(self, key: str) -> str:
        return self._prefix + key

    def qualified_key(self, key: str) -> str:
        """Store-global key form (see ``ShardedKVStore.qualified_key``);
        container caches use it so jobs never collide on bare keys."""
        return self._k(key)

    def _bump(self, **fields: int) -> None:
        with self._stats_lock:
            st = self.stats
            for name, delta in fields.items():
                setattr(st, name, getattr(st, name) + delta)

    # -- object store -------------------------------------------------------
    def put_g(self, key: str, value: Any, nbytes: int | None = None) -> Any:
        with _SinkScope(self):
            yield from self.parent.put_g(self._k(key), value, nbytes)

    def put(self, key: str, value: Any, nbytes: int | None = None) -> None:
        run_effects(self.clock, self.put_g(key, value, nbytes))

    def put_if_absent_g(self, key: str, value: Any,
                        nbytes: int | None = None) -> Any:
        with _SinkScope(self):
            return (yield from self.parent.put_if_absent_g(
                self._k(key), value, nbytes))

    def put_if_absent(self, key: str, value: Any,
                      nbytes: int | None = None) -> bool:
        return run_effects(self.clock,
                           self.put_if_absent_g(key, value, nbytes))

    def get_g(self, key: str) -> Any:
        with _SinkScope(self):
            try:
                return (yield from self.parent.get_g(self._k(key)))
            except KeyError:
                raise KeyError(key) from None

    def get(self, key: str) -> Any:
        return run_effects(self.clock, self.get_g(key))

    def exists(self, key: str) -> bool:
        return self.parent.exists(self._k(key))

    def delete(self, key: str) -> None:
        self.parent.delete(self._k(key))

    def mget_g(self, keys: Iterable[str]) -> Any:
        with _SinkScope(self):
            return (yield from self.parent.mget_g(
                [self._k(k) for k in keys]))

    def mget(self, keys: Iterable[str]) -> list[Any]:
        return run_effects(self.clock, self.mget_g(keys))

    def stripes_for(self, nbytes: int) -> int:
        return self.parent.stripes_for(nbytes)

    # -- fan-in counters ----------------------------------------------------
    def register_counter_g(self, counter_id: str, width: int) -> Any:
        yield from self.parent.register_counter_g(self._k(counter_id), width)

    def register_counter(self, counter_id: str, width: int) -> None:
        run_effects(self.clock, self.register_counter_g(counter_id, width))

    def register_counters_g(self, widths: Mapping[str, int]) -> Any:
        yield from self.parent.register_counters_g(
            {self._k(cid): width for cid, width in widths.items()})

    def register_counters(self, widths: Mapping[str, int]) -> None:
        run_effects(self.clock, self.register_counters_g(widths))

    def increment_dependency_g(self, counter_id: str, edge_id: str) -> Any:
        with _SinkScope(self):
            return (yield from self.parent.increment_dependency_g(
                self._k(counter_id), edge_id))

    def increment_dependency(self, counter_id: str, edge_id: str) -> int:
        return run_effects(
            self.clock, self.increment_dependency_g(counter_id, edge_id))

    def deposit_and_increment_g(
        self,
        counter_id: str,
        edge_id: str,
        items: "dict[str, Any]",
        expected: "tuple[str, ...]" = (),
    ) -> Any:
        with _SinkScope(self):
            count, missing = yield from self.parent.deposit_and_increment_g(
                self._k(counter_id),
                edge_id,
                {self._k(k): v for k, v in items.items()},
                tuple(self._k(k) for k in expected),
            )
        plen = len(self._prefix)
        return count, [k[plen:] for k in missing]

    def deposit_and_increment(
        self,
        counter_id: str,
        edge_id: str,
        items: "dict[str, Any]",
        expected: "tuple[str, ...]" = (),
    ) -> "tuple[int, list[str]]":
        return run_effects(self.clock, self.deposit_and_increment_g(
            counter_id, edge_id, items, expected))

    def counter_value(self, counter_id: str) -> int:
        return self.parent.counter_value(self._k(counter_id))

    def rebind_counter(self, counter_id: str, width: int) -> None:
        self.parent.rebind_counter(self._k(counter_id), width)

    # -- pub/sub ------------------------------------------------------------
    def subscribe(self, channel: str) -> Any:
        return self.parent.subscribe(self._k(channel))

    def unsubscribe(self, channel: str, q: Any) -> None:
        self.parent.unsubscribe(self._k(channel), q)

    def subscriber_count(self, channel: str | None = None) -> int:
        """THIS view's live subscriptions only: with ``channel=None``
        the count covers the namespace's channels, never another job's."""
        if channel is not None:
            return self.parent.subscriber_count(self._k(channel))
        return self.parent.subscriber_count(None, prefix=self._prefix)

    def purge(self) -> int:
        """Reclaim everything this view ever stored (see
        ``ShardedKVStore.drop_namespace``)."""
        return self.parent.drop_namespace(self.name)

    def publish_g(self, channel: str, message: Any) -> Any:
        with _SinkScope(self):
            yield from self.parent.publish_g(self._k(channel), message)

    def publish(self, channel: str, message: Any) -> None:
        run_effects(self.clock, self.publish_g(channel, message))

    # -- journals ------------------------------------------------------------
    def journal_append_g(self, journal: str, entry: Any,
                         nbytes: int | None = None) -> Any:
        with _SinkScope(self):
            return (yield from self.parent.journal_append_g(
                self._k(journal), entry, nbytes))

    def journal_append(self, journal: str, entry: Any,
                       nbytes: int | None = None) -> int:
        return run_effects(self.clock,
                           self.journal_append_g(journal, entry, nbytes))

    def journal_scan_g(self, journal: str) -> Any:
        with _SinkScope(self):
            return (yield from self.parent.journal_scan_g(self._k(journal)))

    def journal_scan(self, journal: str) -> list[Any]:
        return run_effects(self.clock, self.journal_scan_g(journal))

    def journal_len(self, journal: str) -> int:
        return self.parent.journal_len(self._k(journal))

    # -- stats --------------------------------------------------------------
    def reset_stats(self) -> None:
        with self._stats_lock:
            self.stats = KVStats()
