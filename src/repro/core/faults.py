"""Failure / straggler injection and retry policy.

The paper relies on AWS Lambda's automatic retry (up to two retries of a
failed function execution, §IV-C) and explicitly lists stragglers as an
open problem (§II-A "functions suffer from the straggler issues").

We implement both:
- bounded automatic retry of a failed Task Executor (re-invoked from its
  schedule start point, paying invocation cost again),
- speculative duplicate execution for stragglers (a monitor re-invokes an
  executor whose current task has run far beyond the observed median).
Both are safe because KV writes are ``put_if_absent`` and fan-in counters
are idempotent edge-sets (kvstore.py), so a duplicate executor can never
double-fire a fan-in or clobber a result — this robustness is a
beyond-paper contribution (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import random
import threading
import zlib


class SimulatedTaskFailure(RuntimeError):
    """Injected Lambda execution failure."""


def exponential_backoff_ms(base_ms: float, attempt: int,
                           cap_ms: float = float("inf")) -> float:
    """Charged exponential retry delay: attempt ``k`` waits
    ``base * 2**k`` simulated ms, capped. Shared by the Lambda-retry
    path below and the platform model's 429-throttle retries, so both
    retry classes follow one schedule."""
    if base_ms <= 0:
        return 0.0
    return min(cap_ms, base_ms * (2.0 ** attempt))


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    task_failure_prob: float = 0.0   # per task attempt
    max_retries: int = 2             # AWS Lambda automatic retry limit
    # Simulated delay before a retry attempt is re-invoked (Lambda waits
    # ~1 min between automatic retries; default 0 keeps the seed
    # behavior). Exponential: attempt k is delayed 2**k * base.
    retry_backoff_base_ms: float = 0.0
    straggler_prob: float = 0.0      # per task attempt
    straggler_slowdown_ms: float = 0.0
    speculative_threshold_ms: float = float("inf")  # re-invoke beyond this
    seed: int = 0


class FaultInjector:
    """Deterministic-per-(task, attempt) fault decisions."""

    def __init__(self, config: FaultConfig):
        self.config = config
        self._lock = threading.Lock()

    def retry_backoff_ms(self, attempt: int) -> float:
        """Simulated delay charged before respawning retry ``attempt+1``
        (charged on the engine clock, so under the virtual clock it
        advances simulated time without wall-time cost)."""
        return exponential_backoff_ms(self.config.retry_backoff_base_ms,
                                      attempt)

    def _rng(self, task_key: str, attempt: int) -> random.Random:
        # Stable across processes: tuple.__hash__ mixes in the
        # PYTHONHASHSEED-randomized str hash, which silently turned every
        # "verified recoverable" test seed into a per-process lottery
        # (same bug class as hash()-based shard placement, fixed in
        # kvstore the same way).
        token = f"{self.config.seed}|{task_key}|{attempt}".encode()
        return random.Random(zlib.crc32(token))

    def should_fail(self, task_key: str, attempt: int) -> bool:
        if self.config.task_failure_prob <= 0:
            return False
        return self._rng(task_key, attempt).random() < self.config.task_failure_prob

    def straggle_ms(self, task_key: str, attempt: int) -> float:
        if self.config.straggler_prob <= 0:
            return 0.0
        rng = self._rng(task_key, attempt)
        rng.random()  # decorrelate from should_fail
        if rng.random() < self.config.straggler_prob:
            return self.config.straggler_slowdown_ms
        return 0.0


@dataclasses.dataclass
class ExecutorHeartbeat:
    executor_id: int
    start_key: str
    current_key: str
    started_at: float  # engine-clock ms (virtual ms under VirtualClock)
    parent: str | None = None
    # Full start batch for coalesced executors (speculative duplicates
    # must cover every member, not just the first).
    start_keys: tuple[str, ...] = ()


class HeartbeatRegistry:
    """Tracks in-flight executors for the speculative straggler monitor."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._beats: dict[int, ExecutorHeartbeat] = {}

    def beat(self, hb: ExecutorHeartbeat) -> None:
        with self._lock:
            self._beats[hb.executor_id] = hb

    def done(self, executor_id: int) -> None:
        with self._lock:
            self._beats.pop(executor_id, None)

    def inflight(self) -> list[ExecutorHeartbeat]:
        with self._lock:
            return list(self._beats.values())
