"""Failure / straggler injection and retry policy.

The paper relies on AWS Lambda's automatic retry (up to two retries of a
failed function execution, §IV-C) and explicitly lists stragglers as an
open problem (§II-A "functions suffer from the straggler issues").

We implement both:
- bounded automatic retry of a failed Task Executor (re-invoked from its
  schedule start point, paying invocation cost again),
- speculative duplicate execution for stragglers (a monitor re-invokes an
  executor whose current task has run far beyond the observed median).
Both are safe because KV writes are ``put_if_absent`` and fan-in counters
are idempotent edge-sets (kvstore.py), so a duplicate executor can never
double-fire a fan-in or clobber a result — this robustness is a
beyond-paper contribution (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import random
import threading
import zlib


class SimulatedTaskFailure(RuntimeError):
    """Injected Lambda execution failure."""


def exponential_backoff_ms(base_ms: float, attempt: int,
                           cap_ms: float = float("inf")) -> float:
    """Charged exponential retry delay: attempt ``k`` waits
    ``base * 2**k`` simulated ms, capped. Shared by the Lambda-retry
    path below and the platform model's 429-throttle retries, so both
    retry classes follow one schedule."""
    if base_ms <= 0:
        return 0.0
    return min(cap_ms, base_ms * (2.0 ** attempt))


# Orchestrator crash points (the dispatcher's seeded kill sites). The
# names mark WHERE in the control-plane protocol the process dies:
# after journaling ADMITTED but before the runner exists ("admit"),
# after the runner actor is spawned ("dispatch"), and after journaling
# COMPLETED but before the job's namespace is purged ("complete").
ORCHESTRATOR_CRASH_POINTS = ("admit", "dispatch", "complete")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    task_failure_prob: float = 0.0   # per task attempt
    max_retries: int = 2             # AWS Lambda automatic retry limit
    # Simulated delay before a retry attempt is re-invoked (Lambda waits
    # ~1 min between automatic retries; default 0 keeps the seed
    # behavior). Exponential: attempt k is delayed 2**k * base.
    retry_backoff_base_ms: float = 0.0
    # Exponential doubling is capped here: at high attempt counts an
    # unbounded 2**k delay dominates the simulated makespan (and real
    # SDKs cap retry sleeps the same way).
    max_backoff_ms: float = 60_000.0
    straggler_prob: float = 0.0      # per task attempt
    straggler_slowdown_ms: float = 0.0
    speculative_threshold_ms: float = float("inf")  # re-invoke beyond this
    seed: int = 0
    # Orchestrator-level crash injection: kill the dispatcher the
    # ``orchestrator_crash_at``-th time it passes the named point
    # (None = the orchestrator never crashes).
    orchestrator_crash_point: "str | None" = None
    orchestrator_crash_at: int = 1

    def __post_init__(self) -> None:
        # Reject bad knobs at construction: a negative rate silently
        # disables injection mid-run and a negative backoff/threshold
        # produces negative simulated charges — both are config bugs.
        for prob_field in ("task_failure_prob", "straggler_prob"):
            p = getattr(self, prob_field)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{prob_field} must be in [0, 1], got {p}")
        for nonneg in ("max_retries", "retry_backoff_base_ms",
                       "straggler_slowdown_ms"):
            v = getattr(self, nonneg)
            if v < 0:
                raise ValueError(f"{nonneg} must be >= 0, got {v}")
        if self.max_backoff_ms <= 0:
            raise ValueError(
                f"max_backoff_ms must be > 0, got {self.max_backoff_ms}")
        if self.speculative_threshold_ms <= 0:
            raise ValueError(
                "speculative_threshold_ms must be > 0 "
                f"(inf disables), got {self.speculative_threshold_ms}")
        if (self.orchestrator_crash_point is not None
                and self.orchestrator_crash_point
                not in ORCHESTRATOR_CRASH_POINTS):
            raise ValueError(
                f"orchestrator_crash_point must be one of "
                f"{ORCHESTRATOR_CRASH_POINTS}, "
                f"got {self.orchestrator_crash_point!r}")
        if self.orchestrator_crash_at < 1:
            raise ValueError(
                f"orchestrator_crash_at must be >= 1, "
                f"got {self.orchestrator_crash_at}")


class FaultStats:
    """Thread-safe per-job fault/retry observability counters, surfaced
    in ``JobReport.fault_stats`` so fault runs are inspectable without
    log scraping."""

    FIELDS = ("task_attempts", "injected_failures", "task_retries",
              "speculative_duplicates", "throttle_retries", "tasks_resumed")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counts = dict.fromkeys(self.FIELDS, 0)

    def bump(self, field: str, n: int = 1) -> None:
        with self._lock:
            self._counts[field] += n  # KeyError on a typo'd field: good

    def snapshot(self) -> "dict[str, int]":
        with self._lock:
            return dict(self._counts)


class FaultInjector:
    """Deterministic-per-(task, attempt) fault decisions."""

    def __init__(self, config: FaultConfig):
        self.config = config
        self._lock = threading.Lock()
        # Occurrence counters per orchestrator crash point. They live on
        # the injector INSTANCE and keep counting across recovery
        # generations, so a configured crash fires exactly once per
        # injector — recovery passes the same injector along and does
        # not re-crash at the same point forever.
        self._crash_counts: "dict[str, int]" = {}

    def retry_backoff_ms(self, attempt: int) -> float:
        """Simulated delay charged before respawning retry ``attempt+1``
        (charged on the engine clock, so under the virtual clock it
        advances simulated time without wall-time cost)."""
        return exponential_backoff_ms(self.config.retry_backoff_base_ms,
                                      attempt,
                                      cap_ms=self.config.max_backoff_ms)

    def orchestrator_crash(self, point: str) -> bool:
        """True when the dispatcher must die HERE: the configured crash
        point has been reached for the ``orchestrator_crash_at``-th
        time. Deterministic (occurrence-counted, no RNG), so the same
        workload crashes at the same job on every run."""
        if self.config.orchestrator_crash_point != point:
            return False
        with self._lock:
            self._crash_counts[point] = self._crash_counts.get(point, 0) + 1
            return self._crash_counts[point] == \
                self.config.orchestrator_crash_at

    def _rng(self, task_key: str, attempt: int) -> random.Random:
        # Stable across processes: tuple.__hash__ mixes in the
        # PYTHONHASHSEED-randomized str hash, which silently turned every
        # "verified recoverable" test seed into a per-process lottery
        # (same bug class as hash()-based shard placement, fixed in
        # kvstore the same way).
        token = f"{self.config.seed}|{task_key}|{attempt}".encode()
        return random.Random(zlib.crc32(token))

    def should_fail(self, task_key: str, attempt: int) -> bool:
        if self.config.task_failure_prob <= 0:
            return False
        return self._rng(task_key, attempt).random() < self.config.task_failure_prob

    def straggle_ms(self, task_key: str, attempt: int) -> float:
        if self.config.straggler_prob <= 0:
            return 0.0
        rng = self._rng(task_key, attempt)
        rng.random()  # decorrelate from should_fail
        if rng.random() < self.config.straggler_prob:
            return self.config.straggler_slowdown_ms
        return 0.0


@dataclasses.dataclass
class ExecutorHeartbeat:
    executor_id: int
    start_key: str
    current_key: str
    started_at: float  # engine-clock ms (virtual ms under VirtualClock)
    parent: str | None = None
    # Full start batch for coalesced executors (speculative duplicates
    # must cover every member, not just the first).
    start_keys: tuple[str, ...] = ()


class HeartbeatRegistry:
    """Tracks in-flight executors for the speculative straggler monitor."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._beats: dict[int, ExecutorHeartbeat] = {}

    def beat(self, hb: ExecutorHeartbeat) -> None:
        with self._lock:
            self._beats[hb.executor_id] = hb

    def done(self, executor_id: int) -> None:
        with self._lock:
            self._beats.pop(executor_id, None)

    def inflight(self) -> list[ExecutorHeartbeat]:
        with self._lock:
            return list(self._beats.values())
