"""Virtual-time simulation core: a deterministic discrete-event substrate.

Every engine layer (KV store, executors, invoker pools, schedulers, the
fault monitor) charges FaaS latency on a *clock* instead of calling
``time.sleep``/``time.monotonic`` directly. Two implementations share one
interface:

- ``VirtualClock`` (the default, selected by ``CostModel.time_scale == 0``)
  is a cooperative discrete-event scheduler over real threads. Threads
  register as *actors*; exactly one actor runs at a time (a run token),
  and every blocking operation — a simulated-latency charge, a queue
  ``get``, a transfer-lane ``acquire``, an event ``wait`` — yields the
  token through the clock. Virtual time advances to the next pending
  timer only when every actor is quiescent (blocked on an event or a
  timer), so a 512-leaf tree reduction that takes ~40 s of *simulated*
  time runs in well under a second of *wall* time — and, because the
  token handoff order is a pure function of the event sequence, runs are
  bit-identical: same ``wall_s``, same ``charged_ms``, same metrics.

- ``RealtimeClock`` (``time_scale > 0``) is the seed behavior kept for
  sanity cross-checks: charges really sleep ``ms * time_scale / 1e3``
  seconds, and the primitives are the plain ``threading``/``queue``
  ones. ``REPRO_SIM_SCALE`` is only needed for this mode.

Both clocks expose the *same* primitive factories (``queue()``,
``lock()``, ``event()``, ``pool()``, ``spawn()``), so the engines contain
no mode branches: they are written once against the clock and the mode is
picked by the cost model.

Determinism contract (virtual mode): actors are scheduled FIFO in the
order they became ready; timers fire in (deadline, registration-seq)
order; queue/lock waiters are served FIFO. Any randomness (invoke-latency
jitter, cold starts, fault injection) is drawn from counters/keys hashed
with seeds — never from wall time — so two runs of the same job produce
identical traces.

Threads that never registered as actors (unit tests driving the KV store
directly, external callers) degrade gracefully: their charges accumulate
``charged_ms`` without advancing virtual time, and their blocking waits
use real condition variables with real timeouts.
"""
from __future__ import annotations

import heapq
import itertools
import queue as _queue
import threading
import time
from typing import Any, Callable

__all__ = [
    "BaseClock",
    "RealtimeClock",
    "VirtualClock",
    "charge_meter",
    "clock_for_scale",
    "simulated_compute",
    "task_clock",
]


# ---------------------------------------------------------------------------
# Task-payload compute charging.
#
# Workload DAGs (tree reduction, GEMM, SVD, SVC) declare per-task compute
# duration in *simulated* ms. The executor installs the engine's clock in
# a thread-local around each task-function call; `simulated_compute`
# charges the duration on whatever clock is installed. Outside an engine
# (sequential reference evaluation in tests) it is free: reference
# results never depend on timing.
# ---------------------------------------------------------------------------

_task_clock = threading.local()


class task_clock:
    """Context manager installing ``clock`` as the current task clock."""

    def __init__(self, clock: "BaseClock | None"):
        self.clock = clock

    def __enter__(self) -> None:
        self._prev = getattr(_task_clock, "clock", None)
        _task_clock.clock = self.clock

    def __exit__(self, *exc: Any) -> None:
        _task_clock.clock = self._prev


def simulated_compute(ms: float) -> None:
    """Charge ``ms`` simulated milliseconds of task compute on the
    engine clock running this task (no-op outside an engine)."""
    clock = getattr(_task_clock, "clock", None)
    if clock is not None and ms > 0:
        clock.charge(ms)


# ---------------------------------------------------------------------------
# Per-thread charge metering (billing).
#
# The platform model bills an invocation the simulated time its thread
# *charges* while running the function body — not a wall-clock delta —
# because charge amounts are identical in both clock modes (the virtual
# clock advances them, the real-time clock sleeps them scaled), which
# makes billed cost bit-identical across modes. The tap lives here so the
# platform layer never has to patch clock internals.
# ---------------------------------------------------------------------------

_charge_tap = threading.local()


class charge_meter:
    """Context manager accumulating this thread's clock charges into
    ``acc[0]`` (a single-element list). Nesting restores the previous
    accumulator on exit; charges while nested land in the innermost."""

    def __init__(self, acc: "list[float]"):
        self.acc = acc

    def __enter__(self) -> "list[float]":
        self._prev = getattr(_charge_tap, "acc", None)
        _charge_tap.acc = self.acc
        return self.acc

    def __exit__(self, *exc: Any) -> None:
        _charge_tap.acc = self._prev


# ---------------------------------------------------------------------------
# Worker-thread cache.
#
# Engines spawn hundreds of short-lived actor threads per job (invoker
# lanes, runtime-pool workers, monitors). OS thread creation is ~100s of
# microseconds — a large fraction of a virtual run's wall time — so
# finished workers park here and get re-dispatched instead of dying.
# Recycling is invisible to the simulation: the *actor slot* is created
# deterministically by ``spawn``; which OS thread services it is not an
# event the discrete-event scheduler can observe.
# ---------------------------------------------------------------------------

_WORKER_CACHE_MAX = 2048
_worker_cache: "list[_CachedWorker]" = []
_worker_cache_lock = threading.Lock()


class _CachedWorker(threading.Thread):
    def __init__(self) -> None:
        super().__init__(daemon=True, name="simclock-worker")
        self._sem = threading.Semaphore(0)
        self._job: Callable[[], None] | None = None
        self.start()

    def run(self) -> None:
        while True:
            self._sem.acquire()
            job, self._job = self._job, None
            if job is None:
                return
            job()  # an escaping exception retires this thread (no recycle)
            with _worker_cache_lock:
                if len(_worker_cache) >= _WORKER_CACHE_MAX:
                    return
                _worker_cache.append(self)

    def dispatch(self, job: Callable[[], None]) -> None:
        self._job = job
        self._sem.release()


def _dispatch_to_worker(job: Callable[[], None]) -> None:
    with _worker_cache_lock:
        worker = _worker_cache.pop() if _worker_cache else None
    (worker or _CachedWorker()).dispatch(job)


# ---------------------------------------------------------------------------
# Shared interface
# ---------------------------------------------------------------------------


class BaseClock:
    """Accounting shared by both clock implementations."""

    virtual: bool = False

    def __init__(self) -> None:
        self._charge_lock = threading.Lock()
        self.charged_ms = 0.0

    def _account(self, ms: float) -> None:
        with self._charge_lock:
            self.charged_ms += ms
        acc = getattr(_charge_tap, "acc", None)
        if acc is not None:
            acc[0] += ms

    # subclass API ----------------------------------------------------------
    def charge(self, ms: float) -> None:  # bill + advance simulated time
        raise NotImplementedError

    def now_ms(self) -> float:  # simulated (virtual) / real elapsed ms
        raise NotImplementedError

    def queue(self) -> Any:  # queue.Queue-compatible
        raise NotImplementedError

    def lock(self) -> Any:  # context-manager lock (transfer lanes)
        raise NotImplementedError

    def event(self) -> Any:  # threading.Event-compatible
        raise NotImplementedError

    def pool(self, max_workers: int) -> Any:  # .submit(fn) / .shutdown()
        raise NotImplementedError

    def spawn(self, fn: Callable[[], None], name: str) -> None:
        raise NotImplementedError

    def actor(self) -> Any:  # context manager registering current thread
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Real-time clock (the seed behavior, kept for cross-checks)
# ---------------------------------------------------------------------------


class _RealtimePool:
    """Thin ThreadPoolExecutor wrapper pinning the two methods engines use."""

    def __init__(self, max_workers: int):
        from concurrent.futures import ThreadPoolExecutor

        self._tpe = ThreadPoolExecutor(max_workers=max_workers)

    def submit(self, fn: Callable[[], Any]) -> None:
        self._tpe.submit(fn)

    def shutdown(self, wait: bool = False,
                 cancel_futures: bool = True) -> None:
        self._tpe.shutdown(wait=wait, cancel_futures=cancel_futures)


class _NullActor:
    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: Any) -> None:
        return None


class RealtimeClock(BaseClock):
    """Charges simulated latency by really sleeping ``ms * time_scale``."""

    virtual = False

    def __init__(self, time_scale: float):
        super().__init__()
        self.time_scale = time_scale
        self._t0 = time.perf_counter()

    def charge(self, ms: float) -> None:
        if ms <= 0:
            return
        self._account(ms)
        if self.time_scale > 0:
            time.sleep(ms * self.time_scale / 1e3)

    def now_ms(self) -> float:
        return (time.perf_counter() - self._t0) * 1e3

    def queue(self) -> "_queue.Queue[Any]":
        return _queue.Queue()

    def lock(self) -> threading.Lock:
        return threading.Lock()

    def event(self) -> threading.Event:
        return threading.Event()

    def pool(self, max_workers: int) -> _RealtimePool:
        return _RealtimePool(max_workers)

    def spawn(self, fn: Callable[[], None], name: str) -> None:
        _dispatch_to_worker(fn)

    def actor(self) -> _NullActor:
        return _NullActor()


# ---------------------------------------------------------------------------
# Virtual clock: cooperative discrete-event scheduling
# ---------------------------------------------------------------------------

_RUNNING = "running"
_READY = "ready"
_BLOCKED = "blocked"

_WAKE_SIGNAL = "signal"
_WAKE_TIMEOUT = "timeout"


class _Actor:
    __slots__ = ("seq", "cond", "state", "wake_reason", "timer")

    def __init__(self, seq: int, mutex: threading.Lock):
        self.seq = seq
        self.cond = threading.Condition(mutex)
        self.state = _READY
        self.wake_reason: str | None = None
        self.timer: "_Timer | None" = None  # pending virtual timeout


class _Timer:
    __slots__ = ("deadline", "actor", "cancelled")

    def __init__(self, deadline: float, actor: _Actor):
        self.deadline = deadline
        self.actor = actor
        self.cancelled = False

    def __lt__(self, other: "_Timer") -> bool:  # heap tiebreak
        return (self.deadline, self.actor.seq) < (
            other.deadline, other.actor.seq)


class _ExternalWaiter:
    """A non-actor thread blocked on a clock primitive (tests, legacy
    callers). It waits on a real condition with a real timeout and does
    not hold back virtual-time advancement."""

    __slots__ = ("cond", "signalled")

    def __init__(self, mutex: threading.Lock):
        self.cond = threading.Condition(mutex)
        self.signalled = False


class VirtualClock(BaseClock):
    """Deterministic discrete-event clock over cooperative actor threads.

    Exactly one registered actor holds the run token at any moment; all
    others are parked on per-actor condition variables sharing one mutex.
    Blocking operations release the token; wake-ups re-enter a FIFO ready
    queue. Virtual time jumps to the earliest pending timer only when no
    actor is ready — i.e. when every actor is provably waiting on
    simulated time or on an event another actor will produce.
    """

    virtual = True

    def __init__(self) -> None:
        super().__init__()
        self._mutex = threading.Lock()
        self._now = 0.0
        self._seq = itertools.count()
        self._actors: dict[int, _Actor] = {}  # thread ident -> actor
        self._ready: list[_Actor] = []
        self._running: _Actor | None = None
        self._timers: list[_Timer] = []
        self.switches = 0        # token handoffs (scheduler cost metric)
        self.actors_spawned = 0  # total actor registrations

    # -- introspection ------------------------------------------------------
    def now_ms(self) -> float:
        return self._now

    def _current(self) -> _Actor | None:
        return self._actors.get(threading.get_ident())

    # -- scheduling core (all called with self._mutex held) -----------------
    def _schedule_next(self) -> None:
        """Hand the run token to the next ready actor, advancing virtual
        time to the earliest timer when nobody is ready."""
        while True:
            if self._ready:
                nxt = self._ready.pop(0)
                nxt.state = _RUNNING
                self._running = nxt
                self.switches += 1
                nxt.cond.notify()
                return
            while self._timers and self._timers[0].cancelled:
                heapq.heappop(self._timers)
            if not self._timers:
                # Fully event-blocked (or no actors at all): idle until an
                # external stimulus re-kicks the scheduler.
                self._running = None
                return
            timer = heapq.heappop(self._timers)
            self._now = max(self._now, timer.deadline)
            actor = timer.actor
            actor.timer = None
            actor.wake_reason = _WAKE_TIMEOUT
            actor.state = _READY
            self._ready.append(actor)

    def _kick(self) -> None:
        """Start the scheduler if the simulation is idle (called after an
        external thread made an actor ready or added a timer)."""
        if self._running is None:
            self._schedule_next()

    def _make_ready(self, actor: _Actor) -> None:
        """Move a blocked actor to the ready queue (waker side)."""
        if actor.timer is not None:
            actor.timer.cancelled = True
            actor.timer = None
        actor.wake_reason = _WAKE_SIGNAL
        actor.state = _READY
        self._ready.append(actor)

    def _block(self, actor: _Actor, timeout_ms: float | None) -> str:
        """Release the run token and wait to be woken. Returns the wake
        reason (``signal`` or ``timeout``)."""
        actor.state = _BLOCKED
        actor.wake_reason = None
        if timeout_ms is not None:
            actor.timer = _Timer(self._now + max(0.0, timeout_ms), actor)
            heapq.heappush(self._timers, actor.timer)
        self._schedule_next()
        while actor.state is not _RUNNING:
            actor.cond.wait()
        return actor.wake_reason or _WAKE_SIGNAL

    def _wait_for_token(self, actor: _Actor) -> None:
        while actor.state is not _RUNNING:
            actor.cond.wait()

    # -- actor lifecycle ----------------------------------------------------
    def _register_current(self) -> _Actor:
        with self._mutex:
            actor = _Actor(next(self._seq), self._mutex)
            actor.state = _READY
            self._actors[threading.get_ident()] = actor
            self._ready.append(actor)
            self._kick()
            self._wait_for_token(actor)
            return actor

    def _deregister_current(self) -> None:
        with self._mutex:
            actor = self._actors.pop(threading.get_ident(), None)
            if actor is None:
                return
            if self._running is actor:
                self._schedule_next()

    class _ActorContext:
        def __init__(self, clock: "VirtualClock"):
            self.clock = clock

        def __enter__(self) -> None:
            self.clock._register_current()

        def __exit__(self, *exc: Any) -> None:
            self.clock._deregister_current()

    def actor(self) -> "_ActorContext":
        return VirtualClock._ActorContext(self)

    def spawn(self, fn: Callable[[], None], name: str) -> None:
        # The actor slot enters the ready queue HERE, on the spawning
        # thread, so scheduling order is a pure function of the event
        # sequence — not of how quickly the OS starts (or recycles) the
        # worker thread that will service it.
        with self._mutex:
            actor = _Actor(next(self._seq), self._mutex)
            actor.state = _READY
            self._ready.append(actor)
            self.actors_spawned += 1
            self._kick()

        def body() -> None:
            with self._mutex:
                self._actors[threading.get_ident()] = actor
                self._wait_for_token(actor)
            try:
                fn()
            finally:
                self._deregister_current()

        _dispatch_to_worker(body)

    # -- time ---------------------------------------------------------------
    def sleep_ms(self, ms: float) -> None:
        with self._mutex:
            actor = self._current()
            if actor is None or self._running is not actor:
                return  # non-actor thread: virtual time is not its to spend
            self._block(actor, ms)

    def charge(self, ms: float) -> None:
        if ms <= 0:
            return
        self._account(ms)
        self.sleep_ms(ms)

    # -- primitives ---------------------------------------------------------
    def queue(self) -> "VirtualQueue":
        return VirtualQueue(self)

    def lock(self) -> "VirtualLock":
        return VirtualLock(self)

    def event(self) -> "VirtualEvent":
        return VirtualEvent(self)

    def pool(self, max_workers: int) -> "VirtualPool":
        return VirtualPool(self, max_workers)


class VirtualQueue:
    """``queue.Queue``-compatible FIFO whose blocking ``get`` cooperates
    with the virtual clock. ``timeout`` is *simulated seconds* for actor
    threads and real seconds for non-actor threads."""

    def __init__(self, clock: VirtualClock):
        self._clock = clock
        self._items: list[Any] = []
        self._waiters: list[_Actor | _ExternalWaiter] = []

    def put(self, item: Any) -> None:
        clock = self._clock
        with clock._mutex:
            self._items.append(item)
            if self._waiters:
                waiter = self._waiters.pop(0)
                if isinstance(waiter, _ExternalWaiter):
                    waiter.signalled = True
                    waiter.cond.notify()
                else:
                    clock._make_ready(waiter)
                    clock._kick()

    def get(self, timeout: float | None = None) -> Any:
        clock = self._clock
        with clock._mutex:
            actor = clock._current()
            if actor is not None and clock._running is actor:
                deadline = (None if timeout is None
                            else clock._now + timeout * 1e3)
                while not self._items:
                    remaining = (None if deadline is None
                                 else deadline - clock._now)
                    if remaining is not None and remaining <= 0:
                        raise _queue.Empty
                    self._waiters.append(actor)
                    reason = clock._block(actor, remaining)
                    if reason == _WAKE_TIMEOUT:
                        if actor in self._waiters:
                            self._waiters.remove(actor)
                        raise _queue.Empty
                return self._items.pop(0)
            # Non-actor thread: real wait, real timeout.
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self._items:
                waiter = _ExternalWaiter(clock._mutex)
                self._waiters.append(waiter)
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self._waiters.remove(waiter)
                    raise _queue.Empty
                if not waiter.cond.wait(remaining):
                    if waiter in self._waiters:
                        self._waiters.remove(waiter)
                    if not waiter.signalled:
                        raise _queue.Empty
            return self._items.pop(0)

    def empty(self) -> bool:
        with self._clock._mutex:
            return not self._items

    def drain(self) -> "list[Any]":
        """Atomically remove and return every queued item (pool shutdown
        with ``cancel_futures``: queued-but-unstarted work is dropped)."""
        with self._clock._mutex:
            items, self._items = self._items, []
            return items


class VirtualLock:
    """Transfer-lane lock held across simulated transfers. FIFO handoff:
    ``release`` passes ownership directly to the longest-waiting thread,
    which keeps lane-contention outcomes deterministic."""

    def __init__(self, clock: VirtualClock):
        self._clock = clock
        self._owner: Any = None  # _Actor, _ExternalWaiter, or thread ident
        self._waiters: list[_Actor | _ExternalWaiter] = []

    def acquire(self) -> None:
        clock = self._clock
        with clock._mutex:
            actor = clock._current()
            if actor is not None and clock._running is actor:
                if self._owner is None:
                    self._owner = actor
                    return
                self._waiters.append(actor)
                clock._block(actor, None)  # woken owning the lock
                return
            ident = threading.get_ident()
            if self._owner is None:
                self._owner = ident
                return
            waiter = _ExternalWaiter(clock._mutex)
            self._waiters.append(waiter)
            while not waiter.signalled:
                waiter.cond.wait()
            self._owner = ident

    def release(self) -> None:
        clock = self._clock
        with clock._mutex:
            if not self._waiters:
                self._owner = None
                return
            waiter = self._waiters.pop(0)
            if isinstance(waiter, _ExternalWaiter):
                self._owner = waiter  # placeholder until the thread wakes
                waiter.signalled = True
                waiter.cond.notify()
            else:
                self._owner = waiter
                clock._make_ready(waiter)
                clock._kick()

    def __enter__(self) -> "VirtualLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()


class VirtualEvent:
    """``threading.Event``-compatible; ``wait`` timeout is simulated
    seconds for actors, real seconds for non-actor threads."""

    def __init__(self, clock: VirtualClock):
        self._clock = clock
        self._flag = False
        self._waiters: list[_Actor | _ExternalWaiter] = []

    def is_set(self) -> bool:
        return self._flag

    def set(self) -> None:
        clock = self._clock
        with clock._mutex:
            self._flag = True
            waiters, self._waiters = self._waiters, []
            kicked = False
            for waiter in waiters:
                if isinstance(waiter, _ExternalWaiter):
                    waiter.signalled = True
                    waiter.cond.notify()
                else:
                    clock._make_ready(waiter)
                    kicked = True
            if kicked:
                clock._kick()

    def wait(self, timeout: float | None = None) -> bool:
        clock = self._clock
        with clock._mutex:
            if self._flag:
                return True
            actor = clock._current()
            if actor is not None and clock._running is actor:
                self._waiters.append(actor)
                reason = clock._block(
                    actor, None if timeout is None else timeout * 1e3)
                if reason == _WAKE_TIMEOUT and actor in self._waiters:
                    self._waiters.remove(actor)
                return self._flag
            waiter = _ExternalWaiter(clock._mutex)
            self._waiters.append(waiter)
            waiter.cond.wait(timeout)
            if waiter in self._waiters:
                self._waiters.remove(waiter)
            return self._flag


class VirtualPool:
    """Executor-runtime stand-in for ``ThreadPoolExecutor``: worker
    threads are clock actors created lazily up to ``max_workers``, so an
    8k-task sweep only materializes as many OS threads as are ever
    simultaneously busy. Queued bodies do NOT hold back virtual time —
    a full pool models the provider's concurrency limit."""

    def __init__(self, clock: VirtualClock, max_workers: int):
        self._clock = clock
        self._max_workers = max(1, max_workers)
        self._q = clock.queue()
        self._state_lock = threading.Lock()
        self._workers = 0
        self._idle = 0
        self._closed = False

    def submit(self, fn: Callable[[], Any]) -> None:
        with self._state_lock:
            if self._closed:
                raise RuntimeError("cannot schedule new futures after "
                                   "shutdown")
            spawn = self._idle == 0 and self._workers < self._max_workers
            if spawn:
                self._workers += 1
                n = self._workers
        self._q.put(fn)
        if spawn:
            self._clock.spawn(self._worker, name=f"vpool-{n}")

    def _worker(self) -> None:
        while True:
            with self._state_lock:
                self._idle += 1
            item = self._q.get()
            with self._state_lock:
                self._idle -= 1
            if item is None:
                return
            item()

    def shutdown(self, wait: bool = False,
                 cancel_futures: bool = True) -> None:
        with self._state_lock:
            if self._closed:
                return
            self._closed = True
            n = self._workers
        if cancel_futures:
            # Drop queued-but-unstarted bodies (matching the
            # ThreadPoolExecutor contract the realtime pool inherits).
            # Before this, a torn-down job's queued executors still ran
            # to completion behind the shutdown sentinels — harmless when
            # the substrate died with the job, a capacity leak once
            # platform and store outlive it.
            self._q.drain()
        for _ in range(n):
            self._q.put(None)


# ---------------------------------------------------------------------------
# Mode selection
# ---------------------------------------------------------------------------


def clock_for_scale(time_scale: float) -> BaseClock:
    """``time_scale == 0`` selects the virtual discrete-event clock (the
    default); ``time_scale > 0`` keeps the seed real-time mode for
    cross-checks."""
    if time_scale > 0:
        return RealtimeClock(time_scale)
    return VirtualClock()
